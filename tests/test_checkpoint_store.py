"""``repro.checkpoint.store`` on simulation pytrees: ``BlockCarry``
round-trips (including the strategy engines' per-shard ``(P,)`` tile
counters) must preserve every leaf's dtype and value exactly, and a
template/checkpoint dtype mismatch must raise instead of silently casting."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.sim import ensemble as ens
from repro.sim import scenarios


def _block_state_and_carry():
    state = ens.stack_states(
        [scenarios.pad_state(scenarios.make("plummer", 24), 32),
         scenarios.pad_state(scenarios.make("two_body", 2), 32)])
    state = ens.ensemble_initialize(state, order=6, eps=1e-7, impl="xla")
    state, carry = ens.ensemble_run_block(
        state, t_end=0.02, n_events=4, dt_max=0.0625, n_levels=4,
        eta=0.02, order=6, eps=1e-7, impl="xla",
        block_i=32, block_j=32)
    return state, carry


def test_blockcarry_roundtrip_exact(tmp_path):
    state, carry = _block_state_and_carry()
    tree = {"state": state, "carry": carry}
    store.save(str(tmp_path), 5, tree)

    like = {"state": jax.tree_util.tree_map(jnp.zeros_like, state),
            "carry": jax.tree_util.tree_map(jnp.zeros_like, carry)}
    step, back = store.restore_latest(str(tmp_path), like)
    assert step == 5
    assert isinstance(back["carry"], ens.BlockCarry)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert a.dtype == b.dtype  # the once-lost part: no silent casting
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the fractional-capable accumulators must still be the wide count dtype
    count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
    assert back["carry"].n_tiles.dtype == count_dtype
    assert back["carry"].n_pairs.dtype == count_dtype
    assert back["carry"].n_events.dtype == jnp.int32


def test_restore_refuses_dtype_mismatch(tmp_path):
    _, carry = _block_state_and_carry()
    store.save(str(tmp_path), 1, {"carry": carry})
    narrow = carry._replace(
        n_tiles=jnp.zeros(carry.n_tiles.shape, jnp.float32))
    with pytest.raises(ValueError, match="restore never casts"):
        store.restore(str(tmp_path), 1, {"carry": narrow})


def test_restore_refuses_shape_mismatch(tmp_path):
    _, carry = _block_state_and_carry()
    store.save(str(tmp_path), 1, {"carry": carry})
    wrong = jax.tree_util.tree_map(
        lambda a: jnp.zeros((3,) + tuple(a.shape[1:]), a.dtype), carry)
    with pytest.raises(ValueError, match="shape"):
        store.restore(str(tmp_path), 1, {"carry": wrong})


# strategy engines carry a per-shard (P,) tile vector; a 2-device carry must
# round-trip bit-exactly too (subprocess: device count is fixed at import)
_PER_SHARD = r"""
import numpy as np
import jax, jax.numpy as jnp, sys
jax.config.update("jax_enable_x64", True)
from repro.checkpoint import store
from repro.sim import ensemble as ens
from repro.sim import scenarios

state = scenarios.pad_state(scenarios.make("plummer", 24), 32)
state, carry = ens.strategy_run_block(
    state, t_end=0.02, n_events=4, dt_max=0.0625, n_levels=4,
    strategy="mesh_sharded", impl="xla", block_i=32, block_j=32,
    devices=jax.devices())
assert carry.n_tiles.shape == (2,), carry.n_tiles.shape

store.save(sys.argv[1], 2, {"carry": carry})
like = jax.tree_util.tree_map(jnp.zeros_like, carry)
step, back = store.restore_latest(sys.argv[1], {"carry": like})
assert step == 2
for a, b in zip(jax.tree_util.tree_leaves(carry),
                jax.tree_util.tree_leaves(back["carry"])):
    assert a.dtype == b.dtype, (a.dtype, b.dtype)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert back["carry"].n_tiles.shape == (2,)
print("PER-SHARD-ROUNDTRIP OK")
"""


@pytest.mark.slow
def test_per_shard_tile_counters_roundtrip(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    res = subprocess.run([sys.executable, "-c", _PER_SHARD, str(tmp_path)],
                         env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "PER-SHARD-ROUNDTRIP OK" in res.stdout
