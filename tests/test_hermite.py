"""Hermite integrator validation: analytic orbit, energy conservation,
convergence order, and the paper's golden-reference comparison (Fig. 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hermite, nbody
from repro.core.evaluate import make_evaluator


def test_two_body_circular_orbit():
    """Equal-mass binary on a circular orbit: period 2*pi*a^1.5 with a=1,
    M=1 (G=1) => T = 2*pi; positions return to start."""
    state = nbody.two_body_circular()
    ev = make_evaluator(precision="fp64")
    period = 2.0 * np.pi
    out = hermite.evolve(state, ev, t_end=period, dt=period / 512)
    np.testing.assert_allclose(np.asarray(out.pos), np.asarray(state.pos),
                               atol=1e-6)
    e0 = float(nbody.total_energy(hermite.initialize(state, ev)))
    e1 = float(nbody.total_energy(out))
    assert abs((e1 - e0) / e0) < 1e-12


def test_energy_conservation_plummer():
    state = nbody.plummer(256, seed=1)
    ev = make_evaluator(precision="fp64")
    init = hermite.initialize(state, ev)
    e0 = float(nbody.total_energy(init))
    # E0 must be the virial value (~-1/4), not the self-interaction-polluted
    # figure the softened potential gives without the r2>0 guard
    assert -0.30 < e0 < -0.20, e0
    out = hermite.evolve(state, ev, t_end=0.25, dt=1.0 / 512)
    e1 = float(nbody.total_energy(out))
    assert abs((e1 - e0) / e0) < 1e-7, (e0, e1)


def test_sixth_order_beats_fourth_order():
    """At equal dt the 6th-order scheme tracks a fine-dt reference trajectory
    markedly better than the 4th-order (acc+jerk-only) scheme.  (Energy drift
    is too cancellation-prone to discriminate orders robustly.)"""
    state = nbody.plummer(32, seed=3)
    ev = make_evaluator(precision="fp64")
    ref = hermite.evolve(state, ev, t_end=0.25, dt=1.0 / 2048)

    def traj_err(order, dt):
        out = hermite.evolve(state, ev, t_end=0.25, dt=dt, order=order)
        return float(jnp.sqrt(jnp.mean((out.pos - ref.pos) ** 2)))

    e4 = traj_err(4, 1.0 / 128)
    e6 = traj_err(6, 1.0 / 128)
    assert e6 < e4 / 3, (e4, e6)
    # order-6 refines ~2^6 per halving (asymptotic regime)
    e6_coarse = traj_err(6, 1.0 / 64)
    assert e6_coarse / e6 > 16, (e6_coarse, e6)


def test_convergence_rate_order6():
    """Halving dt must cut the energy error by ~2^6 (within slack)."""
    state = nbody.plummer(64, seed=3)
    ev = make_evaluator(precision="fp64")
    e0 = float(nbody.total_energy(hermite.initialize(state, ev)))

    def err(dt):
        out = hermite.evolve(state, ev, t_end=0.125, dt=dt)
        return abs((float(nbody.total_energy(out)) - e0) / e0)

    e_h = err(1.0 / 32)
    e_h2 = err(1.0 / 64)
    rate = np.log2(max(e_h, 1e-16) / max(e_h2, 1e-16))
    assert rate > 4.0, (e_h, e_h2, rate)   # >= ~2^5-2^6 in practice


def test_fp32_device_evaluation_tracks_golden():
    """Paper Fig. 4: mixed-precision run stays on the FP64 track."""
    state = nbody.plummer(256, seed=4)
    golden = make_evaluator(precision="fp64")
    device = make_evaluator(impl="pallas_interpret")  # FP32 kernel
    out_g = hermite.evolve(state, golden, t_end=0.25, dt=1.0 / 128)
    out_d = hermite.evolve(state, device, t_end=0.25, dt=1.0 / 128)
    # end-state energy distributions overlap (not particle-exact: FP32)
    eg = np.asarray(nbody.particle_energies(out_g))
    ed = np.asarray(nbody.particle_energies(out_d))
    np.testing.assert_allclose(np.sort(eg), np.sort(ed), rtol=2e-2,
                               atol=2e-2)
    assert abs(np.mean(eg) - np.mean(ed)) / abs(np.mean(eg)) < 1e-3


def test_adaptive_timestep_positive_and_bounded():
    state = nbody.plummer(128, seed=5)
    ev = make_evaluator(precision="fp64")
    init = hermite.initialize(state, ev)
    dt = float(hermite.aarseth_dt(init, eta=0.02, dt_max=0.0625))
    assert 0.0 < dt <= 0.0625


def test_evolve_scan_matches_python_loop():
    state = nbody.plummer(64, seed=6)
    ev = make_evaluator(precision="fp64")
    out_a = hermite.evolve(state, ev, t_end=8 / 128, dt=1 / 128)
    out_b = hermite.evolve_scan(state, ev, n_steps=8, dt=1 / 128)
    np.testing.assert_allclose(np.asarray(out_a.pos), np.asarray(out_b.pos),
                               rtol=1e-12, atol=1e-12)
