"""The continuous-batching ``SimServer`` (``repro.serve.sim_engine``):
admission-policy tile accounting, batch-mate bit-identity across
retire/backfill, the zero-recompile steady state, retirement reports and
dtype-strict suspend/resume."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.kernels import ops
from repro.serve import (ServerConfig, SimRequest, SimServer,
                         fifo_event_tiles, packed_event_tiles)
from repro.sim.scenarios import ScenarioError, ScenarioSpec
from repro.sim.telemetry import RunReport


def _cfg(**kw):
    base = dict(slots_per_pod=2, n_max=64, chunk_events=4, impl="xla",
                dt_max=0.0625, n_levels=4, block_i=32, block_j=32,
                devices=1)
    base.update(kw)
    return ServerConfig(**base)


def _req(token, stepper="adaptive", t_end=0.02, seed=0):
    return SimRequest(spec=ScenarioSpec.parse(token, seed=seed),
                      stepper=stepper, t_end=t_end)


# --------------------------------------------------------------------------
# admission policy: packing by bucket never launches more tiles than FIFO
# --------------------------------------------------------------------------
def test_packed_tiles_never_exceed_fifo_exhaustive():
    """Every admissible n, every plan shape we serve (pure host math)."""
    for n_max, bi, bj in ((64, 32, 32), (128, 32, 32), (256, 64, 64)):
        plan = ops.CapacityPlan(n_max, n_max, bi, bj)
        for n in range(1, n_max + 1):
            packed = packed_event_tiles(plan, n)
            fifo = fifo_event_tiles(plan, n)
            assert packed <= fifo, (n_max, bi, bj, n, packed, fifo)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # optional dependency; the exhaustive test still runs
    given = None

if given is not None:
    @settings(deadline=None, max_examples=200)
    @given(n=st.integers(min_value=1, max_value=1024),
           shape=st.sampled_from([(1024, 32, 32), (1024, 64, 64),
                                  (512, 32, 64)]))
    def test_packed_tiles_never_exceed_fifo_property(n, shape):
        n_max, bi, bj = shape
        plan = ops.CapacityPlan(n_max, n_max, bi, bj)
        assert packed_event_tiles(plan, n) <= fifo_event_tiles(plan, n)


# --------------------------------------------------------------------------
# batch-mate bit-identity across retire + backfill
# --------------------------------------------------------------------------
def _member_rows(pod, slot):
    return jax.tree_util.tree_map(lambda a: np.asarray(a)[slot],
                                  pod.batched)


@pytest.mark.parametrize("stepper", ["adaptive", "block"])
def test_batch_mate_bit_identical_across_backfill(stepper):
    """A neighbour retiring and a new member backfilling its slot must not
    perturb the surviving member's trajectory by a single bit."""
    short, long_ = 0.01, 0.08
    treatment = SimServer(_cfg())
    treatment.submit(_req("plummer:24", stepper, short), now=0.0)
    treatment.submit(_req("two_body:2", stepper, long_), now=0.0)
    treatment.submit(_req("king:20", stepper, short, seed=5), now=0.0)

    control = SimServer(_cfg())
    control.submit(_req("plummer:24", stepper, short), now=0.0)
    control.submit(_req("two_body:2", stepper, long_), now=0.0)

    # pod is full (2 slots), so the third request queues until the first
    # retires; its backfill must leave the second member's rows untouched.
    ticks = 0
    while treatment.busy() or control.busy():
        treatment.step(now=float(ticks))
        control.step(now=float(ticks))
        ticks += 1
        assert ticks < 1000
        (t_pod,), (c_pod,) = treatment.pods.values(), control.pods.values()
        if t_pod.slots[1] is not None and c_pod.slots[1] is not None:
            t_rows = _member_rows(t_pod, 1)
            c_rows = _member_rows(c_pod, 1)
            for t_leaf, c_leaf in zip(jax.tree_util.tree_leaves(t_rows),
                                      jax.tree_util.tree_leaves(c_rows)):
                np.testing.assert_array_equal(t_leaf, c_leaf)

    by_rid = {r["request_id"]: r for r in treatment.reports}
    assert len(by_rid) == 3
    survivor_t = by_rid[1]
    survivor_c = {r["request_id"]: r for r in control.reports}[1]
    for key in ("steps", "t_final", "e1"):
        assert survivor_t[key] == survivor_c[key]


# --------------------------------------------------------------------------
# zero recompiles in steady state
# --------------------------------------------------------------------------
def test_zero_cache_miss_after_warmup():
    server = SimServer(_cfg())
    server.warmup([_req("plummer:24", "adaptive"),
                   _req("plummer:40", "block")])
    baseline = server.cache_misses()
    assert baseline > 0  # warmup itself lowered the engines
    for seed in range(4):
        server.submit(_req("plummer:24", "adaptive", 0.02, seed=seed))
        server.submit(_req("king:40", "block", 0.02, seed=seed))
    reports = server.run_until_drained()
    assert len(reports) == 8
    assert server.cache_misses() == baseline


# --------------------------------------------------------------------------
# retirement reports
# --------------------------------------------------------------------------
def test_retire_report_contents():
    server = SimServer(_cfg())
    rid = server.submit(_req("plummer:24", "block", t_end=0.02, seed=7),
                        now=0.0)
    (report,) = server.run_until_drained()
    assert isinstance(report, RunReport)
    assert report["scenario"] == "plummer:24"
    assert report["n_active"] == [24]
    assert report["n_bodies"] == server.pod_for(
        _req("plummer:24", "block")).cap
    assert report["steps"] >= 1
    assert report["request_id"] == rid
    assert report["t_final"] >= 0.02
    assert report["turnaround_s"] >= report["admission_latency_s"] >= 0.0
    assert np.isfinite(report["de_rel"])
    assert report["grid_tiles"][0] > 0  # block pods count launched tiles
    snap = server.metrics_snapshot()
    assert {"serve.requests_admitted",
            "serve.requests_retired"} <= set(snap["counters"])
    assert "serve.queue_depth" in snap["gauges"]
    assert "serve.turnaround_s" in snap["histograms"]


def test_bucket_packing_separates_pods_and_fifo_per_bucket():
    server = SimServer(_cfg())
    server.submit(_req("plummer:24", "adaptive"))   # cap 32 pod
    server.submit(_req("plummer:40", "adaptive"))   # cap 64 pod
    server.submit(_req("plummer:20", "block"))      # block cap 32 pod
    server.step(now=0.0)
    assert set(server.pods) == {("adaptive", 32), ("adaptive", 64),
                                ("block", 32)}
    assert not server.queue  # distinct buckets never block one another


# --------------------------------------------------------------------------
# suspend / resume
# --------------------------------------------------------------------------
@pytest.mark.parametrize("stepper", ["adaptive", "block"])
def test_suspend_resume_bit_identical(tmp_path, stepper):
    def build():
        s = SimServer(_cfg())
        s.submit(_req("plummer:24", stepper, 0.04), now=0.0)
        s.submit(_req("two_body:2", stepper, 0.04), now=0.0)
        s.submit(_req("king:20", stepper, 0.04, seed=3), now=0.0)
        return s

    straight = build()
    straight.run_until_drained()

    paused = build()
    paused.step(now=0.0)
    paused.step(now=1.0)
    paused.suspend(str(tmp_path / "ckpt"))
    resumed = SimServer.resume(str(tmp_path / "ckpt"))
    assert resumed.cfg == paused.cfg
    resumed.reports = list(paused.reports)
    resumed.run_until_drained()

    def key(reports):
        return sorted((r["request_id"], r["steps"], r["e1"], r["t_final"])
                      for r in reports)

    assert key(resumed.reports) == key(straight.reports)


# --------------------------------------------------------------------------
# admission-boundary validation
# --------------------------------------------------------------------------
def test_submit_rejects_unsized_spec():
    with pytest.raises(ScenarioError, match="SimRequest.spec.n"):
        SimServer(_cfg()).submit(SimRequest(spec=ScenarioSpec.parse(
            "plummer")))


def test_submit_rejects_oversized_request():
    with pytest.raises(ValueError, match="n_max=64"):
        SimServer(_cfg()).submit(_req("plummer:100"))


def test_submit_rejects_fixed_stepper():
    with pytest.raises(ValueError, match="not servable"):
        SimServer(_cfg()).submit(_req("plummer:24", stepper="fixed"))


def test_submit_rejects_nonpositive_deadline():
    with pytest.raises(ValueError, match="SimRequest.t_end"):
        SimServer(_cfg()).submit(_req("plummer:24", t_end=0.0))


def test_config_rejects_unaligned_n_max():
    with pytest.raises(ValueError, match="block_i-aligned"):
        SimServer(dataclasses.replace(_cfg(), n_max=65))
