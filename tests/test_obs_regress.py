"""repro.obs.regress units: trajectory I/O, comparability, gate semantics.

The synthetic-regression test is the CI contract: a 25% wall-per-event slip
against the committed baseline must FAIL the gate (``main`` returns the
job-failing exit code 1); incomparable explicit baselines must REFUSE
(exit 2), never silently compare.
"""

import json

import pytest

from repro.obs import regress


def record(wall=0.01, tiles=1000.0, edp=100.0, sha="aaa", **prov):
    """A minimal stamped bench_ci record with one row per gated sweep."""
    p = {"git_sha": sha, "schema_version": regress.BENCH_SCHEMA_VERSION,
         "jax_version": "0.4.37", "device_count": 2, **prov}
    return {
        "suite": "bench_ci",
        "stepper_modes": [
            {"stepper": "block", "wall_per_event_s": wall, "edp_Js": edp}],
        "block_compaction": [
            {"seed": 0, "wall_per_event_gather_s": wall,
             "tiles_gather": tiles}],
        "strategy_compaction": [
            {"seed": 0, "wall_per_event_gather_s": wall,
             "tiles_shard_max_gather": tiles / 2}],
        "provenance": p,
    }


def test_provenance_stamp_fields(tmp_path):
    p = regress.provenance(4, repo=str(tmp_path), jax_version="9.9.9")
    assert p["schema_version"] == regress.BENCH_SCHEMA_VERSION
    assert p["jax_version"] == "9.9.9" and p["device_count"] == 4
    assert p["git_sha"] == "unknown"  # tmp_path is not a git repo


def test_trajectory_roundtrip_and_append(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(path, record(sha="one"))
    records = regress.append_record(path, record(sha="two"))
    assert [r["provenance"]["git_sha"] for r in records] == ["one", "two"]
    doc = json.load(open(path))
    assert doc["format"] == "bench_ci_trajectory"
    assert doc["schema_version"] == regress.BENCH_SCHEMA_VERSION
    assert regress.load_trajectory(path) == records


def test_legacy_single_record_loads_as_trajectory(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    legacy = {"suite": "bench_ci", "unix_time": 123, "stepper_modes": []}
    json.dump(legacy, open(path, "w"))
    assert regress.load_trajectory(path) == [legacy]
    # a stamped append preserves the legacy record as history
    records = regress.append_record(path, record())
    assert records[0] == legacy and len(records) == 2


def test_load_rejects_unknown_shape(tmp_path):
    path = str(tmp_path / "x.json")
    json.dump({"something": "else"}, open(path, "w"))
    with pytest.raises(ValueError):
        regress.load_trajectory(path)


def test_tracked_metrics_flattening():
    m = regress.tracked_metrics(record(wall=0.02, tiles=640.0, edp=50.0))
    assert m["stepper_modes/block/wall_per_event_s"] == 0.02
    assert m["stepper_modes/block/edp_Js"] == 50.0
    assert m["block_compaction/seed0/tiles_gather"] == 640.0
    assert m["strategy_compaction/seed0/tiles_shard_max_gather"] == 320.0
    # zero / non-numeric values carry no regression signal
    assert "stepper_modes/none/wall_per_event_s" not in \
        regress.tracked_metrics({"stepper_modes": [
            {"stepper": "none", "wall_per_event_s": 0.0, "edp_Js": "n/a"}]})


def test_comparable_requires_matching_provenance():
    ok, _ = regress.comparable(record(), record())
    assert ok
    ok, reason = regress.comparable(record(), record(device_count=4))
    assert not ok and "device_count" in reason
    ok, reason = regress.comparable({"no": "stamp"}, record())
    assert not ok and "unstamped" in reason


def test_gate_passes_within_threshold(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(path, record(wall=0.0100, sha="base"))
    regress.append_record(path, record(wall=0.0115, sha="head"))  # +15%
    result = regress.check(path)
    assert result.ok and result.baseline_sha == "base"
    assert "PASS" in result.summary()


def test_synthetic_25pct_regression_fails_ci(tmp_path, capsys):
    """The acceptance contract: a 25% regression must fail the CI job."""
    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(path, record(wall=0.0100, sha="base"))
    regress.append_record(path, record(wall=0.0125, sha="head"))  # +25%
    result = regress.check(path)
    assert not result.ok
    regressed = {r.metric for r in result.regressions}
    assert "stepper_modes/block/wall_per_event_s" in regressed
    assert "block_compaction/seed0/wall_per_event_gather_s" in regressed
    # the CLI — the actual CI step — exits 1 (job failure)
    assert regress.main([path]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "REGRESSED" in out


def test_tiles_and_edp_regressions_gate_too(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(path, record(tiles=1000.0, edp=100.0, sha="base"))
    regress.append_record(path, record(tiles=1300.0, edp=130.0, sha="head"))
    regressed = {r.metric for r in regress.check(path).regressions}
    assert "block_compaction/seed0/tiles_gather" in regressed
    assert "stepper_modes/block/edp_Js" in regressed


def test_dropped_metric_is_a_regression(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(path, record(sha="base"))
    gutted = record(sha="head")
    gutted["block_compaction"] = []  # the sweep silently vanished
    regress.append_record(path, gutted)
    result = regress.check(path)
    assert not result.ok
    dropped = [r for r in result.regressions
               if r.metric.startswith("block_compaction/")]
    assert dropped and all(r.current == float("inf") for r in dropped)


def test_scan_skips_incomparable_baselines(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(path, record(sha="old-comparable"))
    regress.append_record(path, record(sha="other-jax", jax_version="0.5.0"))
    regress.append_record(path, record(sha="head"))
    result = regress.check(path)
    assert result.ok and result.baseline_sha == "old-comparable"
    assert any("other-jax" in n for n in result.notes)


def test_no_comparable_baseline_passes_vacuously(tmp_path):
    path = str(tmp_path / "BENCH_ci.json")
    json.dump({"suite": "bench_ci", "stepper_modes": []}, open(path, "w"))
    regress.append_record(path, record(sha="first-stamped"))
    result = regress.check(path)
    assert result.ok and not result.regressions
    assert any("vacuously" in n for n in result.notes)


def test_explicit_incomparable_baseline_refuses(tmp_path, capsys):
    cur = str(tmp_path / "cur.json")
    base = str(tmp_path / "base.json")
    regress.append_record(cur, record(sha="head"))
    regress.append_record(base, record(sha="base", device_count=8))
    with pytest.raises(ValueError):
        regress.check(cur, baseline_path=base)
    assert regress.main([cur, "--baseline", base]) == 2
    assert "REFUSED" in capsys.readouterr().out


def test_explicit_comparable_baseline_compares(tmp_path):
    cur = str(tmp_path / "cur.json")
    base = str(tmp_path / "base.json")
    regress.append_record(base, record(wall=0.0100, sha="base"))
    regress.append_record(cur, record(wall=0.0500, sha="head"))
    assert regress.main([cur, "--baseline", base]) == 1
    assert regress.main([cur, "--baseline", base, "--threshold", "10"]) == 0


def test_provenance_stamps_dtype_default_fp32(tmp_path):
    p = regress.provenance(2, repo=str(tmp_path), jax_version="9.9.9")
    assert p["dtype"] == "fp32"
    p = regress.provenance(2, repo=str(tmp_path), jax_version="9.9.9",
                           dtype="mixed")
    assert p["dtype"] == "mixed"


def test_cross_dtype_comparison_refused():
    """A mixed-precision record must never gate against an fp32 baseline —
    slower-but-cheaper arithmetic would read as a wall regression (or a
    speedup would mask one)."""
    ok, reason = regress.comparable(record(dtype="mixed"), record())
    assert not ok and "dtype" in reason
    ok, reason = regress.comparable(record(), record(dtype="mixed"))
    assert not ok and "dtype" in reason
    ok, _ = regress.comparable(record(dtype="mixed"), record(dtype="mixed"))
    assert ok


def test_absent_dtype_reads_as_fp32():
    """Records stamped before the precision axis existed (the committed
    history) compare against new fp32-stamped records — the gate must not go
    vacuous across the schema addition."""
    legacy = record()
    legacy["provenance"].pop("dtype", None)  # pre-axis stamp has no dtype
    ok, _ = regress.comparable(record(dtype="fp32"), legacy)
    assert ok
    ok, reason = regress.comparable(record(dtype="mixed"), legacy)
    assert not ok and "dtype" in reason


def test_cross_dtype_explicit_baseline_refuses(tmp_path, capsys):
    cur = str(tmp_path / "cur.json")
    base = str(tmp_path / "base.json")
    regress.append_record(cur, record(sha="head", dtype="mixed"))
    regress.append_record(base, record(sha="base", dtype="fp32"))
    assert regress.main([cur, "--baseline", base]) == 2
    assert "REFUSED" in capsys.readouterr().out


def test_precision_sweep_rows_tracked_per_dtype(tmp_path):
    """precision_sweep rows flatten under their own dtype key, so fp32 wall
    only ever compares against fp32 wall, mixed |dE/E| against mixed."""
    def sweep_record(sha, walls, des):
        r = record(sha=sha)
        r["precision_sweep"] = [
            {"dtype": d, "wall_per_event_s": w, "de_rel": e}
            for d, w, e in zip(("fp64", "fp32", "mixed"), walls, des)]
        return r

    m = regress.tracked_metrics(
        sweep_record("x", (0.04, 0.01, 0.02), (1e-12, 1e-7, 1e-4)))
    assert m["precision_sweep/fp32/wall_per_event_s"] == 0.01
    assert m["precision_sweep/mixed/wall_per_event_s"] == 0.02
    assert m["precision_sweep/mixed/de_rel"] == 1e-4

    path = str(tmp_path / "BENCH_ci.json")
    regress.append_record(
        path, sweep_record("base", (0.04, 0.01, 0.02), (1e-12, 1e-7, 1e-4)))
    # mixed |dE/E| blows past its own baseline -> regression, keyed by dtype
    regress.append_record(
        path, sweep_record("head", (0.04, 0.01, 0.02), (1e-12, 1e-7, 1e-2)))
    result = regress.check(path)
    assert not result.ok
    assert {r.metric for r in result.regressions} == \
        {"precision_sweep/mixed/de_rel"}


def test_committed_trajectory_is_loadable_and_gated():
    """The repo's own BENCH_ci.json must parse and pass its gate."""
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_ci.json")
    records = regress.load_trajectory(path)
    assert records, "committed BENCH_ci.json has no records"
    assert regress.main([path]) == 0
