"""repro.obs.metrics units: metric types, registry, snapshots, validation."""

import pytest

from repro.obs import metrics


def test_counter_monotone():
    c = metrics.Counter("c", unit="events")
    c.inc()
    c.inc(4.0)
    assert c.value == 5.0
    with pytest.raises(ValueError):
        c.inc(-1.0)


def test_gauge_holds_vectors():
    g = metrics.Gauge("g")
    g.set([1.0, 2.0])
    assert g.dump()["value"] == [1.0, 2.0]


def test_histogram_summary_and_percentiles():
    h = metrics.Histogram("h", unit="fraction")
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    d = h.dump()
    assert d["count"] == 4 and d["min"] == 0.1 and d["max"] == 0.4
    assert d["mean"] == pytest.approx(0.25)
    assert 0.1 <= d["p50"] <= 0.4 and 0.1 <= d["p95"] <= 0.4


def test_histogram_sample_cap_keeps_summary_exact():
    h = metrics.Histogram("h")
    for i in range(metrics.HISTOGRAM_SAMPLE_CAP + 10):
        h.observe(float(i))
    assert h.count == metrics.HISTOGRAM_SAMPLE_CAP + 10
    assert h.max == float(metrics.HISTOGRAM_SAMPLE_CAP + 9)
    assert len(h._samples) == metrics.HISTOGRAM_SAMPLE_CAP


def test_registry_get_or_create_and_kind_mismatch():
    reg = metrics.MetricsRegistry()
    c1 = reg.counter("sim.events", unit="events")
    assert reg.counter("sim.events") is c1
    with pytest.raises(TypeError):
        reg.gauge("sim.events")


def test_snapshot_schema_validates():
    reg = metrics.MetricsRegistry()
    reg.counter("a.count").inc(2)
    reg.gauge("a.gauge").set(7.5)
    reg.histogram("a.hist").observe(1.0)
    snap = reg.snapshot()
    assert snap["schema_version"] == metrics.METRICS_SCHEMA_VERSION
    assert snap["counters"]["a.count"]["value"] == 2.0
    assert snap["gauges"]["a.gauge"]["value"] == 7.5
    assert snap["histograms"]["a.hist"]["count"] == 1
    metrics.validate_snapshot(snap)  # must not raise


@pytest.mark.parametrize("mutate", [
    lambda s: s.pop("schema_version"),
    lambda s: s.update(schema_version=999),
    lambda s: s.pop("counters"),
    lambda s: s["counters"].update(bad="not-a-dict"),
    lambda s: s["counters"].update(bad={}),  # missing 'value'
])
def test_validate_snapshot_rejects_malformed(mutate):
    reg = metrics.MetricsRegistry()
    reg.counter("x").inc()
    snap = reg.snapshot()
    mutate(snap)
    with pytest.raises(ValueError):
        metrics.validate_snapshot(snap)


def test_validate_snapshot_rejects_non_dict():
    with pytest.raises(ValueError):
        metrics.validate_snapshot([1, 2, 3])


def test_block_tile_chain_launched_bound_dense():
    """The host-side tile-scheduling chain stays pinned: the tiles a block
    run actually launches never exceed the analytic occupancy bound
    (``hermite.block_level_occupancy`` at the tick's threshold level, the
    bucket the strategy path sizes from), which never exceeds the dense
    uncompacted schedule.  A regression in either direction — the bound
    under-counting (would truncate launches) or the bucket switch ignoring
    the bound (would erase the compaction win) — breaks the ordering."""
    from repro.sim import api

    report = api.run(api.SimConfig(
        scenario="binary_plummer", n=64, seed=1, stepper="block",
        compaction="gather", t_end=0.0625, dt_max=1.0 / 64, n_levels=4,
        block_i=16, block_j=16, eta=0.02, diag_every=8))
    c = report["metrics"]["counters"]
    g = report["metrics"]["gauges"]
    launched = c["sim.tiles_launched"]["value"]
    bound = g["sim.tiles_occupancy_bound"]["value"]
    dense = c["sim.tiles_dense_baseline"]["value"]
    assert 0 < launched <= bound <= dense
    # the hierarchy is real on this scenario: the bucket switch must beat
    # the dense schedule, and the analytic bound must be a true envelope
    # rather than a copy of either endpoint.
    assert launched < dense


def test_use_scopes_the_current_registry():
    outer = metrics.registry()
    with metrics.use() as reg:
        assert metrics.registry() is reg and reg is not outer
        metrics.registry().counter("scoped").inc()
        with metrics.use() as inner:  # nested scopes stack
            assert metrics.registry() is inner
        assert metrics.registry() is reg
    assert metrics.registry() is outer
    assert "scoped" not in outer.snapshot()["counters"]
