"""Pallas flash-attention kernel vs the XLA grouped-attention oracle,
swept over shapes/groups/blocks in interpret mode (CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models import layers

F32 = jnp.float32


def _qkv(b, sq, sk, h, kv, d, seed=0, dtype=F32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, sk, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, sk, kv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,sq,sk,h,kv,d,bq,bk", [
    (2, 256, 256, 8, 2, 64, 128, 128),
    (1, 512, 512, 4, 4, 64, 256, 128),    # MHA (g=1)
    (2, 128, 512, 8, 1, 32, 64, 256),     # MQA, rectangular
    (1, 256, 256, 16, 2, 128, 128, 64),   # wide heads
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference(b, sq, sk, h, kv, d, bq, bk, causal):
    if causal and sq != sk:
        pytest.skip("causal requires square for this contract")
    q, k, v = _qkv(b, sq, sk, h, kv, d)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = layers._attn_full(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16_inputs():
    q, k, v = _qkv(1, 256, 256, 4, 2, 64, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    ref = layers._attn_full(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=3e-2, atol=3e-2)


def test_flash_softmax_rows_sum_to_one_property():
    """With v = ones, attention output must be exactly ones (row-stochastic
    weights) — catches normalization bugs independent of the oracle."""
    q, k, _ = _qkv(2, 256, 256, 4, 2, 64, seed=5)
    v = jnp.ones((2, 256, 2, 64), F32)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


def test_flash_dispatch_equivalence_in_model():
    """cfg.attn_impl='flash' (marked region on CPU) is numerically identical
    to the xla path inside a full model forward."""
    import dataclasses

    from repro.distributed.shardings import MeshRules
    from repro.models import model, params as P
    from repro.models.config import ArchConfig

    rules = MeshRules.single_device()
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                     dtype="float32", attn_chunked_above=10 ** 9)
    pr = P.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 255)
    batch = {"tokens": toks, "labels": toks}
    a, _ = model.forward(cfg, rules, pr, batch)
    b, _ = model.forward(dataclasses.replace(cfg, attn_impl="flash"),
                         rules, pr, batch)
    assert float(jnp.abs(a - b).max()) == 0.0
