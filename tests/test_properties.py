"""Hypothesis property tests on system invariants."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from jax.sharding import PartitionSpec

from repro.distributed import compression
from repro.distributed.shardings import MeshRules, DEFAULT_RULES
from repro.kernels import ref

F32 = jnp.float32
COMMON = dict(deadline=None, max_examples=20,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _cloud(n, seed):
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.standard_normal((n, 3)))
    vel = jnp.asarray(rng.standard_normal((n, 3)) * 0.1)
    mass = jnp.asarray(rng.uniform(0.1, 1.0, n) / n)
    return pos, vel, mass


# ------------------------------------------------------------- N-body laws
@settings(**COMMON)
@given(n=st.integers(8, 96), seed=st.integers(0, 10_000))
def test_momentum_conservation(n, seed):
    """Newton's third law: sum_i m_i a_i == 0 for any cloud."""
    pos, vel, mass = _cloud(n, seed)
    acc, jerk, _ = ref.acc_jerk_pot(pos, vel, mass)
    f = jnp.sum(mass[:, None] * acc, axis=0)
    df = jnp.sum(mass[:, None] * jerk, axis=0)
    scale = float(jnp.abs(mass[:, None] * acc).sum()) + 1e-30
    assert float(jnp.abs(f).max()) / scale < 1e-10
    assert float(jnp.abs(df).max()) / (
        float(jnp.abs(mass[:, None] * jerk).sum()) + 1e-30) < 1e-10


@settings(**COMMON)
@given(n=st.integers(8, 64), seed=st.integers(0, 10_000),
       shift=st.floats(-50.0, 50.0))
def test_translation_invariance(n, seed, shift):
    pos, vel, mass = _cloud(n, seed)
    a1, j1, _ = ref.acc_jerk_pot(pos, vel, mass)
    a2, j2, _ = ref.acc_jerk_pot(pos + shift, vel, mass)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-8, atol=1e-10)
    np.testing.assert_allclose(np.asarray(j1), np.asarray(j2),
                               rtol=1e-8, atol=1e-10)


@settings(**COMMON)
@given(n=st.integers(8, 64), seed=st.integers(0, 10_000))
def test_permutation_equivariance(n, seed):
    """Relabeling particles permutes the outputs identically — the invariant
    behind EVERY distribution strategy (order-invariant source sweeps)."""
    pos, vel, mass = _cloud(n, seed)
    perm = np.random.default_rng(seed + 1).permutation(n)
    a1, j1, p1 = ref.acc_jerk_pot(pos, vel, mass)
    a2, j2, p2 = ref.acc_jerk_pot(pos[perm], vel[perm], mass[perm])
    np.testing.assert_allclose(np.asarray(a1[perm]), np.asarray(a2),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(p1[perm]), np.asarray(p2),
                               rtol=1e-9, atol=1e-12)


@settings(**COMMON)
@given(n=st.integers(8, 48), seed=st.integers(0, 10_000),
       split=st.integers(1, 7))
def test_source_block_additivity(n, seed, split):
    """acc(targets; all sources) == sum of acc over source blocks — the
    algebraic fact the replicated/two_level/ring strategies rely on."""
    pos, vel, mass = _cloud(n, seed)
    a_all, j_all, p_all = ref.acc_jerk_pot(pos, vel, mass)
    k = max(1, (n * split) // 8)
    a_sum = jnp.zeros_like(a_all)
    j_sum = jnp.zeros_like(j_all)
    p_sum = jnp.zeros_like(p_all)
    for lo in range(0, n, k):
        hi = min(lo + k, n)
        a, j, p = ref.acc_jerk_pot_rect(pos, vel, pos[lo:hi], vel[lo:hi],
                                        mass[lo:hi])
        a_sum, j_sum, p_sum = a_sum + a, j_sum + j, p_sum + p
    np.testing.assert_allclose(np.asarray(a_sum), np.asarray(a_all),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(j_sum), np.asarray(j_all),
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np.asarray(p_sum), np.asarray(p_all),
                               rtol=1e-9, atol=1e-12)


# ------------------------------------------------------------- compression
@settings(**COMMON)
@given(seed=st.integers(0, 10_000), scale=st.floats(1e-6, 1e6))
def test_quantize_bound_any_scale(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * scale, F32)
    q, s = compression.quantize(x)
    err = jnp.abs(compression.dequantize(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 * (1 + 1e-5) + 1e-30


# ------------------------------------------------------------- sharding rules
class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as _np

        self.axis_names = names
        self.devices = _np.empty(shape)
        self.size = int(_np.prod(shape))


@settings(**COMMON)
@given(
    d0=st.sampled_from([1, 2, 3, 4, 6, 8, 16, 48, 256]),
    d1=st.sampled_from([1, 2, 5, 8, 16, 32, 160, 1024]),
    logical=st.lists(
        st.sampled_from([None] + list(DEFAULT_RULES)), min_size=2,
        max_size=2),
)
def test_spec_never_reuses_axis_and_always_divides(d0, d1, logical):
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    rules = MeshRules(mesh=mesh, rules=dict(DEFAULT_RULES))
    spec = rules.spec((d0, d1), logical)
    assert isinstance(spec, PartitionSpec)
    used = []
    sizes = {"pod": 2, "data": 16, "model": 16}
    for dim, entry in zip((d0, d1), spec):
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        prod = 1
        for a in axes:
            assert a not in used, spec
            used.append(a)
            prod *= sizes[a]
        assert dim % prod == 0, (spec, dim, prod)
