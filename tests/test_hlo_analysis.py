"""The trip-count-aware HLO analyzer (launch/hlo_analysis.py) — the roofline's
measurement instrument — validated against ground truth."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_unrolled_dot_flops_exact():
    k = 4
    def f(x, w):
        y = x
        for i in range(k):
            y = y @ w[i]
        return y
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((k, 128, 128), jnp.float32)
    a = H.analyze(_compile(f, x, w).as_text())
    expected = 2 * 64 * 128 * 128 * k
    assert abs(a["dot_flops"] - expected) / expected < 0.01


def test_scan_trip_count_multiplies():
    """The core fix: a k-step scan counts k x the body (XLA counts it once)."""
    k = 16
    def f(x, w):
        def body(c, wl):
            return c @ wl, None
        y, _ = jax.lax.scan(body, x, w)
        return y
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((k, 128, 128), jnp.float32)
    co = _compile(f, x, w)
    a = H.analyze(co.as_text())
    expected = 2 * 64 * 128 * 128 * k
    assert abs(a["dot_flops"] - expected) / expected < 0.01
    ca = co.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] < expected / 2   # documents the XLA undercount


def test_nested_scan_multiplies():
    k_out, k_in = 3, 5
    def f(x, w):
        def outer(c, wg):
            def inner(ci, wl):
                return ci @ wl, None
            c, _ = jax.lax.scan(inner, c, wg)
            return c, None
        y, _ = jax.lax.scan(outer, x, w)
        return y
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((k_out, k_in, 64, 64), jnp.float32)
    a = H.analyze(_compile(f, x, w).as_text())
    expected = 2 * 32 * 64 * 64 * k_out * k_in
    assert abs(a["dot_flops"] - expected) / expected < 0.01


def test_elementwise_and_bytes_counted():
    def f(x):
        return jnp.tanh(x) + x * 2.0
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    a = H.analyze(_compile(f, x).as_text())
    n = 1024 * 1024
    assert a["flops"] >= 2 * n            # tanh(8n/weighted) + add + mul fused
    assert a["hbm_bytes"] >= 2 * n * 4    # >= read x + write result


def test_multiplier_fixpoint_terminates_on_synthetic():
    hlo = """
ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  ROOT %t = f32[8]{0} tanh(%p0)
}
"""
    a = H.analyze(hlo)
    assert a["flops"] == 8 * 8.0          # tanh weight 8


def test_collective_parsing_iota_and_list():
    hlo = """
%body (p: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p = (s32[], f32[128]{0}) parameter(0)
  %g = f32[128]{0} get-tuple-element(%p), index=1
  %i = s32[] get-tuple-element(%p), index=0
  %c1 = s32[] constant(1)
  %ip = s32[] add(%i, %c1)
  %ag = f32[128]{0} all-reduce(%g), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%sum
  ROOT %r = (s32[], f32[128]{0}) tuple(%ip, %ag)
}
%cond (p2: (s32[], f32[128])) -> pred[] {
  %p2 = (s32[], f32[128]{0}) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %c10 = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %c10), direction=LT
}
ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128]{0} parameter(0)
  %c0 = s32[] constant(0)
  %tup = (s32[], f32[128]{0}) tuple(%c0, %x)
  %w = (s32[], f32[128]{0}) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128]{0} get-tuple-element(%w), index=1
}
"""
    a = H.analyze(hlo)
    # all-reduce of 512 B in group of 4, ring 2x(G-1)/G, x10 trips
    expected = 10 * 2.0 * 512 * 3 / 4
    assert abs(a["collectives"]["all-reduce"] - expected) < 1e-6
    assert a["collectives"]["total"] == a["collectives"]["all-reduce"]
