"""Test fixtures.  x64 is enabled (the paper's FP64 host precision); device
count stays at 1 — multi-device strategy tests run in subprocesses."""

import jax
import pytest

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
