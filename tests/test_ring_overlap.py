"""Ring overlap schedule: p-1 prefetched shifts, bit-identical results.

The ring strategy's historical (``sync``) sweep shifted the source window
*after* each local kernel, all ``p`` rounds — so the last round's shifted
window arrived only to be discarded (a dead ``ppermute`` per pass).  The
``overlap`` schedule (the default) unrolls the sweep and puts round
``k+1``'s window in flight *before* round ``k``'s kernels: exactly
``p - 1`` shifts per pass, and on hardware with async collectives the hop
hides behind the local interaction block.

Locked here (forced 2-device mesh, subprocess):

* **Collective count**: the ``ring.shifts_issued`` counter (incremented at
  trace time, fori_loop trip counts included) pins exactly ``2 * (p - 1)``
  shift rounds per traced overlap evaluation (acc + snap passes) vs
  ``2 * p`` for the sync baseline — for every kernel x dtype, and for the
  block evaluator under both compactions.
* **Bitwise**: overlap == sync on every output leaf (the accumulation
  order is untouched; only the shift timing moves), for every kernel x
  dtype, both compactions, and the analytic ``n_bound`` path.
"""

import os
import subprocess
import sys

import pytest

from repro.core import strategies

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import hermite
from repro.core.strategies import (make_strategy_evaluator,
                                   make_strategy_block_evaluator)
from repro.obs import metrics as obs_metrics
from repro.sim import scenarios

P_DEV = 2
assert len(jax.devices()) == P_DEV
state = scenarios.make("plummer", n=64, seed=3)


def shifts(reg):
    m = reg._metrics.get("ring.shifts_issued")
    return 0.0 if m is None else float(m.value)


# ---- lockstep evaluator: every kernel x dtype --------------------------
for impl in ("xla", "pallas_interpret"):
    for dtype in ("fp32", "mixed"):
        outs, counts = {}, {}
        for mode in ("overlap", "sync"):
            reg = obs_metrics.MetricsRegistry()
            with obs_metrics.use(reg):
                ev = make_strategy_evaluator(
                    "ring", devices=jax.devices(), impl=impl, dtype=dtype,
                    ring_mode=mode)
                outs[mode] = hermite.initialize(state, ev)
                jax.block_until_ready(outs[mode].pos)
            counts[mode] = shifts(reg)
        tag = (impl, dtype)
        # exactly p-1 shift rounds per traced pass (2 passes: acc + snap);
        # the sync baseline pays p, the last one computed-and-discarded
        assert counts["overlap"] == 2 * (P_DEV - 1), (tag, counts)
        assert counts["sync"] == 2 * P_DEV, (tag, counts)
        for leaf in ("pos", "vel", "acc", "jerk", "snap", "crackle", "pot"):
            a = np.asarray(getattr(outs["overlap"], leaf))
            b = np.asarray(getattr(outs["sync"], leaf))
            assert np.array_equal(a, b), (tag, leaf)
        print(f"lockstep {impl}/{dtype}: OK shifts {counts}")

# ---- block evaluator: both compactions + the analytic-bound path -------
mask = np.zeros(64, bool)
mask[:24] = True
ap = jnp.zeros_like(state.pos)
for compaction in ("none", "gather"):
    outs, counts = {}, {}
    for mode in ("overlap", "sync"):
        reg = obs_metrics.MetricsRegistry()
        with obs_metrics.use(reg):
            bev = make_strategy_block_evaluator(
                "ring", devices=jax.devices(), impl="xla", block_i=8,
                block_j=8, compaction=compaction, ring_mode=mode)
            ev, tiles = bev(state.pos, state.vel, ap, state.mass,
                            jnp.asarray(mask))
            jax.block_until_ready(ev.acc)
        outs[mode] = (ev, np.asarray(tiles))
        counts[mode] = shifts(reg)
    assert counts["overlap"] == 2 * (P_DEV - 1), (compaction, counts)
    assert counts["sync"] == 2 * P_DEV, (compaction, counts)
    for leaf in ("acc", "jerk", "snap", "pot"):
        a = np.asarray(getattr(outs["overlap"][0], leaf))
        b = np.asarray(getattr(outs["sync"][0], leaf))
        assert np.array_equal(a, b), (compaction, leaf)
    assert np.array_equal(outs["overlap"][1], outs["sync"][1])
    print(f"block {compaction}: OK shifts {counts}")

# host-side analytic bound == measured path, bit for bit (the bound is
# exact for the block schedule, so bucket, tiles and physics all agree)
bev = make_strategy_block_evaluator(
    "ring", devices=jax.devices(), impl="xla", block_i=8, block_j=8,
    compaction="gather")
ev_m, t_m = bev(state.pos, state.vel, ap, state.mass, jnp.asarray(mask))
ev_b, t_b = bev(state.pos, state.vel, ap, state.mass, jnp.asarray(mask),
                jnp.asarray([24, 0], jnp.int32))
for leaf in ("acc", "jerk", "snap", "pot"):
    assert np.array_equal(np.asarray(getattr(ev_m, leaf)),
                          np.asarray(getattr(ev_b, leaf))), leaf
assert np.array_equal(np.asarray(t_m), np.asarray(t_b))
print("bound-path: OK")
print("RING-OVERLAP: OK")
"""


@pytest.mark.slow
def test_ring_overlap_2dev_counts_and_bitwise():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for impl in ("xla", "pallas_interpret"):
        for dtype in ("fp32", "mixed"):
            assert f"lockstep {impl}/{dtype}: OK" in res.stdout
    assert "block none: OK" in res.stdout
    assert "block gather: OK" in res.stdout
    assert "bound-path: OK" in res.stdout
    assert "RING-OVERLAP: OK" in res.stdout


def test_ring_mode_validation():
    with pytest.raises(ValueError, match="ring_mode"):
        strategies.make_strategy_evaluator("ring", ring_mode="eager")
    with pytest.raises(ValueError, match="ring_mode"):
        strategies.make_strategy_block_evaluator("ring", ring_mode="eager")
    assert strategies.RING_MODES == ("overlap", "sync")
