"""Ahmad-Cohen neighbor scheme: windows, ordering, capacity, physics.

The load-bearing invariant is **coverage**: source block J joins target
block I's window whenever the box-to-box distance of their AABBs is within
the neighbor radius, and the box distance lower-bounds every cross-block
pair distance — so no pair inside the radius is ever evaluated through the
(approximate) far field.  The property is pinned twice: a deterministic
seeded grid that always runs, and a Hypothesis search over the same check
when the package is available (the grid is the floor, not the ceiling).
Alongside: the ORB ordering is a valid permutation that preserves the
padding suffix, window capacity never truncates (overflow degrades to the
exact full-window result), and the split trajectory agrees with all-pairs
evaluation within the far-field prediction tier.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
    COMMON = dict(deadline=None, max_examples=20,
                  suppress_health_check=[hypothesis.HealthCheck.too_slow])
except ImportError:          # the container may not ship hypothesis
    HAVE_HYPOTHESIS = False

from repro.kernels import neighbor, ops
from repro.sim import ensemble as ens
from repro.sim import scenarios


def _cloud(n, seed, spread=1.0):
    rng = np.random.default_rng(seed)
    # lognormal radii: dense core + sparse halo, the geometry that breaks
    # naive (bounding-sphere) window tests
    r = rng.lognormal(mean=0.0, sigma=spread, size=n)
    u = rng.standard_normal((n, 3))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    return jnp.asarray(u * r[:, None])


# ------------------------------------------------------------ ORB ordering
def _check_kd_perm(n, n_active, seed):
    n_active = min(n_active, n)
    pos = _cloud(n, seed)
    valid = jnp.arange(n) < n_active
    perm = np.asarray(neighbor.kd_perm(pos, valid, leaf=8))
    assert sorted(perm.tolist()) == list(range(n))
    # invalid rows stay a right-aligned suffix in original relative order
    np.testing.assert_array_equal(perm[n_active:], np.arange(n_active, n))
    # valid rows land in the prefix (no padding row interleaves real rows)
    assert set(perm[:n_active].tolist()) == set(range(n_active))


@pytest.mark.parametrize("n,n_active,seed",
                         [(8, 8, 0), (33, 20, 1), (96, 96, 2),
                          (100, 37, 3), (200, 111, 4)])
def test_kd_perm_is_permutation_with_padding_suffix(n, n_active, seed):
    _check_kd_perm(n, n_active, seed)


def test_kd_perm_sort_shrinks_windows():
    """The point of the ORB ordering: windows over sorted index blocks are
    much smaller than over arrival-order blocks (which each span the whole
    cloud and select every source block)."""
    n, bi = 512, 32
    pos = _cloud(n, seed=3)
    valid = jnp.ones(n, bool)

    def mean_window(p):
        _, win_cnt = neighbor.build_windows(p, valid, block_i=bi,
                                            block_j=bi, radius=0.25)
        return float(np.asarray(win_cnt).mean())

    perm = neighbor.kd_perm(pos, valid, leaf=bi)
    unsorted, srt = mean_window(pos), mean_window(pos[perm])
    assert srt < 0.5 * unsorted, (srt, unsorted)


# ------------------------------------------------- window coverage (tentpole)
def _check_coverage(n, n_active, seed, radius, sort):
    """No valid pair within the neighbor radius may miss its window —
    sorted or not (the sort only changes how TIGHT windows are, never
    whether they cover)."""
    n_active = min(n_active, n)
    bi = bj = 8
    pos = _cloud(n, seed)
    valid = jnp.arange(n) < n_active
    if sort:
        perm = neighbor.kd_perm(pos, valid, leaf=bi)
        pos = pos[perm]
    win_idx, win_cnt = neighbor.build_windows(
        pos, valid, block_i=bi, block_j=bj, radius=radius)
    win_idx, win_cnt = np.asarray(win_idx), np.asarray(win_cnt)
    p = np.asarray(pos)[:n_active]
    d = np.linalg.norm(p[:, None] - p[None, :], axis=-1)
    ti, sj = np.nonzero(d <= radius)
    for i, j in zip(ti.tolist(), sj.tolist()):
        tb, sb = i // bi, j // bj
        assert sb in win_idx[tb, : win_cnt[tb]], (
            f"pair ({i},{j}) d={d[i, j]:.4f} <= {radius}: source block "
            f"{sb} missing from target block {tb}'s window")


@pytest.mark.parametrize("n,n_active,seed,radius,sort", [
    (16, 16, 0, 0.25, True), (64, 64, 1, 0.5, True),
    (64, 40, 2, 1.0, True), (160, 160, 3, 0.1, True),
    (96, 96, 4, 0.5, False), (100, 61, 5, 2.0, False),
    (64, 9, 6, 0.01, True),
])
def test_no_pair_inside_radius_is_dropped(n, n_active, seed, radius, sort):
    _check_coverage(n, n_active, seed, radius, sort)


if HAVE_HYPOTHESIS:
    @settings(**COMMON)
    @given(n=st.integers(8, 200), n_active=st.integers(4, 200),
           seed=st.integers(0, 10_000))
    def test_kd_perm_property(n, n_active, seed):
        _check_kd_perm(n, n_active, seed)

    @settings(**COMMON)
    @given(n=st.integers(16, 160), n_active=st.integers(8, 160),
           seed=st.integers(0, 10_000),
           radius=st.floats(0.01, 2.0), sort=st.booleans())
    def test_no_pair_dropped_property(n, n_active, seed, radius, sort):
        _check_coverage(n, n_active, seed, radius, sort)


def test_empty_blocks_never_selected_and_select_nothing():
    n, bi, bj = 64, 8, 8
    pos = _cloud(n, seed=7)
    valid = jnp.arange(n) < 20          # blocks 3..7 are all-padding
    win_idx, win_cnt = neighbor.build_windows(
        pos, valid, block_i=bi, block_j=bj, radius=1e9)
    win_idx, win_cnt = np.asarray(win_idx), np.asarray(win_cnt)
    # empty target blocks select nothing (they must not widen the bucket)
    assert (win_cnt[3:] == 0).all()
    # occupied targets select only occupied sources, even at huge radius
    for tb in range(3):
        assert set(win_idx[tb, : win_cnt[tb]].tolist()) <= {0, 1, 2}


# --------------------------------------------- capacity: never underestimate
def test_source_caps_last_bucket_is_full_extent():
    plan = ops.CapacityPlan(96, 96, 8, 8, sources="neighbor")
    caps = plan.source_caps
    assert caps[-1] == 96          # overflow bucket == exact full window
    assert all(c % 8 == 0 for c in caps)
    # bucket never underestimates: selected cap >= requested rows
    for rows in range(0, 97, 8):
        assert caps[int(plan.source_bucket(rows))] >= rows


def test_overflow_falls_back_to_full_window_exactly():
    """radius=inf forces every window to the full source extent: the engine
    must count overflow fallbacks AND reproduce the all-pairs trajectory
    (fallback is the exact computation, never a truncation)."""
    state = scenarios.make("binary_plummer", 64, seed=1)
    kw = dict(t_end=0.03125, dt_max=1.0 / 64, n_levels=3, eta=0.02,
              impl="fp64", block_i=16, block_j=16)
    sorted_state = ens.spatial_sort_state(state, leaf=16)
    full, cf = ens.evolve_ensemble_block([sorted_state], **kw)
    nbr, cn = ens.evolve_ensemble_block(
        [state], sources="neighbor", neighbor_radius=1e9,
        refresh_levels=0, **kw)
    assert int(cn.nbr.n_overflow[0]) > 0
    assert int(cn.n_events[0]) == int(cf.n_events[0])
    np.testing.assert_allclose(np.asarray(nbr.pos[0]),
                               np.asarray(full.pos[0]), rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(nbr.vel[0]),
                               np.asarray(full.vel[0]), rtol=0, atol=1e-12)


# ------------------------------------------------- split vs all-pairs physics
def test_neighbor_split_matches_all_pairs():
    """Finite radius: the regular+irregular split stays within the far-field
    prediction tier of the all-pairs trajectory, and conserves energy at the
    same order.  Compared in the engine's sorted row order."""
    state = scenarios.make("binary_plummer", 64, seed=1)
    kw = dict(t_end=0.0625, dt_max=1.0 / 64, n_levels=4, eta=0.02,
              impl="fp64", block_i=16, block_j=16)
    sorted_state = ens.spatial_sort_state(state, leaf=16)
    full, _ = ens.evolve_ensemble_block([sorted_state], **kw)
    nbr, carry = ens.evolve_ensemble_block(
        [state], sources="neighbor", neighbor_radius=0.5,
        refresh_levels=2, **kw)
    assert int(carry.nbr.n_refresh[0]) > 0
    np.testing.assert_allclose(np.asarray(nbr.pos[0]),
                               np.asarray(full.pos[0]), rtol=0, atol=5e-7)
    np.testing.assert_allclose(np.asarray(nbr.vel[0]),
                               np.asarray(full.vel[0]), rtol=0, atol=5e-5)

    def energy(s):
        ke = 0.5 * jnp.sum(s.mass[0] * jnp.sum(s.vel[0] ** 2, axis=1))
        return float(ke + 0.5 * jnp.sum(s.mass[0] * s.pot[0]))

    e_full, e_nbr = energy(full), energy(nbr)
    assert abs((e_nbr - e_full) / e_full) < 1e-6


def test_full_sources_ignore_neighbor_knobs():
    """sources='full' is the historical path: the neighbor knobs must not
    leak into it (bit-identical trajectories for any radius)."""
    state = scenarios.make("plummer", 32, seed=0)
    kw = dict(t_end=0.03125, dt_max=1.0 / 64, n_levels=3, eta=0.02,
              impl="fp64", block_i=16, block_j=16, sources="full")
    a, ca = ens.evolve_ensemble_block([state], neighbor_radius=0.1, **kw)
    b, cb = ens.evolve_ensemble_block([state], neighbor_radius=7.0, **kw)
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    assert ca.nbr is None and cb.nbr is None


# ---------------------------------------------------------- config plumbing
def test_sim_config_neighbor_validation():
    from repro.sim import api
    good = api.SimConfig(stepper="block", sources="neighbor", n=32,
                         t_end=0.01)
    assert api.validate_config(good) == "block"
    with pytest.raises(ValueError, match="sources"):
        api.validate_config(api.SimConfig(sources="nope"))
    with pytest.raises(ValueError, match="block"):
        api.validate_config(api.SimConfig(sources="neighbor"))  # adaptive
    with pytest.raises(ValueError, match="compaction"):
        api.validate_config(api.SimConfig(
            stepper="block", sources="neighbor", compaction="gather"))
    with pytest.raises(ValueError, match="strategy"):
        api.validate_config(api.SimConfig(
            stepper="block", sources="neighbor", strategy="ring"))
    meta = good.meta()
    assert meta["sources"] == "neighbor"
    assert meta["neighbor_radius"] == good.neighbor_radius
    assert meta["refresh_levels"] == good.refresh_levels


def test_api_run_reports_neighbor_telemetry():
    from repro.sim import api
    report = api.run(api.SimConfig(
        scenario="plummer", n=64, stepper="block", sources="neighbor",
        neighbor_radius=0.5, t_end=0.0625, dtype="fp32",
        block_i=16, block_j=16, n_levels=4, diag_every=8))
    assert report["de_rel"] < 1e-3
    assert report["neighbor_refreshes"] > 0
    assert "neighbor_overflows" in report
    counters = report["metrics"]["counters"]
    assert counters["sim.neighbor_refreshes"]["value"] > 0
    occ = report["metrics"]["histograms"]["sim.neighbor_occupancy"]
    assert 0.0 <= occ["min"] and occ["max"] <= 1.0
    assert report["runs"][0]["neighbor_refreshes"] > 0


def test_serve_neighbor_pod_round_trip(tmp_path):
    """A neighbor-sources block pod admits, advances, suspends and resumes
    bit-identically (the NeighborCarry template must round-trip)."""
    from repro.serve.sim_engine import ServerConfig, SimRequest, SimServer
    from repro.sim.scenarios import ScenarioSpec
    cfg = ServerConfig(slots_per_pod=2, n_max=128, chunk_events=8,
                       dtype="fp32", eta=0.02, sources="neighbor",
                       neighbor_radius=0.5, block_i=16, block_j=16)
    srv = SimServer(cfg)
    req = SimRequest(spec=ScenarioSpec.parse("plummer:64", seed=0),
                     stepper="block", t_end=0.0625)
    srv.submit(req)
    srv.step()
    pod = next(iter(srv.pods.values()))
    assert pod.carry is not None and pod.carry.nbr is not None
    srv.suspend(str(tmp_path))
    srv2 = SimServer.resume(str(tmp_path))
    pod2 = next(iter(srv2.pods.values()))
    assert pod2.carry.nbr is not None
    np.testing.assert_array_equal(np.asarray(pod2.carry.nbr.win_cnt),
                                  np.asarray(pod.carry.nbr.win_cnt))
    srv.step()
    srv2.step()
    p1 = next(iter(srv.pods.values())).batched.pos
    p2 = next(iter(srv2.pods.values())).batched.pos
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))


def test_server_config_rejects_neighbor_with_gather():
    from repro.serve.sim_engine import ServerConfig
    with pytest.raises(ValueError, match="compaction"):
        ServerConfig(sources="neighbor", compaction="gather").validate()


def test_spatial_sort_leaf_divides_blocks():
    """The entry points sort with leaf = gcd(block_i, block_j), so every
    kernel block of the sorted rows is a whole number of ORB cells."""
    assert math.gcd(16, 64) == 16
    state = scenarios.make("plummer", 96, seed=0)
    srt = ens.spatial_sort_state(state, leaf=8)
    # same multiset of rows
    np.testing.assert_allclose(
        np.asarray(jnp.sort(srt.mass)), np.asarray(jnp.sort(state.mass)),
        rtol=0, atol=0)
    assert float(jnp.abs(jnp.sort(srt.pos[:, 0])
                         - jnp.sort(state.pos[:, 0])).max()) == 0.0
