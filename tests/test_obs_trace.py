"""repro.obs.trace units: span recording, nesting, export, module scoping."""

import json

from repro.obs import trace


def test_null_tracer_is_inert():
    t = trace.NullTracer()
    assert not t.enabled
    with t.span("anything", foo=1):
        pass
    t.add_span("x", 0.0, 1.0)
    t.instant("y")
    assert t.export("/nonexistent/should/never/be/written.json") is None


def test_span_records_complete_event():
    t = trace.SpanTracer()
    with t.span("outer", key="v"):
        pass
    (ev,) = t.events
    assert ev["name"] == "outer" and ev["ph"] == "X"
    assert ev["dur"] >= 0.001 and ev["args"] == {"key": "v"}


def test_nested_spans_contained_in_time():
    t = trace.SpanTracer()
    with t.span("outer"):
        with t.span("inner"):
            pass
    by = {e["name"]: e for e in t.events}
    outer, inner = by["outer"], by["inner"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]


def test_add_span_synthetic_and_instant():
    t = trace.SpanTracer()
    t.add_span("event", 10.0, 5.0, args={"synthetic": True})
    t.add_span("degenerate", 0.0, 0.0)  # dur clamped to a visible sliver
    t.instant("marker", n=3)
    by = {e["name"]: e for e in t.events}
    assert by["event"]["args"]["synthetic"] is True
    assert by["degenerate"]["dur"] == 0.001
    assert by["marker"]["ph"] == "i"


def test_export_chrome_trace_json(tmp_path):
    t = trace.SpanTracer()
    t.add_span("b", 5.0, 1.0)
    t.add_span("a", 1.0, 10.0)
    path = t.export(str(tmp_path / "sub" / "trace.json"))  # creates parents
    doc = json.load(open(path))
    assert doc["otherData"]["schema_version"] == trace.TRACE_SCHEMA_VERSION
    assert doc["otherData"]["producer"] == "repro.obs.trace"
    evs = doc["traceEvents"]
    # sorted by (tid, ts) as Perfetto's importer expects
    assert [e["name"] for e in evs] == ["a", "b"]
    for e in evs:
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)


def test_module_tracer_scoping(tmp_path):
    assert not trace.get_tracer().enabled  # default is the null tracer
    out = tmp_path / "t.json"
    with trace.tracing(str(out)) as t:
        assert trace.get_tracer() is t
        with trace.get_tracer().span("scoped"):
            pass
    assert not trace.get_tracer().enabled  # restored on exit
    assert json.load(open(out))["traceEvents"][0]["name"] == "scoped"


def test_set_tracer_returns_previous():
    live = trace.SpanTracer()
    prev = trace.set_tracer(live)
    try:
        assert trace.get_tracer() is live
    finally:
        trace.set_tracer(prev)
    assert trace.get_tracer() is prev
