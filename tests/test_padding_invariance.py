"""Padding-invariance: zero-mass rows change NOTHING, at any padded size.

The mask contract behind every padded path in the repo (kernel block
alignment, strategy device alignment, ragged-N ensemble packing): forces,
jerks, snaps, potentials and energies of the N active particles are
identical — within FP32 summation-order tolerance — whether evaluated at N
or padded to any N_max, for both the reference XLA op and the tiled Pallas
kernel, including under ``jax.vmap``.  Property-based (hypothesis) variants
sweep sizes when hypothesis is installed; the parameterized variants always
run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nbody
from repro.kernels import ops
from repro.sim import ensemble as ens, scenarios

F32 = jnp.float32
# fp32 evaluation: padding only reassociates the source-axis reduction
ATOL, RTOL = 2e-6, 2e-5
IMPLS = ("xla", "pallas_interpret")


def _cloud(n, seed):
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.standard_normal((n, 3)), F32)
    vel = jnp.asarray(rng.standard_normal((n, 3)) * 0.1, F32)
    mass = jnp.asarray(rng.uniform(0.5, 1.5, n) / n, F32)
    return pos, vel, mass


def _padded(pos, vel, mass, extra, seed):
    """Append ``extra`` zero-mass rows at RANDOM positions (harsher than
    zeros: any leak of a padding row's position into active results shows)."""
    rng = np.random.default_rng(seed + 1)
    ep = jnp.asarray(rng.standard_normal((extra, 3)) * 2.0, F32)
    ev = jnp.asarray(rng.standard_normal((extra, 3)), F32)
    return (jnp.concatenate([pos, ep]), jnp.concatenate([vel, ev]),
            jnp.concatenate([mass, jnp.zeros((extra,), F32)]))


def _check_invariant(n, extra, seed, impl, block=128, dtype="fp32"):
    pos, vel, mass = _cloud(n, seed)
    pp, vp, mp = _padded(pos, vel, mass, extra, seed)
    kw = dict(impl=impl, block_i=block, block_j=block, dtype=dtype)
    a, j, p = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, **kw)
    ap, jp_, ppot = ops.acc_jerk_pot_rect(pp, vp, pp, vp, mp, **kw)
    np.testing.assert_allclose(ap[:n], a, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(jp_[:n], j, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(ppot[:n], p, rtol=RTOL, atol=ATOL)
    s = ops.snap_rect(pos, vel, a, pos, vel, a, mass, **kw)
    sp = ops.snap_rect(pp, vp, ap, pp, vp, ap, mp, **kw)
    np.testing.assert_allclose(sp[:n], s, rtol=10 * RTOL, atol=10 * ATOL)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("n,extra", [(32, 1), (48, 80), (100, 28), (2, 62)])
def test_forces_invariant_under_padding(n, extra, impl):
    _check_invariant(n, extra, seed=3, impl=impl)


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("n,extra", [(32, 1), (48, 80), (100, 28), (2, 62)])
def test_forces_invariant_under_padding_mixed(n, extra, impl):
    """dtype='mixed' keeps the mask contract at the SAME tolerance as fp32:
    bf16 rounding is per-pair deterministic and the padding rows contribute
    exact zeros, so the padded reduction reassociates nothing new."""
    _check_invariant(n, extra, seed=3, impl=impl, dtype="mixed")


@pytest.mark.parametrize("impl", IMPLS)
def test_forces_invariant_under_padding_vmapped(impl):
    """The same invariance through jax.vmap (the ensemble engine's path)."""
    b, n, extra = 3, 40, 24
    unpadded, padded = [], []
    for s in range(b):
        pos, vel, mass = _cloud(n, 100 + s)
        unpadded.append((pos, vel, mass))
        padded.append(_padded(pos, vel, mass, extra, 100 + s))
    stack = lambda xs: tuple(jnp.stack(z) for z in zip(*xs))  # noqa: E731
    kw = dict(impl=impl, block_i=128, block_j=128)
    f = jax.vmap(lambda p, v, m: ops.acc_jerk_pot_rect(p, v, p, v, m, **kw))
    a, j, _ = f(*stack(unpadded))
    ap, jp_, _ = f(*stack(padded))
    np.testing.assert_allclose(ap[:, :n], a, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(jp_[:, :n], j, rtol=RTOL, atol=ATOL)


def test_energies_invariant_under_padding():
    """Mass-weighting annihilates padding rows EXACTLY (their mass and
    masked pot are zero); the active rows' potentials carry only the fp32
    reassociation noise of the evaluator's longer source reduction."""
    state = scenarios.make("plummer", 24, seed=5)
    padded = scenarios.pad_state(state, 40)
    assert float(jnp.sum(padded.mass[24:])) == 0.0
    batched, n_active = scenarios.build_padded(
        [scenarios.Scenario(name="plummer", n=24, seed=5)], n_max=40)
    init = ens.ensemble_initialize(batched, n_active=n_active)
    assert float(jnp.abs(init.pot[0, 24:]).sum()) == 0.0   # masked targets
    e_pad = float(ens.batched_total_energy(init)[0])
    e_ref = float(nbody.total_energy(
        ens.unstack_states(ens.ensemble_initialize(
            ens.stack_states([state])))[0]))
    assert np.isclose(e_pad, e_ref, rtol=1e-6, atol=1e-7)


def test_massive_padding_row_is_detected():
    """Canary: if a 'padding' particle DID carry mass, the active particles'
    forces change well beyond tolerance — i.e. this suite can actually fail
    when the m = 0 invariant is broken."""
    n, extra = 32, 8
    pos, vel, mass = _cloud(n, 7)
    pp, vp, mp = _padded(pos, vel, mass, extra, 7)
    a, _, _ = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, impl="xla")
    bad_m = mp.at[n].set(1.0 / n)  # one padding row gains mass
    a_bad, _, _ = ops.acc_jerk_pot_rect(pp, vp, pp, vp, bad_m, impl="xla")
    assert float(jnp.max(jnp.abs(a_bad[:n] - a))) > 100 * ATOL


# --------------------------------------------------------------------------
# hypothesis sweeps (defined only when hypothesis is installed — a module-
# level importorskip would skip the always-run tests above too; CI has it)
# --------------------------------------------------------------------------
try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - exercised on minimal installs
    hypothesis = None

if hypothesis is not None:
    COMMON = dict(deadline=None,
                  suppress_health_check=[hypothesis.HealthCheck.too_slow])

    @settings(max_examples=20, **COMMON)
    @given(n=st.integers(2, 100), extra=st.integers(1, 100),
           seed=st.integers(0, 10_000),
           dtype=st.sampled_from(("fp32", "mixed")))
    def test_padding_invariance_property_ref(n, extra, seed, dtype):
        _check_invariant(n, extra, seed, "xla", dtype=dtype)

    @settings(max_examples=8, **COMMON)
    @given(n=st.integers(2, 80), extra=st.integers(1, 60),
           seed=st.integers(0, 10_000),
           dtype=st.sampled_from(("fp32", "mixed")))
    def test_padding_invariance_property_pallas(n, extra, seed, dtype):
        _check_invariant(n, extra, seed, "pallas_interpret", dtype=dtype)

    @settings(max_examples=6, **COMMON)
    @given(n=st.integers(4, 48), extra=st.integers(1, 40),
           seed=st.integers(0, 10_000), b=st.integers(2, 4))
    def test_padding_invariance_property_vmap(n, extra, seed, b):
        stack = lambda xs: tuple(jnp.stack(z) for z in zip(*xs))  # noqa: E731
        clouds = [_cloud(n, seed + s) for s in range(b)]
        pads = [_padded(*c, extra, seed + s) for s, c in enumerate(clouds)]
        f = jax.vmap(lambda p, v, m: ops.acc_jerk_pot_rect(
            p, v, p, v, m, impl="xla"))
        a, j, _ = f(*stack(clouds))
        ap, jp_, _ = f(*stack(pads))
        np.testing.assert_allclose(ap[:, :n], a, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(jp_[:, :n], j, rtol=RTOL, atol=ATOL)
