"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 architectures is instantiated at a REDUCED config of the same
family (launch.train.scaled_config) and runs one forward + one train step on
CPU, asserting output shapes and finiteness; decode paths are covered by a
prefill + 2 decode steps.  The FULL configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM, batch_spec_for
from repro.distributed.shardings import MeshRules
from repro.launch.train import scaled_config
from repro.models import config as C
from repro.models import model, params as P
from repro.optim import AdamW
from repro.train import make_train_step

ARCHS = [
    "stablelm-3b", "deepseek-67b", "qwen3-0.6b", "stablelm-12b",
    "zamba2-7b", "seamless-m4t-medium", "xlstm-1.3b",
    "phi3.5-moe-42b-a6.6b", "deepseek-v2-236b", "qwen2-vl-2b",
]

RULES = MeshRules.single_device()


def _reduced(arch):
    return scaled_config(C.get(arch), 0.04)


def _batch(cfg, b=2, s=64, seed=0):
    data = SyntheticLM(cfg, batch_spec_for(cfg, b, s), seed=seed)
    return {k: jnp.asarray(v) for k, v in data(0).items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_registered_exact(arch):
    cfg = C.get(arch)
    assert cfg.name == arch
    # spot-check the assigned numbers survived
    expected = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.moe_d_ff if arch == "deepseek-v2-236b" else cfg.d_ff,
           cfg.vocab_size)
    assert got == expected, (arch, got, expected)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = _reduced(arch)
    batch = _batch(cfg)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    logits, aux = model.forward(cfg, RULES, params, batch)
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.padded_vocab), logits.shape
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = AdamW(learning_rate=1e-3)
    step = make_train_step(cfg, RULES, opt)
    p2, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ["stablelm-3b", "zamba2-7b", "xlstm-1.3b",
                                  "deepseek-v2-236b", "qwen2-vl-2b",
                                  "seamless-m4t-medium"])
def test_reduced_prefill_decode(arch):
    cfg = _reduced(arch)
    s, n_dec = 24, 2
    # vlm batches split seq between patches and text: double so the text
    # span covers s + n_dec tokens
    total = 2 * (s + n_dec) if cfg.family == "vlm" else s + n_dec
    batch = _batch(cfg, b=2, s=total)
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    toks = batch["tokens"]
    front = batch["patches"].shape[1] if "patches" in batch else 0
    pf = dict(batch, tokens=toks[:, : s])
    logits, cache = model.prefill(cfg, RULES, params, pf,
                                  max_len=front + s + n_dec)
    assert logits.shape == (2, cfg.padded_vocab)
    for i in range(n_dec):
        logits, cache = model.decode_step(cfg, RULES, params, cache,
                                          toks[:, s + i : s + i + 1])
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache["len"]) == front + s + n_dec


def test_param_counts_scale_with_family():
    """Full-config parameter counts are in the right ballpark."""
    approx = {
        "deepseek-67b": (60e9, 75e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "phi3.5-moe-42b-a6.6b": (38e9, 48e9),
        "stablelm-12b": (10e9, 14e9),
        "zamba2-7b": (6e9, 9e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
    }
    for arch, (lo, hi) in approx.items():
        n = P.count_params(C.get(arch))
        assert lo < n < hi, (arch, n)
    # MoE active << total
    moe = C.get("deepseek-v2-236b")
    assert P.count_active(moe) < 0.15 * P.count_params(moe)
