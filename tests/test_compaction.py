"""Active-target compaction: gather/scatter primitives, capacity schedule,
engine equivalence, auto level sizing, and tiles telemetry.

Locks the tentpole contracts of the compaction layer:

* ``scatter_outputs`` after ``compact_targets`` is the identity on active
  rows and exactly zero on inactive rows (hypothesis-swept);
* the capacity schedule never underestimates an active count, and the
  per-level occupancy bound dominates every tick's true active set;
* ``compaction="gather"`` reproduces ``compaction="none"`` **bit-for-bit**
  on the committed block golden trajectory, for both FP32 kernels and the
  FP64 oracle, and launches strictly fewer grid tiles;
* ``--levels auto`` derives the hierarchy depth from the initial Aarseth dt
  distribution, clamped to [1, 8];
* driver/telemetry plumbing (``compaction`` validation, ``grid_tiles``).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hermite
from repro.core.evaluate import make_block_evaluator, make_evaluator
from repro.kernels import nbody_force, ops
from repro.sim import driver, ensemble as ens, scenarios

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "binary_plummer_block.json")


# --------------------------------------------------------------------------
# gather/scatter primitives
# --------------------------------------------------------------------------
def test_scatter_gather_identity_basic():
    rng = np.random.default_rng(0)
    n = 24
    x = jnp.asarray(rng.standard_normal((n, 3)))
    mask = jnp.asarray(rng.uniform(size=n) < 0.4)
    perm = jnp.argsort(~mask, stable=True)
    caps = ops.capacity_buckets(n, 8)
    cap = caps[int(ops.bucket_index(mask.sum(), caps))]
    (x_c, m_c) = ops.compact_targets(perm, cap, x, mask)
    (back,) = ops.scatter_outputs(perm, cap, n, x_c * m_c[:, None])
    m = np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(back)[m], np.asarray(x)[m])
    assert not np.asarray(back)[~m].any()


def test_scatter_gather_property():
    """scatter o gather == identity on active rows, zero elsewhere — for any
    mask, permutation order, and capacity bucket that bounds the count."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 40), st.integers(1, 16))
    def run(seed, n, block_i):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, 3)))
        mask = jnp.asarray(rng.uniform(size=n) < rng.uniform())
        caps = ops.capacity_buckets(n, block_i)
        n_act = int(mask.sum())
        cap = caps[int(ops.bucket_index(n_act, caps))]
        assert cap >= n_act  # the bucket bounds the active count
        perm = jnp.argsort(~mask, stable=True)
        # every active row lands inside the gathered window
        assert set(np.asarray(perm[:min(cap, n)])) >= set(np.flatnonzero(np.asarray(mask)))
        x_c, m_c = ops.compact_targets(perm, cap, x, mask)
        (back,) = ops.scatter_outputs(perm, cap, n, x_c * m_c[:, None])
        m = np.asarray(mask)
        np.testing.assert_array_equal(np.asarray(back)[m], np.asarray(x)[m])
        assert not np.asarray(back)[~m].any()

    run()


# --------------------------------------------------------------------------
# capacity schedule + occupancy bound
# --------------------------------------------------------------------------
def test_capacity_buckets_block_aligned_and_cover():
    assert ops.capacity_buckets(256, 32) == (32, 64, 128, 256)
    assert ops.capacity_buckets(24, 8) == (8, 16, 24)
    assert ops.capacity_buckets(24, 256) == (256,)
    assert ops.capacity_buckets(100, 16) == (16, 32, 64, 112)
    for n, bi in ((256, 32), (100, 16), (24, 8), (7, 8)):
        caps = ops.capacity_buckets(n, bi)
        assert caps[-1] >= n                      # covers every active count
        assert all(c % bi == 0 for c in caps)     # block-aligned launches


def test_bucket_never_underestimates():
    """For every possible active count the selected bucket holds it."""
    for n, bi in ((256, 32), (100, 16), (24, 8)):
        caps = ops.capacity_buckets(n, bi)
        idx = np.asarray(ops.bucket_index(jnp.arange(n + 1), caps))
        chosen = np.asarray(caps)[idx]
        assert (chosen >= np.arange(n + 1)).all()


def test_occupancy_bounds_dominate_schedule():
    """Entry t of the occupancy vector caps the active set of every tick
    whose threshold is t — across a simulated block schedule."""
    rng = np.random.default_rng(3)
    n_levels, n = 4, 32
    levels = jnp.asarray(rng.integers(0, n_levels, n), jnp.int32)
    occ = np.asarray(hermite.block_level_occupancy(levels,
                                                   n_levels=n_levels))
    assert occ[0] == n  # macro boundary: everyone
    n_sub = 2 ** (n_levels - 1)
    for k in range(1, n_sub + 1):
        act = np.asarray(hermite.block_active_mask(levels, k,
                                                   n_levels=n_levels))
        thresh = n_levels - 1 - (k & -k).bit_length() + 1
        thresh = max(thresh, 0)
        assert act.sum() <= occ[thresh]
    # padding mask excludes fake rows from the bound
    mask = jnp.arange(n) < 20
    occ_m = np.asarray(hermite.block_level_occupancy(levels,
                                                     n_levels=n_levels,
                                                     mask=mask))
    assert occ_m[0] == 20 and (occ_m <= occ).all()


# --------------------------------------------------------------------------
# evaluator equivalence (bit-for-bit) and grid accounting
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_gather_evaluator_bitwise_equals_masked(impl):
    rng = np.random.default_rng(7)
    n = 24
    pos = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    vel = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    acc_p = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.1, 1.0, n), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=n) < 0.3)
    kw = dict(eps=1e-7, order=6, impl=impl, block_i=8, block_j=128)
    dense = make_block_evaluator(**kw)(pos, vel, acc_p, mass, mask)
    perm = jnp.argsort(~mask, stable=True)
    caps = ops.capacity_buckets(n, 8)
    cap_idx = ops.bucket_index(mask.sum(), caps)
    packed = make_block_evaluator(compaction="gather", **kw)(
        pos, vel, acc_p, mass, mask, perm, cap_idx)
    for a, b in zip(dense, packed):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_make_evaluator_is_all_ones_block_evaluator():
    """The folded lockstep factory matches the block body with an all-ones
    mask exactly (the identity the fold rests on)."""
    rng = np.random.default_rng(11)
    n = 16
    pos = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    vel = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.1, 1.0, n), jnp.float32)
    lock = make_evaluator(impl="xla")(pos, vel, mass)
    blk = make_block_evaluator(impl="xla")(
        pos, vel, jnp.zeros_like(pos), mass, jnp.ones(n, bool))
    for a, b in zip(lock, blk):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grid_tiles_counts():
    assert nbody_force.grid_tiles(256, 256, 32, 256) == 8
    assert nbody_force.grid_tiles(32, 256, 32, 256) == 1
    assert nbody_force.grid_tiles(24, 24, 8, 128) == 3
    assert nbody_force.grid_tiles(100, 300, 16, 128) == 7 * 3


# --------------------------------------------------------------------------
# engine: the block golden trajectory, bit for bit, with fewer tiles
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ("fp64", "xla", "pallas_interpret"))
def test_block_golden_gather_bitwise_equals_none(impl):
    """``compaction=gather`` reproduces the committed block golden
    trajectory's run **bit-for-bit** vs ``compaction=none`` — same event
    schedule, same measured pairs, strictly fewer tiles launched."""
    with open(GOLDEN) as f:
        m = json.load(f)["meta"]
    state = scenarios.make(m["scenario"], m["n"], seed=m["seed"])
    kw = dict(t_end=m["t_end"], dt_max=m["dt_max"], n_levels=m["n_levels"],
              eta=m["eta"], order=m["order"], eps=m["eps"], impl=impl,
              block_i=8, block_j=128)
    dense, c0 = ens.evolve_ensemble_block([state], compaction="none", **kw)
    packed, c1 = ens.evolve_ensemble_block([state], compaction="gather",
                                           **kw)
    assert int(c1.n_events[0]) == int(c0.n_events[0])
    assert float(c1.n_pairs[0]) == float(c0.n_pairs[0])
    assert float(c1.n_tiles[0]) < float(c0.n_tiles[0])
    np.testing.assert_array_equal(np.asarray(packed.pos),
                                  np.asarray(dense.pos))
    np.testing.assert_array_equal(np.asarray(packed.vel),
                                  np.asarray(dense.vel))


def test_block_gather_padded_composes_with_n_active():
    """Compaction composes with the zero-mass padding mask: the padded
    member follows the identical schedule/trajectory, and padding rows are
    never gathered as active targets."""
    kw = dict(t_end=0.03125, dt_max=1 / 64, n_levels=4, impl="fp64",
              compaction="gather", block_i=8, block_j=128)
    st = scenarios.make("binary_plummer", 24, seed=1)
    alone, c_alone = ens.evolve_ensemble_block([st], **kw)
    padded, n_active = scenarios.build_padded(
        [scenarios.Scenario(name="binary_plummer", n=24, seed=1)], n_max=32)
    pad_out, c_pad = ens.evolve_ensemble_block(padded, n_active=n_active,
                                               **kw)
    assert int(c_pad.n_events[0]) == int(c_alone.n_events[0])
    assert float(c_pad.n_pairs[0]) == float(c_alone.n_pairs[0])
    np.testing.assert_allclose(np.asarray(pad_out.pos[0, :24]),
                               np.asarray(alone.pos[0]), rtol=0, atol=1e-12)
    assert not np.asarray(pad_out.vel[0, 24:]).any()
    assert not np.asarray(pad_out.acc[0, 24:]).any()


# --------------------------------------------------------------------------
# auto level sizing
# --------------------------------------------------------------------------
def test_auto_n_levels_clamped_and_resolving():
    dt_max = 0.0625
    # coarse system: one level suffices
    assert int(hermite.auto_n_levels(jnp.asarray([0.0625, 0.5]),
                                     dt_max=dt_max)) == 1
    # dt_i = dt_max/4 needs level 2 -> depth 3
    assert int(hermite.auto_n_levels(jnp.asarray([0.0625, 0.0625 / 4]),
                                     dt_max=dt_max)) == 3
    # pathological: clamped at max_levels
    assert int(hermite.auto_n_levels(jnp.asarray([1e-12]),
                                     dt_max=dt_max)) == 8
    assert int(hermite.auto_n_levels(jnp.asarray([1e-12]), dt_max=dt_max,
                                     max_levels=5)) == 5


def test_driver_auto_levels_and_tiles_report(tmp_path):
    cfg = driver.SimConfig(scenario="binary_plummer", n=24, seed=1,
                           t_end=0.03125, stepper="block", dt_max=1 / 64,
                           n_levels=None, compaction="gather", block_i=8,
                           block_j=128, impl="xla", diag_every=8,
                           out=str(tmp_path / "r.json"))
    report = driver.run(cfg)
    assert 1 <= report["n_levels"] <= 8
    assert report["n_levels_auto"] == [report["n_levels"]]
    assert report["compaction"] == "gather"
    assert report["grid_tiles_total"] == report["runs"][0]["grid_tiles"] > 0
    # gather never launches more tiles than the masked full grid would
    full = nbody_force.grid_tiles(24, 24, 8, 128) * 2 * report["steps"]
    assert report["grid_tiles_total"] <= full


def test_driver_rejects_compaction_off_block():
    with pytest.raises(ValueError, match="only applies to the block"):
        driver.SimConfig(dt=0.01, compaction="gather").resolved_stepper()
    with pytest.raises(ValueError, match="only reach the block"):
        driver.SimConfig(dt=0.01, block_i=32).resolved_stepper()
    with pytest.raises(ValueError, match="no levels to size"):
        driver.SimConfig(dt=0.01, n_levels=None).resolved_stepper()
    with pytest.raises(ValueError, match="compaction must be one of"):
        ens.ensemble_run_block(
            ens.stack_states([scenarios.make("plummer", 16, seed=0,
                                             validate=False)]),
            t_end=0.01, compaction="squeeze")
