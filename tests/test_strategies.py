"""Multi-device strategy equivalence (paper §3, Fig. 3).

The four distribution strategies must produce the same evaluation as the
single-device path.  Multi-device CPU meshes require
``--xla_force_host_platform_device_count`` BEFORE jax initializes, so the
check runs in a subprocess with a clean environment (mirroring the paper's
process-per-card launch).
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
from repro.core import nbody, hermite
from repro.core.evaluate import make_evaluator
from repro.core.strategies import make_strategy_evaluator, STRATEGIES

state = nbody.plummer(500, seed=7)   # 500 % 4 != 0: exercises padding
single = make_evaluator(impl="xla")
ref = single(state.pos, state.vel, state.mass)

for strategy in STRATEGIES:
    ev = make_strategy_evaluator(strategy, devices=jax.devices(),
                                 impl="xla", chips_per_card=2)
    out = ev(state.pos, state.vel, state.mass)
    for name in ("acc", "jerk", "snap", "pot"):
        a, b = getattr(out, name), getattr(ref, name)
        err = float(jnp.max(jnp.abs(a - b)))
        scale = float(jnp.max(jnp.abs(b))) + 1e-30
        assert err / scale < 1e-5, (strategy, name, err, scale)
    print(f"{strategy}: OK")

# one full Hermite step under each strategy matches the single-device step
for strategy in STRATEGIES:
    ev = make_strategy_evaluator(strategy, devices=jax.devices(), impl="xla")
    s1 = hermite.step(hermite.initialize(state, single),
                      jnp.asarray(1e-3), single)
    s2 = hermite.step(hermite.initialize(state, ev), jnp.asarray(1e-3), ev)
    assert float(jnp.max(jnp.abs(s1.pos - s2.pos))) < 1e-9, strategy
print("HERMITE-STEP: OK")
"""


@pytest.mark.slow
def test_strategy_equivalence_4dev():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for strategy in ("replicated", "two_level", "mesh_sharded", "ring"):
        assert f"{strategy}: OK" in res.stdout
    assert "HERMITE-STEP: OK" in res.stdout
