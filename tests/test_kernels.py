"""Pallas N-body kernel vs the pure-jnp oracle (paper §4.1 validation).

The kernel is TPU-targeted; on CPU it executes under ``interpret=True``
(Mosaic-free Python interpretation of the same kernel body), swept over
shapes, block sizes and target/source splits and compared against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import nbody_force, ops, ref

F32 = jnp.float32


def _cloud(n, seed=0, dtype=F32):
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.standard_normal((n, 3)), dtype)
    vel = jnp.asarray(rng.standard_normal((n, 3)) * 0.1, dtype)
    mass = jnp.asarray(rng.uniform(0.5, 1.5, n) / n, dtype)
    return pos, vel, mass


@pytest.mark.parametrize("n,block_i,block_j", [
    (256, 128, 128),
    (512, 256, 512),
    (300, 128, 256),     # non-multiple of block => padding path
    (1024, 256, 512),
    (128, 8, 128),       # minimal sublane/lane-aligned blocks
])
def test_acc_jerk_pot_matches_ref(n, block_i, block_j):
    pos, vel, mass = _cloud(n)
    a_k, j_k, p_k = ops.acc_jerk_pot_rect(
        pos, vel, pos, vel, mass, impl="pallas_interpret",
        block_i=block_i, block_j=block_j)
    a_r, j_r, p_r = ref.acc_jerk_pot_rect(pos, vel, pos, vel, mass)
    np.testing.assert_allclose(a_k, a_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(j_k, j_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(p_k, p_r, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n_t,n_s", [(128, 512), (512, 128), (256, 256)])
def test_rectangular_contract(n_t, n_s):
    """Targets != sources (the multi-device strategies' local view)."""
    pt, vt, _ = _cloud(n_t, seed=1)
    ps, vs, ms = _cloud(n_s, seed=2)
    a_k, j_k, p_k = ops.acc_jerk_pot_rect(
        pt, vt, ps, vs, ms, impl="pallas_interpret")
    a_r, j_r, p_r = ref.acc_jerk_pot_rect(pt, vt, ps, vs, ms)
    np.testing.assert_allclose(a_k, a_r, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(j_k, j_r, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,block_i,block_j", [
    (256, 128, 128), (300, 128, 256), (512, 256, 512),
])
def test_snap_matches_ref(n, block_i, block_j):
    pos, vel, mass = _cloud(n)
    acc, _, _ = ref.acc_jerk_pot_rect(pos, vel, pos, vel, mass)
    s_k = ops.snap_rect(pos, vel, acc, pos, vel, acc, mass,
                        impl="pallas_interpret",
                        block_i=block_i, block_j=block_j)
    s_r = ref.snap_rect(pos, vel, acc, pos, vel, acc, mass)
    np.testing.assert_allclose(s_k, s_r, rtol=5e-4, atol=5e-4)


def test_row_chunked_rect_matches_dense(monkeypatch):
    """Above ``DENSE_PAIR_LIMIT`` the oracle streams target-row chunks
    through ``lax.map`` instead of fusing one (N_t, N_s) rectangle (the
    memory wall a 65536-body sweep hits at >100 GiB).  Row chunking never
    reorders a row-local source reduction, so the chunked results must
    match the dense path to reduction-vectorization rounding — and be
    shape-exact through padding (n_t not a multiple of the chunk rows)."""
    pt, vt, _ = _cloud(100, seed=1)
    at = jnp.asarray(np.random.default_rng(3).standard_normal((100, 3)), F32)
    ps, vs, ms = _cloud(64, seed=2)
    dense = ref.acc_jerk_pot_rect(pt, vt, ps, vs, ms)
    dense_s = ref.snap_rect(pt, vt, at, ps, vs, at[:64], ms)
    monkeypatch.setattr(ref, "DENSE_PAIR_LIMIT", 1 << 9)  # 8-row chunks
    chunked = ref.acc_jerk_pot_rect(pt, vt, ps, vs, ms)
    chunked_s = ref.snap_rect(pt, vt, at, ps, vs, at[:64], ms)
    for d, c in zip(dense + (dense_s,), chunked + (chunked_s,)):
        assert d.shape == c.shape
        np.testing.assert_allclose(np.asarray(c), np.asarray(d),
                                   rtol=1e-6, atol=1e-6)
    # under vmap (the batched ensemble engines) chunking lowers via scan
    bat = jax.vmap(lambda p, v: ref.acc_jerk_pot_rect(p, v, ps, vs, ms))
    a_b, _, _ = bat(jnp.stack([pt, pt]), jnp.stack([vt, vt]))
    np.testing.assert_allclose(np.asarray(a_b[0]), np.asarray(chunked[0]),
                               rtol=0, atol=0)


def test_zero_mass_padding_is_exact():
    """Padding particles carry m=0 => exactly zero contribution."""
    pos, vel, mass = _cloud(200)
    a1, j1, p1 = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, impl="xla")
    # embed the same cloud among zero-mass strangers
    rng = np.random.default_rng(9)
    extra = jnp.asarray(rng.standard_normal((56, 3)), F32)
    pos_p = jnp.concatenate([pos, extra])
    vel_p = jnp.concatenate([vel, jnp.zeros_like(extra)])
    mass_p = jnp.concatenate([mass, jnp.zeros((56,), F32)])
    a2, j2, p2 = ops.acc_jerk_pot_rect(pos, vel, pos_p, vel_p, mass_p,
                                       impl="xla")
    np.testing.assert_allclose(a1, a2, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(j1, j2, rtol=1e-6, atol=1e-7)


def test_paper_accuracy_bands_fp32_vs_fp64_golden():
    """Paper §4.1: FP32 vs FP64 golden — acc <= 0.05%, jerk <= 0.2%."""
    n = 1024
    rng = np.random.default_rng(3)
    pos64 = jnp.asarray(rng.standard_normal((n, 3)), jnp.float64)
    vel64 = jnp.asarray(rng.standard_normal((n, 3)) * 0.1, jnp.float64)
    mass64 = jnp.asarray(np.full(n, 1.0 / n), jnp.float64)

    a64, j64, _ = ref.acc_jerk_pot_rect(pos64, vel64, pos64, vel64, mass64)
    a32, j32, _ = ops.acc_jerk_pot_rect(
        pos64.astype(F32), vel64.astype(F32), pos64.astype(F32),
        vel64.astype(F32), mass64.astype(F32), impl="pallas_interpret")

    def rel(x, y):
        scale = jnp.maximum(jnp.abs(y), jnp.abs(y).mean())
        return float(jnp.max(jnp.abs(x.astype(jnp.float64) - y) / scale))

    assert rel(a32, a64) < 5e-4, rel(a32, a64)   # 0.05 %
    assert rel(j32, j64) < 2e-3, rel(j32, j64)   # 0.2 %


def test_packing_layout():
    pos, vel, mass = _cloud(130)
    tgt = ops.pack_targets(pos, vel, 256)
    src = ops.pack_sources(pos, vel, mass, 256)
    assert tgt.shape == (256, 8) and src.shape == (8, 256)
    np.testing.assert_array_equal(tgt[:130, 0], pos[:, 0])
    np.testing.assert_array_equal(src[3, :130], mass)
    assert float(jnp.abs(tgt[130:]).sum()) == 0.0
    assert float(jnp.abs(src[:, 130:]).sum()) == 0.0
