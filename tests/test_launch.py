"""Launch-layer units: input specs, shape/skip policy, scaled configs.

These run WITHOUT the 512-device flag (rules=None -> no shardings), so they
exercise exactly the spec-construction logic the dry-run uses.
"""

import jax.numpy as jnp
import pytest

from repro.launch import shapes as S
from repro.launch.train import scaled_config
from repro.models import config as C
from repro.models import model as M

ARCHS = C.available()


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_train_specs_shapes(arch):
    cfg = C.get(arch)
    case = S.SHAPES["train_4k"]
    batch = S.train_specs(cfg, case)
    assert batch["tokens"].dtype == jnp.int32
    b, s_txt = batch["tokens"].shape
    assert b == case.global_batch
    total = s_txt + (batch["patches"].shape[1] if "patches" in batch else 0)
    assert total == case.seq_len
    if cfg.family == "audio":
        assert batch["frames"].shape == (b, case.seq_len, cfg.d_model)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_specs_cache_tree(arch):
    cfg = C.get(arch)
    spec = S.decode_specs(cfg, S.SHAPES["decode_32k"])
    assert spec["tokens"].shape == (128, 1)
    cache = spec["cache"]
    assert cache["len"].shape == ()
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        kv = cache["layers"]
        key = "c_kv" if cfg.uses_mla else "k"
        n_l = cfg.n_layers - cfg.first_k_dense
        assert kv[key].shape[0] == n_l
        assert kv[key].shape[2] == 32_768
    if cfg.family == "hybrid":
        assert cache["ssm"].shape[0] == cfg.n_layers
        assert cache["attn"]["k"].shape[0] == cfg.n_layers // cfg.attn_every


def test_long_500k_policy():
    ok, _ = S.cell_supported(C.get("zamba2-7b"), "long_500k")
    assert ok
    ok, why = S.cell_supported(C.get("stablelm-3b"), "long_500k")
    assert not ok and "full-attention" in why
    with pytest.raises(ValueError):
        S.input_specs(C.get("qwen3-0.6b"), "long_500k")
    # 40 cells total: 10 archs x 4 shapes, 8 documented skips
    cells = [(a, s) for a in ARCHS for s in S.SHAPES]
    skipped = [c for c in cells if not S.cell_supported(C.get(c[0]), c[1])[0]]
    assert len(cells) == 40 and len(skipped) == 8


def test_train_accum_covers_all_archs():
    assert set(S.TRAIN_ACCUM) == set(ARCHS)
    # microbatch divisibility on both meshes after the cap
    for arch, accum in S.TRAIN_ACCUM.items():
        for batch_shards in (16, 32):
            eff = max(1, min(accum, 256 // batch_shards))
            assert (256 // eff) % batch_shards == 0, (arch, eff)


@pytest.mark.parametrize("arch", ARCHS)
def test_scaled_config_valid(arch):
    cfg = scaled_config(C.get(arch), 0.04)
    assert cfg.d_model % cfg.n_heads == 0 or cfg.uses_mla
    assert cfg.n_heads % cfg.n_kv_heads == 0
    if cfg.family == "ssm":
        assert cfg.n_layers % cfg.slstm_every == 0
    if cfg.mrope:
        assert sum(cfg.mrope_sections) == (cfg.head_dim or 0) // 2


def test_cache_spec_matches_init_cache():
    cfg = scaled_config(C.get("zamba2-7b"), 0.04)
    spec = M.cache_spec(cfg, 2, 64)
    concrete = M.init_cache(cfg, 2, 64)
    import jax

    s_leaves = jax.tree.leaves(spec)
    c_leaves = jax.tree.leaves(concrete)
    assert len(s_leaves) == len(c_leaves)
    for s, c in zip(s_leaves, c_leaves):
        assert s.shape == c.shape and s.dtype == c.dtype
