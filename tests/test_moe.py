"""MoE dispatch unit tests: routing mass, capacity semantics, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.shardings import MeshRules
from repro.models import layers, params as P
from repro.models.config import ArchConfig

RULES = MeshRules.single_device()


def _cfg(**kw):
    base = dict(name="m", family="moe", n_layers=1, d_model=32, n_heads=2,
                n_kv_heads=2, d_ff=64, moe_d_ff=64, vocab_size=64,
                n_experts=4, top_k=2, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def _moe_params(cfg, key):
    from repro.models.params import _moe_defs, _init_one, is_def
    defs = _moe_defs(cfg)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(p, k, jnp.float32) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def test_no_drop_capacity_matches_dense_combine():
    """With capacity >= tokens*k/experts the sorted dispatch is EXACT: it
    must equal the dense (all-experts) combine weighted by router probs."""
    cfg = _cfg(capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (2, 16, 32), jnp.float32)

    out, aux = layers.moe_ffn(cfg, RULES, p, x)

    # dense reference: run every expert on every token, combine by top-k probs
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->besf", x, p["we_g"])
    u = jnp.einsum("bsd,edf->besf", x, p["we_u"])
    y = jnp.einsum("besf,efd->besd", jax.nn.silu(h) * u, p["we_d"])
    w_full = jnp.zeros(probs.shape).at[
        jnp.arange(2)[:, None, None], jnp.arange(16)[None, :, None], top_i
    ].add(top_p)
    want = jnp.einsum("besd,bse->bsd", y, w_full)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_tight_capacity_drops_tokens():
    """With capacity ~0, outputs collapse toward zero (all slots dropped)."""
    cfg = _cfg(capacity_factor=1e-6)
    key = jax.random.PRNGKey(1)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (1, 64, 32), jnp.float32)
    out, _ = layers.moe_ffn(cfg, RULES, p, x)
    cfg_big = _cfg(capacity_factor=8.0)
    out_big, _ = layers.moe_ffn(cfg_big, RULES, p, x)
    assert float(jnp.abs(out).sum()) < float(jnp.abs(out_big).sum())


def test_aux_loss_penalizes_imbalance():
    cfg = _cfg()
    key = jax.random.PRNGKey(2)
    p = _moe_params(cfg, key)
    # biased router: with all-positive inputs, a +1/-1 column pattern sends
    # EVERY token to expert 0 regardless of its features
    router_bias = (-jnp.ones_like(p["router"])).at[:, 0].set(1.0)
    p_bias = dict(p, router=router_bias)
    x = jnp.abs(jax.random.normal(key, (2, 32, 32), jnp.float32))
    _, aux_balanced = layers.moe_ffn(cfg, RULES, p, x)
    _, aux_biased = layers.moe_ffn(cfg, RULES, p_bias, x)
    assert float(aux_biased) > float(aux_balanced)


def test_decode_path_single_token():
    cfg = _cfg()
    key = jax.random.PRNGKey(3)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (4, 1, 32), jnp.float32)
    out, aux = layers.moe_ffn(cfg, RULES, p, x)
    assert out.shape == (4, 1, 32)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_shared_experts_added():
    cfg = _cfg(n_shared_experts=1)
    key = jax.random.PRNGKey(4)
    p = _moe_params(cfg, key)
    x = jax.random.normal(key, (2, 8, 32), jnp.float32)
    out_with, _ = layers.moe_ffn(cfg, RULES, p, x)
    p_zero = dict(p, ws_g=jnp.zeros_like(p["ws_g"]))
    out_zero, _ = layers.moe_ffn(cfg, RULES, p_zero, x)
    assert float(jnp.abs(out_with - out_zero).max()) > 0
