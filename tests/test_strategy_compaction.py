"""Shard-local compaction across the distribution strategies + per-member
capacity buckets.

Locks the distributed-compaction contracts:

* **Differential suite** (forced 2-device host mesh, subprocess): for every
  strategy x kernel combination, ``compaction="gather"`` reproduces
  ``compaction="none"`` **bit-for-bit** on the committed block golden
  recipe — same event schedule, same measured pairs, strictly fewer local
  grid tiles — and tracks both the FP64 block golden (FP32 tolerance) and
  the committed 2-device strategy golden (``tests/golden/regen.py``
  regenerates it through its multi-device subprocess respawn).
* **Hypothesis properties**: per-shard and per-member bucket selection never
  underestimates the active count, and shard-local scatter∘gather is the
  identity under arbitrary activity masks and uneven shard occupancy.
* **Heterogeneous buckets**: a deliberately lopsided mixed batch (deep
  binary-rich member + quiescent two-body member) launches strictly fewer
  ``grid_tiles_total`` under per-member bucket groups than under the
  batch-shared-bucket baseline, with bit-for-bit identical physics (energy
  drift unchanged).
* **Plumbing**: ``CapacityPlan`` shard/restrict units, driver routing of
  strategy block runs (``grid_tiles_per_shard`` telemetry), bucket-mode
  validation.
"""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.sim import driver, ensemble as ens, scenarios

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
GOLDEN = os.path.join(GOLDEN_DIR, "binary_plummer_block.json")
GOLDEN_2DEV = os.path.join(GOLDEN_DIR, "binary_plummer_block_2dev.json")


# --------------------------------------------------------------------------
# differential suite: every strategy x kernel on a forced 2-device mesh
# --------------------------------------------------------------------------
# XLA's host-platform device count must be set before jax initializes, so
# the sweep runs in one subprocess (mirroring tests/test_strategies.py).
_DIFF_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json, sys
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.core.strategies import STRATEGIES
from repro.sim import ensemble as ens, scenarios

assert len(jax.devices()) == 2
with open(sys.argv[1]) as f:
    doc = json.load(f)            # the FP64 single-device block golden
with open(sys.argv[2]) as f:
    doc2 = json.load(f)           # the committed 2-device strategy golden
m = doc["meta"]
state = scenarios.make(m["scenario"], m["n"], seed=m["seed"])
kw = dict(t_end=m["t_end"], dt_max=m["dt_max"], n_levels=m["n_levels"],
          eta=m["eta"], order=m["order"], eps=m["eps"],
          block_i=8, block_j=128, devices=2)

for strategy in STRATEGIES:
    for impl in sys.argv[3].split(","):
        dense, c0 = ens.evolve_strategy_block(
            state, strategy=strategy, impl=impl, compaction="none", **kw)
        packed, c1 = ens.evolve_strategy_block(
            state, strategy=strategy, impl=impl, compaction="gather", **kw)
        tag = (strategy, impl)
        # identical event schedule and measured pairwise work ...
        assert int(c1.n_events) == int(c0.n_events) == doc["n_events"], tag
        assert float(c1.n_pairs) == float(c0.n_pairs), tag
        # ... bit-for-bit identical trajectory ...
        assert np.array_equal(np.asarray(packed.pos),
                              np.asarray(dense.pos)), tag
        assert np.array_equal(np.asarray(packed.vel),
                              np.asarray(dense.vel)), tag
        # ... strictly fewer tiles enqueued on EVERY shard
        tn, tg = np.asarray(c0.n_tiles), np.asarray(c1.n_tiles)
        assert tn.shape == tg.shape == (2,), tag
        assert (tg < tn).all(), (tag, tn, tg)
        # FP32 distributed evaluation tracks the FP64 block golden (the
        # binary-rich case compounds FP32 noise; cf. BLOCK_TOL in
        # tests/test_golden_trajectories.py)
        np.testing.assert_allclose(np.asarray(packed.pos),
                                   np.asarray(doc["pos"]),
                                   rtol=0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(packed.vel),
                                   np.asarray(doc["vel"]),
                                   rtol=0, atol=1e-5)
        print(f"{strategy}/{impl}: OK tiles {tn.sum():.0f} -> {tg.sum():.0f}")

# --sources neighbor rides the vmapped batch engine: the same member
# duplicated across the 2-device batch axis must match its 1-device
# evolution bit-for-bit (sharding the batch never touches per-member
# math), march in lockstep, rebuild windows on the same schedule, and
# stay within the far-field prediction tier of the all-pairs trajectory
nkw = dict(t_end=m["t_end"], dt_max=m["dt_max"], n_levels=m["n_levels"],
           eta=m["eta"], order=m["order"], eps=m["eps"],
           block_i=8, block_j=8)
srt = ens.spatial_sort_state(state, leaf=8)
for impl in sys.argv[3].split(","):
    two, cn2 = ens.evolve_ensemble_block(
        [state, state], impl=impl, sources="neighbor",
        neighbor_radius=0.5, devices=jax.devices()[:2], **nkw)
    one, cn1 = ens.evolve_ensemble_block(
        [state, state], impl=impl, sources="neighbor",
        neighbor_radius=0.5, devices=jax.devices()[:1], **nkw)
    for leaf in ("pos", "vel"):
        assert np.array_equal(np.asarray(getattr(two, leaf)),
                              np.asarray(getattr(one, leaf))), (impl, leaf)
    assert np.array_equal(np.asarray(two.pos[0]), np.asarray(two.pos[1]))
    assert np.asarray(cn2.nbr.n_refresh).tolist() \
        == np.asarray(cn1.nbr.n_refresh).tolist()
    assert int(cn2.nbr.n_refresh[0]) > 0
    full, _ = ens.evolve_ensemble_block([srt], impl=impl, **nkw)
    np.testing.assert_allclose(np.asarray(two.pos[0]),
                               np.asarray(full.pos[0]), rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(two.vel[0]),
                               np.asarray(full.vel[0]), rtol=0, atol=1e-4)
    print(f"neighbor/{impl}: OK refreshes {int(cn2.nbr.n_refresh[0])}")

# the committed 2-device fixture replays exactly (same code path + version)
m2 = doc2["meta"]
state2 = scenarios.make(m2["scenario"], m2["n"], seed=m2["seed"])
out2, c2 = ens.evolve_strategy_block(
    state2, strategy=m2["strategy"], impl=m2["impl"],
    compaction=m2["compaction"], t_end=m2["t_end"], dt_max=m2["dt_max"],
    n_levels=m2["n_levels"], eta=m2["eta"], order=m2["order"],
    eps=m2["eps"], block_i=m2["block_i"], block_j=m2["block_j"],
    devices=m2["devices"])
assert int(c2.n_events) == doc2["n_events"]
np.testing.assert_allclose(np.asarray(out2.pos), np.asarray(doc2["pos"]),
                           rtol=0, atol=1e-9)
np.testing.assert_allclose(np.asarray(out2.vel), np.asarray(doc2["vel"]),
                           rtol=0, atol=1e-9)
print("GOLDEN-2DEV: OK")
print("DIFFERENTIAL: OK")
"""


def _run_differential(impls: str) -> str:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run(
        [sys.executable, "-c", _DIFF_SCRIPT, GOLDEN, GOLDEN_2DEV, impls],
        env=env, capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    return res.stdout


@pytest.mark.slow
def test_strategy_compaction_differential_2dev_xla():
    out = _run_differential("xla")
    for strategy in ("replicated", "two_level", "mesh_sharded", "ring"):
        assert f"{strategy}/xla: OK" in out
    assert "neighbor/xla: OK" in out
    assert "GOLDEN-2DEV: OK" in out
    assert "DIFFERENTIAL: OK" in out


@pytest.mark.slow
def test_strategy_compaction_differential_2dev_pallas():
    out = _run_differential("pallas_interpret")
    for strategy in ("replicated", "two_level", "mesh_sharded", "ring"):
        assert f"{strategy}/pallas_interpret: OK" in out
    assert "neighbor/pallas_interpret: OK" in out
    assert "DIFFERENTIAL: OK" in out


# --------------------------------------------------------------------------
# hypothesis properties: shard-local buckets and gather/scatter
# --------------------------------------------------------------------------
def _shard_split(x, p):
    n_local = x.shape[0] // p
    return [x[i * n_local:(i + 1) * n_local] for i in range(p)]


def test_shard_bucket_never_underestimates_property():
    """For any activity mask and any (even wildly uneven) shard occupancy,
    every shard's selected local bucket holds its local active count."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4),
           st.integers(1, 16), st.integers(1, 16),
           st.floats(0.0, 1.0))
    def run(seed, p, n_local_blocks, block_i, frac):
        rng = np.random.default_rng(seed)
        n = p * n_local_blocks * block_i
        # uneven occupancy: a random contiguous span of actives, so some
        # shards can be full while others are empty
        start = int(rng.integers(0, n))
        width = int(frac * n)
        mask = np.zeros(n, bool)
        mask[start:min(start + width, n)] = True
        plan = ops.CapacityPlan(n, n, block_i, 128).shard(p)
        assert plan.n_targets == n // p
        # host-side shard() agrees with what in-shard code builds from its
        # own local extent (strategies._shard_plan)
        assert plan.caps == ops.capacity_buckets(n // p, block_i)
        for mask_l in _shard_split(mask, p):
            n_act = int(mask_l.sum())
            cap = plan.caps[int(plan.bucket(n_act))]
            assert cap >= n_act
            assert cap % block_i == 0

    run()


def test_shard_local_scatter_gather_identity_property():
    """Shard-local scatter∘gather == identity on each shard's active rows,
    zero elsewhere — reassembled over shards it equals the global masked
    array, whatever the mask and however uneven the shard occupancy."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4),
           st.integers(2, 12), st.integers(1, 8))
    def run(seed, p, n_local, block_i):
        rng = np.random.default_rng(seed)
        n = p * n_local
        x = rng.standard_normal((n, 3))
        mask = rng.uniform(size=n) < rng.uniform()
        back = []
        for x_l, m_l in zip(_shard_split(x, p), _shard_split(mask, p)):
            plan = ops.CapacityPlan(n_local, n, block_i, 128)
            cap = plan.caps[int(plan.bucket(m_l.sum()))]
            perm = jnp.argsort(~jnp.asarray(m_l), stable=True)
            x_c, m_c = ops.compact_targets(perm, cap, jnp.asarray(x_l),
                                           jnp.asarray(m_l))
            (b,) = ops.scatter_outputs(perm, cap, n_local,
                                       x_c * m_c[:, None])
            back.append(np.asarray(b))
        back = np.concatenate(back)
        np.testing.assert_array_equal(back[mask], x[mask])
        assert not back[~mask].any()

    run()


def test_member_bucket_never_underestimates_property():
    """Per-member dispatch: each bucket group's shared cap bounds every
    group member's per-event active count, for any n_active profile and any
    active counts below the per-member ceilings."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 6),
           st.integers(2, 64), st.integers(1, 16))
    def run(seed, b, n, block_i):
        rng = np.random.default_rng(seed)
        n_active = rng.integers(1, n + 1, size=b)
        groups = ens._bucket_groups(n, n_active, block_i, 128,
                                    "gather", "member")
        # groups partition the batch
        members = sorted(m for ms, _ in groups for m in ms)
        assert members == list(range(b))
        caps = ops.capacity_buckets(n, block_i)
        for ms, n_caps in groups:
            gcaps = caps[:n_caps]
            counts = np.asarray([rng.integers(0, n_active[m] + 1)
                                 for m in ms])
            # the ceiling bucket covers every member of the group ...
            assert all(gcaps[-1] >= n_active[m] for m in ms)
            # ... and the group's shared per-event bucket covers them all
            cap = gcaps[int(ops.bucket_index(counts.max(), gcaps))]
            assert (cap >= counts).all()

    run()


def test_bucket_groups_modes():
    """Homogeneous batches collapse to one full-schedule group in both
    modes; 'shared' always returns the batch-shared baseline."""
    caps = ops.capacity_buckets(256, 32)
    homo = ens._bucket_groups(256, [256, 256, 256], 32, 256,
                              "gather", "member")
    assert homo == (((0, 1, 2), len(caps)),)
    assert ens._bucket_groups(256, [64, 256], 32, 256, "gather", "shared") \
        == (((0, 1), len(caps)),)
    mixed = ens._bucket_groups(256, [64, 256], 32, 256, "gather", "member")
    assert len(mixed) == 2
    assert ens._bucket_groups(256, [64, 256], 32, 256, "none", "member") \
        == (((0, 1), len(caps)),)
    with pytest.raises(ValueError, match="bucket_mode"):
        ens._bucket_groups(256, [256], 32, 256, "gather", "widest")


def test_capacity_plan_shard_restrict_units():
    plan = ops.CapacityPlan(256, 256, 32, 128)
    assert plan.caps == (32, 64, 128, 256)
    assert plan.tiles_by_cap == (4, 8, 16, 32)        # 2 j-tiles x 2 passes
    assert plan.dense_tiles == 32
    local = plan.shard(2)
    assert local.n_targets == 128 and local.caps == (32, 64, 128)
    assert local.n_sources == 256                     # sources stay full
    small = plan.restrict(64)
    assert small.caps == (32, 64)
    # exact bucket boundaries select their own bucket as the ceiling
    assert plan.restrict(256).caps == plan.caps
    assert plan.restrict(32).caps == (32,)
    assert plan.restrict(33).caps == (32, 64)
    # a ceiling above the top bucket is a caller error, not a request for
    # the full schedule: that member could exceed every launchable bucket
    with pytest.raises(ValueError, match="capacity range"):
        plan.restrict(1000)
    with pytest.raises(ValueError, match="capacity range"):
        plan.restrict(0)
    with pytest.raises(ValueError, match="shards"):
        plan.shard(3)
    # ring-style plan: per-pass launch per streamed shard
    ring = ops.CapacityPlan(128, 128, 32, 128, n_passes=4)
    assert ring.tiles_by_cap == (4, 8, 16)


# --------------------------------------------------------------------------
# heterogeneous buckets: lopsided mixed batch
# --------------------------------------------------------------------------
def test_lopsided_mixed_batch_member_buckets_beat_shared():
    """One deep-hierarchy member (binary-rich Plummer) + one quiescent
    member (two-body, n_active=2 inside a 24-row pad): per-member bucket
    groups launch strictly fewer total tiles than the batch-shared-bucket
    baseline, at bit-for-bit identical physics (same trajectory, same
    measured pairs, same energy drift)."""
    specs = [scenarios.Scenario(name="binary_plummer", n=24, seed=1),
             scenarios.Scenario(name="two_body", n=2, seed=0)]
    batched, n_active = scenarios.build_padded(specs, n_max=24)
    kw = dict(t_end=0.0625, dt_max=1 / 64, n_levels=4, impl="xla",
              compaction="gather", block_i=8, block_j=128,
              n_active=n_active)
    shared, cs = ens.evolve_ensemble_block(batched, bucket_mode="shared",
                                           **kw)
    member, cm = ens.evolve_ensemble_block(batched, bucket_mode="member",
                                           **kw)
    # launch economics: strictly fewer tiles, and the quiescent member is
    # the one that got cheaper
    assert float(np.sum(np.asarray(cm.n_tiles))) \
        < float(np.sum(np.asarray(cs.n_tiles)))
    assert float(cm.n_tiles[1]) < float(cs.n_tiles[1])
    # physics: bit-for-bit unchanged
    np.testing.assert_array_equal(np.asarray(member.pos),
                                  np.asarray(shared.pos))
    np.testing.assert_array_equal(np.asarray(member.vel),
                                  np.asarray(shared.vel))
    np.testing.assert_array_equal(np.asarray(cm.n_pairs),
                                  np.asarray(cs.n_pairs))
    np.testing.assert_array_equal(np.asarray(cm.n_events),
                                  np.asarray(cs.n_events))
    e_m = np.asarray(ens.batched_total_energy(member))
    e_s = np.asarray(ens.batched_total_energy(shared))
    np.testing.assert_array_equal(e_m, e_s)


def test_lopsided_mixed_driver_reports_fewer_tiles(tmp_path):
    """The same lopsided comparison end-to-end through the driver: telemetry
    ``grid_tiles_total`` drops under per-member buckets while the reported
    per-run energy drift is unchanged."""
    base = dict(mix=(("binary_plummer", 24), ("two_body", 2)), seed=0,
                t_end=0.03125, stepper="block", dt_max=1 / 64, n_levels=4,
                compaction="gather", block_i=8, block_j=128, impl="xla",
                diag_every=16)
    r_shared = driver.run(driver.SimConfig(bucket_mode="shared", **base,
                                           out=str(tmp_path / "s.json")))
    r_member = driver.run(driver.SimConfig(bucket_mode="member", **base,
                                           out=str(tmp_path / "m.json")))
    assert r_member["grid_tiles_total"] < r_shared["grid_tiles_total"]
    assert r_member["bucket_mode"] == "member"
    assert [r["de_rel"] for r in r_member["runs"]] \
        == [r["de_rel"] for r in r_shared["runs"]]
    assert r_member["force_evals_total"] == r_shared["force_evals_total"]


# --------------------------------------------------------------------------
# plumbing: driver strategy routing + validation
# --------------------------------------------------------------------------
def test_driver_block_strategy_reports_per_shard_tiles(tmp_path):
    """strategy + block routes through the sharded engine (here on the
    1-device mesh every local path still sees) and reports per-shard
    grid_tiles."""
    cfg = driver.SimConfig(scenario="binary_plummer", n=24, seed=1,
                           t_end=0.03125, stepper="block", dt_max=1 / 64,
                           n_levels=4, compaction="gather", block_i=8,
                           block_j=128, strategy="mesh_sharded", devices=1,
                           impl="xla", diag_every=16,
                           out=str(tmp_path / "r.json"))
    report = driver.run(cfg)
    assert report["strategy"] == "mesh_sharded"
    assert len(report["grid_tiles_per_shard"]) == 1
    assert report["grid_tiles_total"] == sum(report["grid_tiles_per_shard"])
    # compaction engaged: fewer than the dense per-shard schedule
    plan = ops.CapacityPlan(24, 24, 8, 128)
    assert report["grid_tiles_total"] < plan.dense_tiles * report["steps"]
    assert report["steps"] > 0 and report["force_evals_total"] > 0


def test_driver_bucket_mode_validation():
    with pytest.raises(ValueError, match="bucket_mode"):
        driver.SimConfig(dt=0.01, bucket_mode="widest").resolved_stepper()
    with pytest.raises(ValueError, match="no buckets to share"):
        driver.SimConfig(stepper="block",
                         bucket_mode="shared").resolved_stepper()
    # member mode is the inert default everywhere
    driver.SimConfig(dt=0.01).resolved_stepper()
