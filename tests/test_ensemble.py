"""Ensemble engine: batching round-trips, ensemble-vs-sequential numerical
equivalence, strategy-label equivalence, and the driver's telemetry report."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hermite
from repro.core.evaluate import make_evaluator
from repro.core.strategies import STRATEGIES
from repro.sim import driver, ensemble as ens, scenarios


def _states(b=3, n=32):
    return [scenarios.make("plummer", n, seed=s) for s in range(b)]


def test_stack_unstack_roundtrip():
    states = _states()
    batched = ens.stack_states(states)
    assert batched.pos.shape == (3, 32, 3)
    for orig, back in zip(states, ens.unstack_states(batched)):
        np.testing.assert_array_equal(np.asarray(orig.pos),
                                      np.asarray(back.pos))
        np.testing.assert_array_equal(np.asarray(orig.mass),
                                      np.asarray(back.mass))


def test_stack_rejects_mismatched_n():
    with pytest.raises(ValueError):
        ens.stack_states([scenarios.make("plummer", 32),
                          scenarios.make("plummer", 48)])


def test_ensemble_matches_sequential_fixed_dt():
    """The batched vmapped loop reproduces per-run evolve_scan exactly."""
    states = _states()
    out_b = ens.evolve_ensemble(ens.stack_states(states), n_steps=4, dt=1e-2)
    ev = make_evaluator(impl="xla")
    for i, s in enumerate(states):
        ref = hermite.evolve_scan(s, ev, n_steps=4, dt=1e-2)
        np.testing.assert_allclose(np.asarray(out_b.pos[i]),
                                   np.asarray(ref.pos),
                                   rtol=1e-12, atol=1e-12)
        np.testing.assert_allclose(np.asarray(out_b.vel[i]),
                                   np.asarray(ref.vel),
                                   rtol=1e-12, atol=1e-12)


def test_ensemble_strategy_labels_equivalent():
    """Independent runs have no cross-run comms: every strategy label yields
    the same one-step result (single-device mesh here; the multi-device
    batch sharding is exercised in the slow subprocess test)."""
    batched = ens.stack_states(_states())
    outs = [ens.evolve_ensemble(batched, n_steps=1, dt=1e-2, strategy=s)
            for s in ("single",) + STRATEGIES]
    for out in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0].pos),
                                      np.asarray(out.pos))
    with pytest.raises(ValueError):
        ens.evolve_ensemble(batched, n_steps=1, dt=1e-2, strategy="bogus")


def test_adaptive_ensemble_reaches_t_end_and_conserves():
    batched = ens.stack_states(_states(b=2, n=48))
    batched = ens.ensemble_initialize(batched)
    e0 = np.asarray(ens.batched_total_energy(batched))
    h = cnt = None
    for _ in range(64):
        batched, h, cnt = ens.ensemble_run_adaptive(
            batched, t_end=0.125, n_steps=16, h_prev=h, n_taken=cnt)
        if float(np.min(np.asarray(batched.time))) >= 0.125:
            break
    times = np.asarray(batched.time)
    np.testing.assert_allclose(times, 0.125, rtol=0, atol=1e-12)
    e1 = np.asarray(ens.batched_total_energy(batched))
    assert np.abs((e1 - e0) / e0).max() < 1e-3
    # per-run productive step counts are positive and can differ
    cnt = np.asarray(cnt)
    assert (cnt > 0).all()


def test_ensemble_rejects_unknown_impl():
    """pallas/pallas_interpret are vmap-safe since the padded-ensemble PR;
    only genuinely unknown impls are rejected."""
    with pytest.raises(ValueError):
        ens.evolve_ensemble(ens.stack_states(_states(b=2)), n_steps=1,
                            dt=1e-2, impl="bogus")
    with pytest.raises(ValueError):
        ens.evolve_ensemble(ens.stack_states(_states(b=2)), n_steps=1,
                            dt=1e-2, impl="pallas_marked")


def test_driver_single_run_report(tmp_path):
    out = str(tmp_path / "report.json")
    report = driver.run(driver.SimConfig(
        scenario="king", n=48, t_end=0.05, dt=1.0 / 256, impl="xla",
        diag_every=4, out=out))
    assert report["de_rel"] < 1e-3
    assert report["steps"] > 0 and report["wall_s"] > 0
    assert report["modeled"]["energy_J"] > 0
    assert report["modeled"]["edp_Js"] > 0
    assert report["snapshots"][0]["step"] == 0
    import json
    on_disk = json.load(open(out))
    assert on_disk["scenario"] == "king" and on_disk["de_rel"] < 1e-3


def test_driver_ensemble_report():
    report = driver.run(driver.SimConfig(
        scenario="merger", n=32, ensemble=3, t_end=0.05, diag_every=8,
        impl="xla"))
    assert report["ensemble"] == 3 and len(report["runs"]) == 3
    assert report["de_rel"] < 1e-3
    assert {r["seed"] for r in report["runs"]} == {0, 1, 2}
    assert report["t_final"] >= 0.05 - 1e-12


@pytest.mark.slow
def test_ensemble_batch_sharding_2dev_subprocess():
    """Multi-device batch sharding matches the single-device result (needs
    placeholder devices before jax init, hence the subprocess)."""
    import os
    import subprocess
    import sys

    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.sim import scenarios, ensemble as ens

states = [scenarios.make("plummer", 32, seed=s) for s in range(3)]  # 3 % 2 != 0
b = ens.stack_states(states)
one = ens.evolve_ensemble(b, n_steps=3, dt=1e-2)
two = ens.evolve_ensemble(b, n_steps=3, dt=1e-2, devices=jax.devices())
err = float(np.abs(np.asarray(one.pos) - np.asarray(two.pos)).max())
assert err < 1e-12, err
print("SHARDED-ENSEMBLE: OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert "SHARDED-ENSEMBLE: OK" in res.stdout
