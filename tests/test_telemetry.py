"""Telemetry units: energy-model pinning, finalize/report round-trips,
report paths, and the versioned ``metrics`` payload contract."""

import json

import pytest

from repro.obs import energy, metrics
from repro.sim import telemetry


# --------------------------------------------------------------------------
# energy model: single source of truth (paper Fig. 6 / Table 1 constants)
# --------------------------------------------------------------------------
def test_energy_constants_pinned():
    assert energy.P_CHIP == 170.0
    assert energy.P_HOST == 250.0
    assert energy.IDLE_FRAC == 0.35
    assert energy.DEFAULT_UTIL == 0.6


def test_modeled_energy_math():
    m = energy.modeled_energy(10.0, 2, util=0.5)
    watts = energy.P_HOST + 2 * energy.P_CHIP * (
        energy.IDLE_FRAC + (1 - energy.IDLE_FRAC) * 0.5)
    assert m["peak_W"] == pytest.approx(watts)
    assert m["energy_J"] == pytest.approx(10.0 * watts)
    assert m["edp_Js"] == pytest.approx(m["energy_J"] * 10.0)


def test_modeled_energy_rejects_out_of_range_util():
    """util is an occupancy *fraction*: util > 1 (a raw roofline ratio) or a
    negative value would silently model above-nameplate chip power in every
    EDP row downstream — the model must refuse, not extrapolate."""
    for bad in (1.2, -0.1, 2.0, float("nan")):
        with pytest.raises(ValueError):
            energy.modeled_energy(10.0, 2, util=bad)
    # the boundaries are legal occupancies
    assert energy.modeled_energy(1.0, 1, util=0.0)["peak_W"] == \
        pytest.approx(energy.P_HOST + energy.P_CHIP * energy.IDLE_FRAC)
    assert energy.modeled_energy(1.0, 1, util=1.0)["peak_W"] == \
        pytest.approx(energy.P_HOST + energy.P_CHIP)


def test_energy_model_not_duplicated():
    """telemetry and benchmarks.common must re-export the obs.energy model,
    not carry their own copies (the single-source-of-truth contract)."""
    from benchmarks import common
    assert telemetry.modeled_energy is energy.modeled_energy
    assert common.modeled_energy is energy.modeled_energy
    assert (common.P_CHIP, common.P_HOST, common.IDLE_FRAC) == \
        (energy.P_CHIP, energy.P_HOST, energy.IDLE_FRAC)
    assert telemetry.DEFAULT_UTIL == energy.DEFAULT_UTIL


# --------------------------------------------------------------------------
# finalize / write_report round-trip
# --------------------------------------------------------------------------
def _recorder():
    rec = telemetry.TelemetryRecorder({"scenario": "plummer", "n": 64})
    rec.record_step(1, 0.1, 0.5)
    rec.record_step(2, 0.2, 0.3)
    rec.record_snapshot(2, 0.2, energy=-0.25, de_rel=1e-9)
    return rec


def test_finalize_report_roundtrip(tmp_path):
    report = _recorder().finalize(n_bodies=64, ensemble=1, n_devices=2)
    path = telemetry.write_report(report, str(tmp_path / "sub" / "r.json"))
    loaded = json.load(open(path))
    assert loaded["scenario"] == "plummer"
    assert loaded["steps"] == 2
    assert loaded["wall_s"] == pytest.approx(0.8)
    assert loaded["modeled"]["edp_Js"] == pytest.approx(
        energy.modeled_energy(0.8, 2, energy.DEFAULT_UTIL)["edp_Js"])
    assert loaded["snapshots"][-1]["de_rel"] == pytest.approx(1e-9)


def test_finalize_metrics_payload_roundtrip(tmp_path):
    reg = metrics.MetricsRegistry()
    reg.counter("sim.events", unit="events").inc(37)
    report = _recorder().finalize(n_bodies=64, metrics=reg.snapshot())
    loaded = json.load(open(telemetry.write_report(
        report, str(tmp_path / "r.json"))))
    m = loaded["metrics"]
    assert m["schema_version"] == metrics.METRICS_SCHEMA_VERSION
    assert m["counters"]["sim.events"]["value"] == 37.0
    metrics.validate_snapshot(m)


def test_finalize_rejects_malformed_metrics():
    with pytest.raises(ValueError):
        _recorder().finalize(n_bodies=64, metrics={"schema_version": 999})
    # reports without a metrics payload simply omit the key
    assert "metrics" not in _recorder().finalize(n_bodies=64)


def test_finalize_per_run_steps_length_mismatch():
    with pytest.raises(ValueError):
        _recorder().finalize(n_bodies=64, n_active=[64, 64],
                             per_run_steps=[2])


# --------------------------------------------------------------------------
# default report paths
# --------------------------------------------------------------------------
def test_default_report_path_shape(tmp_path):
    path = telemetry.default_report_path(
        {"scenario": "king", "n": 256, "ensemble": 1, "strategy": "single"},
        root=str(tmp_path))
    assert path.endswith("experiments/sim/king_n256_single.json")
    e8 = telemetry.default_report_path(
        {"scenario": "king", "n": 256, "ensemble": 8, "strategy": "ring"},
        root=str(tmp_path))
    assert e8.endswith("experiments/sim/king_n256_e8_ring.json")


def test_default_report_path_collisions_distinguished(tmp_path):
    """Configs that differ in any path component never share a report file;
    a re-run of the *same* config deliberately overwrites (one report per
    configuration, not per invocation)."""
    metas = [
        {"scenario": "king", "n": 256, "ensemble": 1, "strategy": "single"},
        {"scenario": "king", "n": 512, "ensemble": 1, "strategy": "single"},
        {"scenario": "king", "n": 256, "ensemble": 2, "strategy": "single"},
        {"scenario": "king", "n": 256, "ensemble": 1, "strategy": "ring"},
        {"scenario": "plummer", "n": 256, "ensemble": 1,
         "strategy": "single"},
    ]
    paths = [telemetry.default_report_path(m, root=str(tmp_path))
             for m in metas]
    assert len(set(paths)) == len(paths)
    same = telemetry.default_report_path(metas[0], root=str(tmp_path))
    telemetry.write_report({"run": 1}, same)
    telemetry.write_report({"run": 2}, same)
    assert json.load(open(same)) == {"run": 2}
