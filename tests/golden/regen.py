"""Regenerate the committed golden-trajectory reference files.

Run from the repo root after an *intentional* physics change:

    PYTHONPATH=src python tests/golden/regen.py

Each golden file records the final (pos, vel) of a short fixed-dt Hermite-6
integration computed with the FP64 golden evaluator (pure-jnp oracle at host
precision — no device kernel involved), plus the exact run recipe.  The
regression test (``tests/test_golden_trajectories.py``) replays the recipe
through every kernel/strategy combination and asserts agreement, so a silent
physics change in any kernel refactor fails loudly.  Commit the regenerated
JSON together with the change that motivated it.

Cases whose meta carries ``devices: k`` need a forced k-device host mesh,
which must be configured BEFORE jax initializes — ``main()`` re-executes
itself per such case in a subprocess with the right ``XLA_FLAGS``, so the
multi-device fixtures of ``tests/test_strategy_compaction.py`` regenerate
from the same single command as everything else.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import hermite  # noqa: E402
from repro.core.evaluate import make_evaluator  # noqa: E402
from repro.sim import ensemble as ens  # noqa: E402
from repro.sim import scenarios  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))

#: The committed golden cases: (filename, scenario recipe).  ``mode="block"``
#: cases run the hierarchical block-timestep engine (B=1 batch) instead of
#: the fixed-dt scan; their recipe pins (dt_max, n_levels, eta, t_end) and
#: the recorded ``n_events`` fingerprints the level schedule itself — a
#: kernel whose timestep quantization drifts fails on the event count before
#: it fails on positions.
CASES = {
    "two_body.json": dict(scenario="two_body", n=2, seed=0,
                          dt=1.0 / 256, n_steps=32, order=6, eps=1e-7),
    "plummer16.json": dict(scenario="plummer", n=16, seed=42,
                           dt=1.0 / 256, n_steps=32, order=6, eps=1e-7),
    "binary_plummer_block.json": dict(
        scenario="binary_plummer", n=24, seed=1, mode="block",
        dt_max=1.0 / 64, n_levels=4, t_end=0.0625, eta=0.02, order=6,
        eps=1e-7),
    # forced-multi-device fixture: the same block recipe, its domain sharded
    # over a 2-device host mesh with shard-local compaction (mode
    # "block_strategy" runs FP32 strategy evaluation — no fp64 oracle exists
    # for the distributed layer, so the differential suite compares this
    # golden at FP32 tolerance and leans on gather==none being bit-for-bit).
    # Needs more devices than a default process has: main() re-executes
    # itself in a subprocess with XLA_FLAGS set before jax initializes.
    "binary_plummer_block_2dev.json": dict(
        scenario="binary_plummer", n=24, seed=1, mode="block_strategy",
        strategy="mesh_sharded", impl="xla", devices=2,
        compaction="gather", block_i=8, block_j=128,
        dt_max=1.0 / 64, n_levels=4, t_end=0.0625, eta=0.02, order=6,
        eps=1e-7),
    # Fused (batch, dev) mesh fixture: B=2 plummer members x P=2 domain
    # shards in ONE shard_map over 4 host devices, capacity switch sized
    # from the host-side analytic occupancy bound.  pallas_interpret's
    # fixed j-block sweep is launch-extent-independent, so this golden is
    # bit-identical to the 1-D batch-sharded ensemble run AND the per-
    # member 1-D mesh_sharded strategy run of the same recipe (the replay
    # test in tests/test_fused_mesh.py pins all three against this file).
    # Block sizes stay at the kernel defaults: the one-shot wrappers
    # bootstrap with default tiles, so explicit tiles here would change
    # the init-force summation order between the layouts' entry points.
    "plummer_block_fused_2x2.json": dict(
        scenario="plummer", n=64, seed=1, ensemble=2, mode="block_fused",
        impl="pallas_interpret", devices=4, mesh=[2, 2],
        compaction="gather",
        dt_max=0.0625, n_levels=4, t_end=0.0625, eta=0.02, order=6,
        eps=1e-7),
    # Ahmad-Cohen neighbor split (sources="neighbor"): near force from
    # gathered per-block windows, far field NM08-predicted between level
    # refreshes.  The fp64 oracle pins the split itself (window build, far
    # capture, prediction blend) — the recorded positions are in the
    # engine's ORB-sorted row order, pos0 in build order.  The radius is
    # chosen so windows are real subsets (some blocks see all sources,
    # some few): both gather paths and the fallback-free steady state get
    # exercised.
    "binary_plummer_neighbor.json": dict(
        scenario="binary_plummer", n=64, seed=1, mode="block",
        sources="neighbor", neighbor_radius=0.5, refresh_levels=2,
        block_i=16, block_j=16,
        dt_max=1.0 / 64, n_levels=4, t_end=0.0625, eta=0.02, order=6,
        eps=1e-7),
}


def integrate(meta: dict):
    state = scenarios.make(meta["scenario"], meta["n"], seed=meta["seed"])
    if meta.get("mode") == "block_fused":
        states = [scenarios.make(meta["scenario"], meta["n"],
                                 seed=meta["seed"] + i)
                  for i in range(meta["ensemble"])]
        batched, carry = ens.evolve_ensemble_block(
            states, t_end=meta["t_end"], dt_max=meta["dt_max"],
            n_levels=meta["n_levels"], eta=meta["eta"],
            order=meta["order"], eps=meta["eps"], impl=meta["impl"],
            compaction=meta["compaction"], mesh=tuple(meta["mesh"]),
            devices=jax.devices()[:meta["devices"]])
        # per-member event counts fingerprint the level schedule; per-
        # member tiles fingerprint the host-side analytic bucket sizing
        return (ens.stack_states(states), batched,
                [int(e) for e in np.asarray(carry.n_events)],
                [float(t) for t in np.asarray(carry.n_tiles)])
    if meta.get("mode") == "block_strategy":
        out, carry = ens.evolve_strategy_block(
            state, t_end=meta["t_end"], dt_max=meta["dt_max"],
            n_levels=meta["n_levels"], eta=meta["eta"], order=meta["order"],
            eps=meta["eps"], impl=meta["impl"], strategy=meta["strategy"],
            compaction=meta["compaction"], block_i=meta["block_i"],
            block_j=meta["block_j"], devices=meta["devices"])
        return state, out, int(carry.n_events)
    if meta.get("mode") == "block":
        kw = {k: meta[k] for k in ("sources", "neighbor_radius",
                                   "refresh_levels", "block_i", "block_j")
              if k in meta}
        batched, carry = ens.evolve_ensemble_block(
            [state], t_end=meta["t_end"], dt_max=meta["dt_max"],
            n_levels=meta["n_levels"], eta=meta["eta"], order=meta["order"],
            eps=meta["eps"], impl="fp64", **kw)
        out = jax.tree_util.tree_map(lambda x: x[0], batched)
        return state, out, int(carry.n_events[0])
    ev = make_evaluator(precision="fp64", order=meta["order"],
                        eps=meta["eps"])
    out = hermite.evolve_scan(state, ev, n_steps=meta["n_steps"],
                              dt=meta["dt"], order=meta["order"])
    return state, out, None


def _respawn(fname: str, devices: int) -> None:
    """Regenerate one case in a subprocess that forces ``devices``
    host-platform devices BEFORE jax initializes (the same constraint the
    multi-device tests work around; this keeps every committed golden —
    single- and multi-device — reproducible from one command)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(HERE, "..", "..", "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--only", fname],
        env=env, capture_output=True, text=True, timeout=1200)
    if res.returncode != 0:
        raise RuntimeError(
            f"multi-device regen of {fname} failed:\n{res.stderr[-2000:]}")
    print(res.stdout, end="")


def main(only: str | None = None):
    for fname, meta in CASES.items():
        if only is not None and fname != only:
            continue
        devices = int(meta.get("devices", 1))
        if devices > jax.device_count():
            _respawn(fname, devices)
            continue
        state, out, n_events, *rest = integrate(meta)
        if meta.get("mode") == "block_strategy":
            evaluator = (f"fp32 {meta['strategy']} strategy x "
                         f"{meta['devices']} devices")
        elif meta.get("mode") == "block_fused":
            evaluator = (f"fp32 fused {tuple(meta['mesh'])} mesh x "
                         f"{meta['devices']} devices ({meta['impl']})")
        else:
            evaluator = "fp64 golden (kernels.ref at x64)"
        doc = {
            "meta": {**meta, "generator": "tests/golden/regen.py",
                     "evaluator": evaluator},
            "pos0": np.asarray(state.pos, np.float64).tolist(),
            "vel0": np.asarray(state.vel, np.float64).tolist(),
            "mass": np.asarray(state.mass, np.float64).tolist(),
            "pos": np.asarray(out.pos, np.float64).tolist(),
            "vel": np.asarray(out.vel, np.float64).tolist(),
            "energy": float(jnp.sum(
                0.5 * out.mass * jnp.sum(out.vel**2, axis=-1)
                + 0.5 * out.mass * out.pot)),
        }
        if n_events is not None:
            doc["n_events"] = n_events
        if rest:
            doc["n_tiles"] = rest[0]
        path = os.path.join(HERE, fname)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        t_end = meta["t_end"] if "t_end" in meta \
            else meta["dt"] * meta["n_steps"]
        print(f"wrote {path} (t_end={t_end:.6f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, metavar="FNAME",
                    help="regenerate a single case (used by the "
                         "multi-device subprocess respawn)")
    main(only=ap.parse_args().only)
