"""Regenerate the committed golden-trajectory reference files.

Run from the repo root after an *intentional* physics change:

    PYTHONPATH=src python tests/golden/regen.py

Each golden file records the final (pos, vel) of a short fixed-dt Hermite-6
integration computed with the FP64 golden evaluator (pure-jnp oracle at host
precision — no device kernel involved), plus the exact run recipe.  The
regression test (``tests/test_golden_trajectories.py``) replays the recipe
through every kernel/strategy combination and asserts agreement, so a silent
physics change in any kernel refactor fails loudly.  Commit the regenerated
JSON together with the change that motivated it.
"""

from __future__ import annotations

import json
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import hermite  # noqa: E402
from repro.core.evaluate import make_evaluator  # noqa: E402
from repro.sim import ensemble as ens  # noqa: E402
from repro.sim import scenarios  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))

#: The committed golden cases: (filename, scenario recipe).  ``mode="block"``
#: cases run the hierarchical block-timestep engine (B=1 batch) instead of
#: the fixed-dt scan; their recipe pins (dt_max, n_levels, eta, t_end) and
#: the recorded ``n_events`` fingerprints the level schedule itself — a
#: kernel whose timestep quantization drifts fails on the event count before
#: it fails on positions.
CASES = {
    "two_body.json": dict(scenario="two_body", n=2, seed=0,
                          dt=1.0 / 256, n_steps=32, order=6, eps=1e-7),
    "plummer16.json": dict(scenario="plummer", n=16, seed=42,
                           dt=1.0 / 256, n_steps=32, order=6, eps=1e-7),
    "binary_plummer_block.json": dict(
        scenario="binary_plummer", n=24, seed=1, mode="block",
        dt_max=1.0 / 64, n_levels=4, t_end=0.0625, eta=0.02, order=6,
        eps=1e-7),
}


def integrate(meta: dict):
    state = scenarios.make(meta["scenario"], meta["n"], seed=meta["seed"])
    if meta.get("mode") == "block":
        batched, carry = ens.evolve_ensemble_block(
            [state], t_end=meta["t_end"], dt_max=meta["dt_max"],
            n_levels=meta["n_levels"], eta=meta["eta"], order=meta["order"],
            eps=meta["eps"], impl="fp64")
        out = jax.tree_util.tree_map(lambda x: x[0], batched)
        return state, out, int(carry.n_events[0])
    ev = make_evaluator(precision="fp64", order=meta["order"],
                        eps=meta["eps"])
    out = hermite.evolve_scan(state, ev, n_steps=meta["n_steps"],
                              dt=meta["dt"], order=meta["order"])
    return state, out, None


def main():
    for fname, meta in CASES.items():
        state, out, n_events = integrate(meta)
        doc = {
            "meta": {**meta, "generator": "tests/golden/regen.py",
                     "evaluator": "fp64 golden (kernels.ref at x64)"},
            "pos0": np.asarray(state.pos, np.float64).tolist(),
            "vel0": np.asarray(state.vel, np.float64).tolist(),
            "mass": np.asarray(state.mass, np.float64).tolist(),
            "pos": np.asarray(out.pos, np.float64).tolist(),
            "vel": np.asarray(out.vel, np.float64).tolist(),
            "energy": float(jnp.sum(
                0.5 * out.mass * jnp.sum(out.vel**2, axis=1)
                + 0.5 * out.mass * out.pot)),
        }
        if n_events is not None:
            doc["n_events"] = n_events
        path = os.path.join(HERE, fname)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        t_end = meta["t_end"] if "t_end" in meta \
            else meta["dt"] * meta["n_steps"]
        print(f"wrote {path} (t_end={t_end:.6f})")


if __name__ == "__main__":
    main()
