"""Serving engine behaviour: batched generation, cache bookkeeping,
greedy determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.shardings import MeshRules
from repro.models import model, params as P
from repro.models.config import ArchConfig
from repro.serve import Engine, ServeConfig

RULES = MeshRules.single_device()
CFG = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 attn_chunked_above=10 ** 9, dtype="float32")


def _engine(temp=0.0):
    params = P.init_params(CFG, jax.random.PRNGKey(0))
    return Engine(CFG, RULES, params, ServeConfig(max_len=64,
                                                  temperature=temp))


def test_generate_shapes_and_stats():
    eng = _engine()
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 8), 0, 255)
    out, stats = eng.generate({"tokens": toks}, 5)
    assert out.shape == (3, 5)
    assert stats["tok_per_s"] > 0 and stats["prefill_s"] > 0


def test_greedy_is_deterministic():
    eng = _engine()
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 255)
    a, _ = eng.generate({"tokens": toks}, 6)
    b, _ = eng.generate({"tokens": toks}, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_greedy_matches_stepwise_forward():
    """Engine generation == argmax over the parallel forward, token by
    token (teacher-forced on its own outputs)."""
    eng = _engine()
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, 255)
    out, _ = eng.generate({"tokens": toks}, 4)
    seq = toks
    for i in range(4):
        logits, _ = model.forward(CFG, RULES, eng.params,
                                  {"tokens": seq, "labels": seq},
                                  train=False)
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        assert int(nxt[0]) == int(out[0, i]), i
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)


def test_cache_len_advances():
    params = P.init_params(CFG, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, 255)
    _, cache = model.prefill(CFG, RULES, params, {"tokens": toks}, max_len=32)
    assert int(cache["len"]) == 8
    _, cache = model.decode_step(CFG, RULES, params, cache,
                                 jnp.zeros((2, 1), jnp.int32))
    assert int(cache["len"]) == 9
