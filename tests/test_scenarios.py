"""Scenario-library sanity: registry contract, COM frame, virial ratio,
energy normalization, and construction-time validation."""

import numpy as np
import pytest

from repro.sim import scenarios


def _build(name, n=96, seed=3, **params):
    if name == "two_body":
        n = 2  # fixed analytic configuration; other n are rejected
    return scenarios.make(name, n, seed=seed, **params)


@pytest.mark.parametrize("name", scenarios.available())
def test_scenario_builds_in_com_frame(name):
    spec = scenarios.get_spec(name)
    state = _build(name, n=max(96, spec.min_n))
    d = scenarios.state_diagnostics(state)
    assert d["com_pos"] < 1e-10, (name, d)
    assert d["com_vel"] < 1e-10, (name, d)
    assert np.isfinite(d["energy"]), (name, d)
    assert d["energy"] < 0.0, (name, d)          # every scenario is bound
    assert abs(d["total_mass"] - 1.0) < 1e-12, (name, d)
    mass = np.asarray(state.mass)
    assert (mass > 0).all(), name


@pytest.mark.parametrize(
    "name", [n for n in scenarios.available()
             if scenarios.get_spec(n).equilibrium])
def test_equilibrium_scenarios_near_virial(name):
    state = _build(name, n=max(128, scenarios.get_spec(name).min_n))
    q = scenarios.state_diagnostics(state)["virial_ratio"]
    assert abs(q - 0.5) < scenarios.VIRIAL_TOL, (name, q)


@pytest.mark.parametrize("name", ["king", "cold_collapse"])
def test_rescaled_scenarios_hit_standard_energy(name):
    state = _build(name, n=128)
    e = scenarios.state_diagnostics(state)["energy"]
    assert abs(e + 0.25) < 1e-10, (name, e)


def test_king_concentration_increases_with_w0():
    def core_radius(w0):
        st = _build("king", n=512, seed=2, w0=w0)
        r = np.sort(np.linalg.norm(np.asarray(st.pos), axis=1))
        return r[len(r) // 10]                   # 10%-mass radius
    assert core_radius(9.0) < core_radius(3.0)


def test_cold_collapse_is_cold():
    state = _build("cold_collapse", n=128)
    assert scenarios.state_diagnostics(state)["kinetic"] < 1e-12
    state = _build("cold_collapse", n=128, virial_ratio=0.1)
    q = scenarios.state_diagnostics(state)["virial_ratio"]
    assert abs(q - 0.1) < 0.02, q


def test_merger_has_two_separated_clusters():
    sep = 4.0
    state = _build("merger", n=128, separation=sep)
    pos = np.asarray(state.pos)
    a, b = pos[:64].mean(0), pos[64:].mean(0)
    assert abs(np.linalg.norm(a - b) - np.hypot(sep, 0.5)) < 0.5
    # approaching along x
    vel = np.asarray(state.vel)
    assert vel[:64, 0].mean() < -0.05 and vel[64:, 0].mean() > 0.05


def test_binary_plummer_contains_tight_pairs():
    sma = 0.02
    state = _build("binary_plummer", n=128, binary_frac=0.2, sma=sma)
    pos = np.asarray(state.pos)
    d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    d[np.diag_indices_from(d)] = np.inf
    n_tight = (d.min(1) < 1.5 * sma).sum()
    assert n_tight >= 2 * int(round(0.2 * 128 / 2)), n_tight


def test_kepler_disk_is_thin_and_rotating():
    state = _build("kepler_disk", n=128)
    pos, vel = np.asarray(state.pos), np.asarray(state.vel)
    assert np.abs(pos[1:, 2]).max() < 0.2       # thin
    lz = pos[1:, 0] * vel[1:, 1] - pos[1:, 1] * vel[1:, 0]
    assert (lz > 0).all()                       # coherent rotation


def test_unknown_scenario_and_bad_params_raise():
    with pytest.raises(scenarios.ScenarioError):
        scenarios.make("no_such_model", 64)
    with pytest.raises(scenarios.ScenarioError):
        scenarios.make("king", 64, w0=99.0)
    with pytest.raises(scenarios.ScenarioError):
        scenarios.make("merger", 4)             # below min_n


def test_validation_rejects_out_of_com_frame():
    spec = scenarios.get_spec("plummer")
    diag = {"com_pos": 1.0, "com_vel": 0.0, "kinetic": 0.25,
            "potential": -0.5, "energy": -0.25, "virial_ratio": 0.5,
            "total_mass": 1.0}
    with pytest.raises(scenarios.ScenarioError):
        scenarios._validate(spec, diag)


def test_scenario_dataclass_reproducible():
    s = scenarios.Scenario(name="king", n=64, seed=9, params={"w0": 4.0})
    a, b = s.build(), s.build()
    np.testing.assert_array_equal(np.asarray(a.pos), np.asarray(b.pos))
    np.testing.assert_array_equal(np.asarray(a.vel), np.asarray(b.vel))
