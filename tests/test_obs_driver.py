"""Driver/CLI observability plumbing + the tentpole acceptance criteria:

* a traced ``block``+``gather`` run exports Perfetto-loadable Chrome-trace
  JSON with nested macro-step -> event -> kernel-launch spans;
* the telemetry ``metrics`` payload reports launched tiles within the
  analytic ``hermite.block_level_occupancy`` bound;
* ``--trace`` / ``--metrics-interval`` thread from the CLI through
  ``SimConfig`` into the report.
"""

import json

import pytest

from repro.obs import metrics as obs_metrics
from repro.sim import driver

#: small-but-real block+gather config: block_i=8 gives the 32-particle grid
#: four i-tiles (several capacity buckets), so compaction has tiles to drop
#: and the occupancy bound is a non-trivial ceiling
BLOCK_KW = dict(scenario="plummer", n=32, ensemble=2, t_end=0.0625,
                stepper="block", dt_max=0.0625, n_levels=3,
                compaction="gather", block_i=8, block_j=32,
                impl="xla", diag_every=4, validate_ic=False)


@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    out = tmp_path_factory.mktemp("obs")
    trace_path = str(out / "trace.json")
    cfg = driver.SimConfig(trace=trace_path, metrics_interval=1, **BLOCK_KW)
    report = driver.run(cfg)
    return report, json.load(open(trace_path))


def test_trace_exported_and_loadable(traced_run):
    report, doc = traced_run
    assert report["trace_path"].endswith("trace.json")
    assert doc["otherData"]["producer"] == "repro.obs.trace"
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]


def test_trace_has_nested_span_taxonomy(traced_run):
    _, doc = traced_run
    by = {}
    for ev in doc["traceEvents"]:
        by.setdefault(ev["name"], []).append(ev)
    assert by.get("macro-step") and by.get("event") and by.get(
        "kernel-launch")
    # every synthetic child sits inside a measured macro-step (Perfetto
    # infers nesting from exactly this time containment)
    def inside(child, parent, tol=1.0):
        return (parent["ts"] <= child["ts"] + tol and
                child["ts"] + child["dur"] <= parent["ts"] + parent["dur"]
                + tol)
    for name in ("event", "kernel-launch"):
        for child in by[name]:
            assert child["args"]["synthetic"] is True
            assert any(inside(child, ms) for ms in by["macro-step"]), \
                f"orphan {name} span at ts={child['ts']}"
    for kl in by["kernel-launch"]:
        assert any(inside(kl, ev) for ev in by["event"])


def test_macro_step_args_carry_measured_aggregates(traced_run):
    report, doc = traced_run
    macro = [e for e in doc["traceEvents"] if e["name"] == "macro-step"]
    assert sum(e["args"]["events"] for e in macro) == \
        sum(r["steps"] for r in report["runs"])
    assert sum(e["args"]["tiles"] for e in macro) == pytest.approx(
        report["grid_tiles_total"])


def test_metrics_payload_in_report(traced_run):
    report, _ = traced_run
    m = report["metrics"]
    obs_metrics.validate_snapshot(m)
    c = m["counters"]
    assert c["sim.events"]["value"] == sum(r["steps"] for r in report["runs"])
    assert c["sim.tiles_launched"]["value"] == pytest.approx(
        report["grid_tiles_total"])
    # the lru-cached engine constructor ran (at least init + block engines)
    assert c["engine.cache_miss"]["value"] >= 1
    assert c["engine.cache_miss.block"]["value"] >= 1
    assert c["engine.bucket_branches"]["value"] >= 1
    assert m["histograms"]["sim.active_fraction"]["count"] > 0
    assert 0.0 < m["histograms"]["sim.active_fraction"]["mean"] <= 1.0


def test_tiles_within_occupancy_bound(traced_run):
    """Acceptance: launched tiles never exceed the analytic a-priori bound
    from ``hermite.block_level_occupancy`` (its entry 0 — every real
    particle — is the largest active set any tick can see)."""
    report, _ = traced_run
    m = report["metrics"]
    launched = m["counters"]["sim.tiles_launched"]["value"]
    bound = m["gauges"]["sim.tiles_occupancy_bound"]["value"]
    dense = m["counters"]["sim.tiles_dense_baseline"]["value"]
    assert 0 < launched <= bound <= dense


def test_bucket_hits_distribution(traced_run):
    report, _ = traced_run
    hits = report["metrics"]["gauges"]["sim.bucket_hits"]["value"]
    assert len(hits) >= 2  # block_i=8 at N=32: a real bucket schedule
    # every productive member-event dispatched exactly one bucket
    assert sum(hits) == sum(r["steps"] for r in report["runs"])


def test_metrics_interval_attaches_series(traced_run):
    report, _ = traced_run
    tagged = [s for s in report["snapshots"] if "metrics" in s]
    assert tagged, "metrics_interval=1 must tag every chunk snapshot"
    for snap in tagged:
        obs_metrics.validate_snapshot(snap["metrics"])
    # the series is monotone in the events counter (counters never decrease)
    vals = [s["metrics"]["counters"]["sim.events"]["value"] for s in tagged]
    assert vals == sorted(vals)


def test_untraced_run_has_metrics_but_no_trace():
    report = driver.run(driver.SimConfig(**BLOCK_KW))
    assert "trace_path" not in report
    obs_metrics.validate_snapshot(report["metrics"])


def test_metrics_interval_validation():
    with pytest.raises(ValueError, match="metrics_interval"):
        driver.run(driver.SimConfig(metrics_interval=-1, **BLOCK_KW))


def test_cli_threads_trace_and_metrics_interval(tmp_path, capsys):
    from repro.launch import sim_run
    trace_path = str(tmp_path / "cli_trace.json")
    out_path = str(tmp_path / "cli_report.json")
    rc = sim_run.main([
        "--scenario", "plummer", "--n", "32", "--t-end", "0.0625",
        "--stepper", "block", "--compaction", "gather",
        "--block-i", "8", "--block-j", "32", "--impl", "xla",
        "--diag-every", "4", "--no-validate",
        "--trace", trace_path, "--metrics-interval", "1",
        "--out", out_path])
    assert rc == 0
    stdout = capsys.readouterr().out
    assert trace_path in stdout and "sim.events" in stdout
    report = json.load(open(out_path))
    assert report["trace_path"] == trace_path
    obs_metrics.validate_snapshot(report["metrics"])
    doc = json.load(open(trace_path))
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"macro-step", "event", "kernel-launch"} <= names


def test_mixed_run_reports_pad_waste():
    report = driver.run(driver.SimConfig(
        mix=(("plummer", 16), ("plummer", 32)), t_end=0.0625,
        stepper="block", dt_max=0.0625, n_levels=2, impl="xla",
        diag_every=4, validate_ic=False))
    waste = report["metrics"]["gauges"]["sim.pad_waste"]["value"]
    assert waste == pytest.approx(1.0 - (16 + 32) / (2 * 32))
