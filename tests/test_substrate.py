"""Substrate tests: optimizer, data pipeline, checkpoint store, trainer
fault-tolerance behaviours, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.data import BatchSpec, SyntheticLM, batch_spec_for
from repro.distributed import compression
from repro.distributed.shardings import MeshRules
from repro.models import config as C
from repro.models import params as P
from repro.models.config import ArchConfig
from repro.optim import AdamW, warmup_cosine, global_norm
from repro.train import StragglerMonitor, Trainer, TrainerConfig, \
    make_train_step

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  attn_chunked_above=10 ** 9, dtype="float32")
RULES = MeshRules.single_device()


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state, _ = opt.update(grads, state, params)
        params = {"w": params["w"] + upd["w"]}
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    opt = AdamW(learning_rate=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(m["gnorm"]) > 1e5  # raw norm reported pre-clip


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr(55)) < float(lr(20))


# ---------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_sharded():
    spec = BatchSpec(batch=8, seq=16)
    a = SyntheticLM(TINY, spec, seed=3)(5)
    b = SyntheticLM(TINY, spec, seed=3)(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(TINY, spec, seed=3)(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the global batch deterministically
    shards = [SyntheticLM(TINY, spec, seed=3, shard=i, num_shards=4)(5)
              for i in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    assert len({s["tokens"].tobytes() for s in shards}) == 4


def test_memmap_corpus(tmp_path):
    from repro.data import MemmapCorpus
    path = tmp_path / "corpus.bin"
    np.arange(10_000, dtype=np.int32).tofile(path)
    spec = BatchSpec(batch=4, seq=32)
    src = MemmapCorpus(TINY, spec, str(path), seed=0)
    batch = src(0)
    assert batch["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(batch["labels"][:, :-1],
                                  batch["tokens"][:, 1:])


def test_frontend_stub_batches():
    audio = C.get("seamless-m4t-medium")
    spec = batch_spec_for(audio, 2, 32)
    b = SyntheticLM(audio, spec)(0)
    assert b["frames"].shape == (2, 32, audio.d_model)
    vlm = C.get("qwen2-vl-2b")
    spec = batch_spec_for(vlm, 2, 512)
    b = SyntheticLM(vlm, spec)(0)
    assert b["patches"].shape[1] + b["tokens"].shape[1] == 512


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_prune(tmp_path):
    params = P.init_params(TINY, jax.random.PRNGKey(0))
    opt = AdamW()
    tree = {"params": params, "opt": opt.init(params)}
    for step in (1, 2, 3, 4):
        store.save(str(tmp_path), step, tree, keep=2)
    assert store.available_steps(str(tmp_path)) == [3, 4]
    step, back = store.restore_latest(str(tmp_path), tree)
    assert step == 4
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_partial(tmp_path):
    tree = {"w": jnp.arange(10)}
    store.save(str(tmp_path), 7, tree)
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


def test_checkpoint_shape_mismatch_raises(tmp_path):
    store.save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        store.restore(str(tmp_path), 1, {"w": jnp.zeros((5,))})


# ---------------------------------------------------------------- trainer
def test_trainer_learns_and_resumes(tmp_path):
    rng = np.random.default_rng(0)
    fixed = rng.integers(0, 256, size=(4, 33), dtype=np.int32)
    data = lambda step: {"tokens": fixed[:, :-1],   # noqa: E731
                         "labels": fixed[:, 1:]}
    opt = AdamW(learning_rate=3e-3)
    t1 = Trainer(TINY, RULES, opt, data,
                 TrainerConfig(steps=30, ckpt_every=10,
                               ckpt_dir=str(tmp_path), log_every=1000),
                 log=lambda s: None)
    _, _, hist = t1.run()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8
    t2 = Trainer(TINY, RULES, opt, data,
                 TrainerConfig(steps=32, ckpt_every=10,
                               ckpt_dir=str(tmp_path), log_every=1000),
                 log=lambda s: None)
    _, _, h2 = t2.run()
    assert h2[0]["step"] == 30   # resumed, not restarted


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(warmup=3, k=3.0)
    flagged = [mon.observe(t) for t in
               [0.10, 0.11, 0.10, 0.10, 0.11, 0.10, 0.95, 0.10]]
    assert flagged[6] is True
    assert sum(flagged) == 1
    assert mon.flagged == 1


def test_grad_accum_equivalence():
    data = SyntheticLM(TINY, BatchSpec(batch=4, seq=32), seed=1)
    batch = {k: jnp.asarray(v) for k, v in data(0).items()}
    params = P.init_params(TINY, jax.random.PRNGKey(1))
    opt = AdamW(learning_rate=1e-3)
    s1 = make_train_step(TINY, RULES, opt, accum=1)
    s2 = make_train_step(TINY, RULES, opt, accum=2)
    p1, _, m1 = s1(params, opt.init(params), batch)
    p2, _, m2 = s2(params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    assert abs(float(m1["gnorm"]) - float(m2["gnorm"])) < 1e-5
    # Adam's m/sqrt(v) amplifies fp32 reduction-order noise at step 1;
    # updates are <= lr = 1e-3, so 5e-5 asserts ~5% agreement per update.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=5e-5)


# ---------------------------------------------------------------- compression
def test_quantize_roundtrip_bound():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, scale = compression.quantize(x)
    err = jnp.abs(compression.dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-7


def test_error_feedback_telescopes():
    """Sum of compressed grads + final error == sum of true grads."""
    rng = np.random.default_rng(6)
    gs = [jnp.asarray(rng.standard_normal(64), jnp.float32) * 10 ** (-i)
          for i in range(6)]
    e = jnp.zeros(64)
    total_hat = jnp.zeros(64)
    for g in gs:
        g_hat, e = compression.compress_leaf(g, e)
        total_hat = total_hat + g_hat
    total = sum(gs)
    np.testing.assert_allclose(np.asarray(total_hat + e), np.asarray(total),
                               rtol=1e-5, atol=1e-5)


def test_train_step_with_compression_converges():
    rng = np.random.default_rng(0)
    fixed = rng.integers(0, 256, size=(4, 33), dtype=np.int32)
    batch = {"tokens": jnp.asarray(fixed[:, :-1]),
             "labels": jnp.asarray(fixed[:, 1:])}
    params = P.init_params(TINY, jax.random.PRNGKey(2))
    opt = AdamW(learning_rate=3e-3)
    step = jax.jit(make_train_step(TINY, RULES, opt,
                                   grad_compression="int8"))
    state = opt.init(params)
    err = compression.zeros_error(params)
    losses = []
    for _ in range(25):
        params, state, m, err = step(params, state, batch, err)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])
