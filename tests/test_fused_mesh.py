"""Fused (batch, dev) mesh: one shard_map over B members x P domain shards.

The fused block engine lays the device list out as a 2-D
``Mesh(("batch", "dev"))`` and advances every ensemble member's domain
shards in a single collective program, with the capacity-bucket switch
sized host-side from the analytic occupancy bound.  Because the kernels
never see the layout (``pallas_interpret``'s j-block sweep is
launch-extent-independent and the shard boundaries fall on block
boundaries), the fused run must be *bit-identical* to both 1-D layouts it
fuses.  Locked here against the committed golden
``tests/golden/plummer_block_fused_2x2.json`` (forced 4-device host mesh,
subprocess):

* replaying the golden recipe reproduces pos/vel, the per-member event
  counts (level-schedule fingerprint) and the per-member tile totals
  (host-side analytic bucket-sizing fingerprint);
* fused ``mesh=(2, 2)`` == the 1-D batch-sharded ensemble run, bitwise;
* each fused member row == a solo 1-D ``mesh_sharded`` strategy run of
  the same member, bitwise;
* a ``sources="neighbor"`` pod under ``ServerConfig.mesh=(2, 2)`` admits
  two large-N members and reaches steady state with ZERO recompiles
  after warmup.

Plus fast in-process checks of the ``SimConfig.mesh`` validation surface.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.sim import api

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN = os.path.join(HERE, "golden", "plummer_block_fused_2x2.json")

_SCRIPT = r"""
import json
import os
import sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np
from repro.serve import ServerConfig, SimRequest, SimServer
from repro.sim import ensemble as ens
from repro.sim import scenarios
from repro.sim.scenarios import ScenarioSpec

assert len(jax.devices()) == 4
with open(sys.argv[1]) as f:
    doc = json.load(f)
m = doc["meta"]
kw = dict(t_end=m["t_end"], dt_max=m["dt_max"], n_levels=m["n_levels"],
          eta=m["eta"], order=m["order"], eps=m["eps"], impl=m["impl"],
          compaction=m["compaction"])
states = [scenarios.make(m["scenario"], m["n"], seed=m["seed"] + i)
          for i in range(m["ensemble"])]

# ---- golden replay: the committed fixture reproduces exactly -----------
fused, carry = ens.evolve_ensemble_block(
    states, mesh=tuple(m["mesh"]), devices=jax.devices()[:m["devices"]],
    **kw)
assert [int(e) for e in np.asarray(carry.n_events)] == doc["n_events"]
assert [float(t) for t in np.asarray(carry.n_tiles)] == doc["n_tiles"]
np.testing.assert_allclose(np.asarray(fused.pos), np.asarray(doc["pos"]),
                           rtol=0, atol=1e-12)
np.testing.assert_allclose(np.asarray(fused.vel), np.asarray(doc["vel"]),
                           rtol=0, atol=1e-12)
print("GOLDEN-FUSED: OK")

# ---- fused == 1-D batch-sharded, bitwise -------------------------------
batch1d, c1d = ens.evolve_ensemble_block(
    states, devices=jax.devices()[:m["ensemble"]], **kw)
for leaf in ("pos", "vel", "acc", "pot"):
    assert np.array_equal(np.asarray(getattr(fused, leaf)),
                          np.asarray(getattr(batch1d, leaf))), leaf
assert np.asarray(carry.n_events).tolist() \
    == np.asarray(c1d.n_events).tolist()
print("FUSED-VS-BATCH: OK")

# ---- each fused member row == a solo 1-D mesh_sharded strategy run -----
p_dom = m["mesh"][1]
for i, st in enumerate(states):
    solo, cs = ens.evolve_strategy_block(
        st, strategy="mesh_sharded", devices=p_dom, **kw)
    for leaf in ("pos", "vel"):
        assert np.array_equal(np.asarray(getattr(fused, leaf))[i],
                              np.asarray(getattr(solo, leaf))), (i, leaf)
    assert int(np.asarray(carry.n_events)[i]) == int(cs.n_events), i
print("FUSED-VS-STRATEGY: OK")

# ---- serve: two large-N neighbor members, one fused pod, 0 recompiles --
cfg = ServerConfig(slots_per_pod=2, n_max=256, chunk_events=8, impl="xla",
                   dt_max=0.0625, n_levels=4, devices=4, mesh=(2, 2),
                   sources="neighbor", neighbor_radius=0.5)
server = SimServer(cfg)
spent = server.warmup([SimRequest(spec=ScenarioSpec.parse("plummer:256"),
                                  stepper="block", t_end=0.0625)])
assert spent > 0
baseline = server.cache_misses()
for seed in (1, 2):
    server.submit(SimRequest(
        spec=ScenarioSpec.parse("plummer:256", seed=seed),
        stepper="block", t_end=0.0625))
reports = server.run_until_drained()
assert len(reports) == 2, [r["request_id"] for r in reports]
assert server.cache_misses() == baseline, \
    (server.cache_misses(), baseline)
print("SERVE-MESH: OK")
print("FUSED-MESH: OK")
"""


@pytest.mark.slow
def test_fused_mesh_4dev_golden_and_layout_identity():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    res = subprocess.run([sys.executable, "-c", _SCRIPT, GOLDEN], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    for tag in ("GOLDEN-FUSED", "FUSED-VS-BATCH", "FUSED-VS-STRATEGY",
                "SERVE-MESH", "FUSED-MESH"):
        assert f"{tag}: OK" in res.stdout


# --------------------------------------------------------------------------
# SimConfig.mesh validation surface (fast, in-process)
# --------------------------------------------------------------------------
def _cfg(**kw):
    base = dict(scenario="plummer", n=32, t_end=0.02, stepper="block",
                dt=None, dt_max=0.0625, n_levels=2, impl="xla", ensemble=2,
                devices=4, mesh=(2, 2), validate_ic=False)
    base.update(kw)
    return api.SimConfig(**base)


def test_mesh_config_valid():
    assert api.resolve_kind(_cfg()) == "ensemble"
    assert _cfg().meta()["mesh"] == [2, 2]


def test_mesh_requires_block_stepper():
    with pytest.raises(ValueError, match="no domain-sharded force pass"):
        api.resolve_kind(_cfg(stepper="adaptive", dt_max=None,
                              n_levels=None))


def test_mesh_must_tile_devices():
    with pytest.raises(ValueError, match="tile the device list exactly"):
        api.resolve_kind(_cfg(devices=3))
    with pytest.raises(ValueError, match="two positive extents"):
        api.resolve_kind(_cfg(mesh=(4,)))


def test_mesh_excludes_strategy_sharding():
    with pytest.raises(ValueError, match="shard the same axis twice"):
        api.resolve_kind(_cfg(strategy="mesh_sharded", ensemble=1))


def test_mesh_requires_member_buckets():
    with pytest.raises(ValueError, match="bucket"):
        api.resolve_kind(_cfg(bucket_mode="shared", compaction="gather"))
