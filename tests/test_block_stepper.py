"""Hierarchical block-timestep stepper: quantization, masking, engine.

Locks the tentpole contracts of the block stepper:

* level quantization / activity-schedule unit behaviour;
* the kernels' target-activity mask (all-ones is the exact identity,
  inactive rows are exact zeros, sources stay full);
* ``n_levels=1`` degenerates to the fixed-dt lockstep engine **exactly**;
* composition with the ``n_active`` padding mask (padded == unpadded);
* the efficiency property: on a wide-dynamic-range scenario, block mode
  reaches shared-adaptive energy error at a fraction of its force
  evaluations (the measured ``n_pairs``, not ``steps * N**2``);
* driver/telemetry plumbing (``stepper`` resolution, ``force_evals``);
* the benchmark registry stays complete (``benchmarks.run`` drives every
  ``benchmarks/*.py`` entry point).
"""

import importlib
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hermite
from repro.kernels import ops
from repro.sim import driver, ensemble as ens, scenarios


# --------------------------------------------------------------------------
# level quantization + schedule
# --------------------------------------------------------------------------
def test_quantize_levels_power_of_two():
    dt_max = 0.0625
    dt_i = jnp.asarray([0.0625, 0.0624, 0.03125, 0.017, 1e-9, 0.5])
    lev = hermite.quantize_block_levels(dt_i, dt_max=dt_max, n_levels=4)
    # coarsest level whose step <= dt_i, clipped to the hierarchy
    np.testing.assert_array_equal(np.asarray(lev), [0, 1, 1, 2, 3, 0])
    h = hermite.block_level_dt(lev, dt_max)
    assert np.all(np.asarray(h)[:4] <= np.asarray(dt_i)[:4] + 1e-15)


def test_block_active_schedule_synchronizes():
    levels = jnp.asarray([0, 1, 2, 3], jnp.int32)
    n_levels, n_sub = 4, 8
    counts = np.zeros(4, int)
    for k in range(1, n_sub + 1):
        act = np.asarray(hermite.block_active_mask(levels, k,
                                                   n_levels=n_levels))
        counts += act
        if k == n_sub:  # macro boundary: everyone synchronizes
            assert act.all()
    # a level-l particle steps 2**l times per macro
    np.testing.assert_array_equal(counts, [1, 2, 4, 8])


def test_aarseth_dt_is_min_of_particles():
    st = scenarios.make("plummer", 16, seed=0)
    st = ens.ensemble_initialize(ens.stack_states([st]), impl="xla")
    s0 = jax.tree_util.tree_map(lambda x: x[0], st)
    dt_i = hermite.aarseth_dt_particles(s0, eta=0.02)
    assert dt_i.shape == (16,)
    np.testing.assert_allclose(float(hermite.aarseth_dt(s0, eta=0.02)),
                               float(jnp.min(dt_i)), rtol=0, atol=0)


# --------------------------------------------------------------------------
# kernel target-activity mask
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_mask_all_ones_is_identity(impl):
    rng = np.random.default_rng(0)
    n = 24
    pos = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    vel = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.1, 1.0, n), jnp.float32)
    full = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, impl=impl)
    ones = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass,
                                 mask_t=jnp.ones(n, bool), impl=impl)
    for a, b in zip(full, ones):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_mask_inactive_rows_zero_active_rows_full(impl):
    rng = np.random.default_rng(1)
    n = 24
    pos = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    vel = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)
    mass = jnp.asarray(rng.uniform(0.1, 1.0, n), jnp.float32)
    mask = jnp.asarray(rng.uniform(size=n) < 0.4)
    full = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, impl=impl)
    part = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, mask_t=mask,
                                 impl=impl)
    m = np.asarray(mask)
    for f, p in zip(full, part):
        f, p = np.asarray(f), np.asarray(p)
        # sources stay full: active targets see every source -> same values
        np.testing.assert_array_equal(p[m], f[m])
        assert not p[~m].any()
    # snap pass honours the same contract
    acc = full[0]
    s_full = ops.snap_rect(pos, vel, acc, pos, vel, acc, mass, impl=impl)
    s_part = ops.snap_rect(pos, vel, acc, pos, vel, acc, mass, mask_t=mask,
                           impl=impl)
    np.testing.assert_array_equal(np.asarray(s_part)[m],
                                  np.asarray(s_full)[m])
    assert not np.asarray(s_part)[~m].any()


# --------------------------------------------------------------------------
# engine degeneracies and composition
# --------------------------------------------------------------------------
@pytest.mark.parametrize("kernel", ens.KERNELS)
def test_single_level_block_equals_fixed_dt(kernel):
    """n_levels=1 = one level at dt_max = plain lockstep, bit for bit."""
    impl = ens.resolve_kernel(kernel)
    st = scenarios.make("plummer", 16, seed=3)
    b0 = ens.ensemble_initialize(ens.stack_states([st]), impl=impl)
    fixed = ens.ensemble_run(b0, n_steps=8, dt=1 / 64, impl=impl)
    blk, carry = ens.evolve_ensemble_block(b0, t_end=8 / 64, dt_max=1 / 64,
                                           n_levels=1, impl=impl)
    np.testing.assert_array_equal(np.asarray(blk.pos), np.asarray(fixed.pos))
    np.testing.assert_array_equal(np.asarray(blk.vel), np.asarray(fixed.vel))
    assert int(carry.n_events[0]) == 8
    assert float(carry.n_pairs[0]) == 8 * 16 * 16


def test_block_padded_matches_unpadded():
    """The activity mask composes with the n_active padding mask: a member
    padded with zero-mass rows follows the identical event schedule and
    trajectory (fp64 so reassociation noise cannot flip a level)."""
    st = scenarios.make("binary_plummer", 24, seed=1)
    kw = dict(t_end=0.03125, dt_max=1 / 64, n_levels=4, impl="fp64")
    alone, c_alone = ens.evolve_ensemble_block([st], **kw)
    padded, n_active = scenarios.build_padded(
        [scenarios.Scenario(name="binary_plummer", n=24, seed=1)], n_max=32)
    pad_out, c_pad = ens.evolve_ensemble_block(padded, n_active=n_active,
                                               **kw)
    assert int(c_pad.n_events[0]) == int(c_alone.n_events[0])
    assert float(c_pad.n_pairs[0]) == float(c_alone.n_pairs[0])
    np.testing.assert_allclose(np.asarray(pad_out.pos[0, :24]),
                               np.asarray(alone.pos[0]), rtol=0, atol=1e-12)
    # padding rows never moved and never carry derivatives
    assert not np.asarray(pad_out.vel[0, 24:]).any()
    assert not np.asarray(pad_out.acc[0, 24:]).any()


def test_block_heterogeneous_batch_members_independent():
    """Two different members in one batch step on independent schedules and
    match their own B=1 runs (fp64: bitwise-stable schedules)."""
    s1 = scenarios.Scenario(name="binary_plummer", n=24, seed=1)
    s2 = scenarios.Scenario(name="plummer", n=16, seed=7)
    batched, n_active = scenarios.build_padded([s1, s2])
    kw = dict(t_end=0.03125, dt_max=1 / 64, n_levels=4, impl="fp64")
    out, carry = ens.evolve_ensemble_block(batched, n_active=n_active, **kw)
    for i, spec in enumerate((s1, s2)):
        solo, c_solo = ens.evolve_ensemble_block([spec.build()], **kw)
        n = spec.n
        assert int(carry.n_events[i]) == int(c_solo.n_events[0])
        np.testing.assert_allclose(np.asarray(out.pos[i, :n]),
                                   np.asarray(solo.pos[0]),
                                   rtol=0, atol=1e-12)


# --------------------------------------------------------------------------
# the efficiency property (the reason block timesteps exist)
# --------------------------------------------------------------------------
def test_block_energy_error_beats_adaptive_at_half_budget():
    """On a binary-rich cluster, block mode reaches the shared-adaptive
    energy error with less than half its force-evaluation budget: the
    lockstep run drags all N particles at the tightest binary's dt, the
    block run steps only the binary finely."""
    st = scenarios.make("binary_plummer", 64, seed=0)
    t_end = 0.25
    b = ens.ensemble_initialize(ens.stack_states([st]), impl="xla")
    e0 = float(ens.batched_total_energy(b)[0])

    bb, hp, nt = b, None, None
    while True:
        bb, hp, nt = ens.ensemble_run_adaptive(
            bb, t_end=t_end, n_steps=64, h_prev=hp, n_taken=nt, eta=0.02,
            impl="xla")
        if float(jnp.min(bb.time)) >= t_end:
            break
    de_adaptive = abs((float(ens.batched_total_energy(bb)[0]) - e0) / e0)
    evals_adaptive = int(nt[0]) * 64 * 64

    out, carry = ens.evolve_ensemble_block(
        b, t_end=t_end, dt_max=0.0625, n_levels=11, eta=0.02, impl="xla")
    de_block = abs((float(ens.batched_total_energy(out)[0]) - e0) / e0)
    evals_block = float(carry.n_pairs[0])

    # measured locally: de_block ~ 0.6 * de_adaptive at ~3.4x fewer evals
    assert evals_block * 2 <= evals_adaptive, \
        f"block used {evals_block:.3g} evals vs adaptive {evals_adaptive:.3g}"
    assert de_block <= de_adaptive, \
        f"block |dE/E|={de_block:.3e} worse than adaptive {de_adaptive:.3e}"


# --------------------------------------------------------------------------
# driver + telemetry plumbing
# --------------------------------------------------------------------------
def test_resolved_stepper_validation():
    assert driver.SimConfig(dt=None).resolved_stepper() == "adaptive"
    assert driver.SimConfig(dt=0.01).resolved_stepper() == "fixed"
    assert driver.SimConfig(stepper="block").resolved_stepper() == "block"
    with pytest.raises(ValueError, match="needs an explicit dt"):
        driver.SimConfig(stepper="fixed").resolved_stepper()
    with pytest.raises(ValueError, match="chooses its own"):
        driver.SimConfig(stepper="block", dt=0.01).resolved_stepper()
    with pytest.raises(ValueError, match="unknown stepper"):
        driver.SimConfig(stepper="warp").resolved_stepper()


def test_driver_block_report_counts_measured_evals(tmp_path):
    cfg = driver.SimConfig(scenario="binary_plummer", n=24, seed=1,
                           t_end=0.03125, stepper="block", dt_max=1 / 64,
                           n_levels=4, impl="xla", diag_every=8,
                           out=str(tmp_path / "r.json"))
    report = driver.run(cfg)
    assert report["stepper"] == "block"
    assert report["n_levels"] == 4
    assert report["steps"] == report["runs"][0]["steps"] > 0
    evals = report["force_evals_total"]
    assert evals == report["runs"][0]["force_evals"] > 0
    # the whole point: measured work is below the lockstep equivalent
    assert evals < report["steps"] * 24 * 24
    assert report["interactions_per_s"] > 0
    assert report["t_final"] == pytest.approx(0.03125)
    assert report["de_rel"] < 1e-4


def test_driver_fixed_and_adaptive_report_force_evals():
    fixed = driver.run(driver.SimConfig(scenario="plummer", n=16, seed=0,
                                        dt=1 / 64, t_end=4 / 64, impl="xla",
                                        ensemble=2, diag_every=4))
    assert fixed["force_evals_total"] == 2 * 4 * 16 * 16
    single = driver.run(driver.SimConfig(scenario="plummer", n=16, seed=0,
                                         t_end=0.01, impl="xla"))
    assert single["force_evals_total"] == single["steps"] * 16 * 16


# --------------------------------------------------------------------------
# benchmark registry completeness
# --------------------------------------------------------------------------
def test_benchmark_registry_complete():
    """Every benchmarks/*.py exposing a run() entry point is wired into
    benchmarks.run, so one command reproduces the full suite."""
    bench_dir = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
    run_mod = importlib.import_module("benchmarks.run")
    registered = {fn.__module__ for fn in run_mod.suites().values()}
    for path in sorted(bench_dir.glob("*.py")):
        name = path.stem
        if name in ("run", "common", "__init__"):
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        if hasattr(mod, "run"):
            assert mod.__name__ in registered, \
                f"benchmarks/{name}.py has run() but is not in run.suites()"
