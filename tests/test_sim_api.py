"""The public simulation API (``repro.sim.api``): registry-based
build/step/collect dispatch, the typed ``ScenarioSpec`` request surface and
the versioned ``RunReport`` — plus the ``driver`` compatibility shim."""

import json

import pytest

from repro.sim import api, driver, scenarios, telemetry
from repro.sim.scenarios import ScenarioError, ScenarioSpec
from repro.sim.telemetry import REPORT_SCHEMA_VERSION, RunReport


# --------------------------------------------------------------------------
# registry dispatch
# --------------------------------------------------------------------------
def _cfg(**kw):
    base = dict(scenario="plummer", n=16, t_end=0.02, dt=1.0 / 256,
                diag_every=4, validate_ic=False)
    base.update(kw)
    return api.SimConfig(**base)


@pytest.mark.parametrize("cfg,kind", [
    (_cfg(), "single"),
    (_cfg(ensemble=2), "ensemble"),
    (_cfg(stepper="block", dt=None, n_levels=2, impl="xla"), "ensemble"),
    (_cfg(stepper="block", dt=None, n_levels=2, impl="xla",
          strategy="mesh_sharded"), "block_strategy"),
    (_cfg(mix=(("plummer", 16), ("two_body", 2)), scenario="mixed"),
     "mixed"),
])
def test_resolve_kind_dispatch(cfg, kind):
    assert api.resolve_kind(cfg) == kind


def test_get_runner_unknown_kind():
    with pytest.raises(ValueError, match="unknown runner kind"):
        api.get_runner("warp_drive")


def test_resolve_kind_validates_first():
    with pytest.raises(ValueError):
        api.resolve_kind(_cfg(ensemble=0))


def test_driver_shim_is_the_api():
    """The legacy ``driver`` module re-exports the api surface unchanged."""
    assert driver.run is api.run
    assert driver.SimConfig is api.SimConfig
    assert driver.RUNNERS is api.RUNNERS


# --------------------------------------------------------------------------
# build/step/collect == run()
# --------------------------------------------------------------------------
#: physics-deterministic report fields (wall-clock fields excluded)
_DETERMINISTIC = ("scenario", "n_bodies", "ensemble", "steps", "e0", "e1",
                  "de_rel", "t_final", "force_evals_total")


def _deterministic(report):
    return {k: report[k] for k in _DETERMINISTIC if k in report}


@pytest.mark.parametrize("cfg", [
    _cfg(),
    _cfg(ensemble=2, stepper="adaptive", dt=None, t_end=0.01),
    _cfg(mix=(("plummer", 16), ("two_body", 2)), scenario="mixed"),
])
def test_build_step_collect_matches_run(cfg):
    """Driving the triple by hand reproduces ``run()``'s physics exactly."""
    monolithic = api.run(cfg)
    runner = api.get_runner(api.resolve_kind(cfg))
    h = runner.build(cfg)
    while not runner.step(h):
        pass
    composed = runner.collect(h)
    assert isinstance(composed, RunReport)
    assert _deterministic(composed) == _deterministic(monolithic)


def test_run_twice_is_deterministic():
    cfg = _cfg()
    a, b = api.run(cfg), api.run(cfg)
    assert _deterministic(a) == _deterministic(b)


# --------------------------------------------------------------------------
# ScenarioSpec: the typed name[:N] request
# --------------------------------------------------------------------------
def test_scenariospec_parse_format_roundtrip():
    for token in ("plummer:24", "two_body:2", "king:32"):
        spec = ScenarioSpec.parse(token)
        assert spec.format() == token
        assert ScenarioSpec.parse(spec.format()) == spec
    bare = ScenarioSpec.parse("plummer")
    assert bare.n is None and bare.format() == "plummer"


def test_scenariospec_parse_bad_int_names_field():
    with pytest.raises(ScenarioError, match="ScenarioSpec.n"):
        ScenarioSpec.parse("plummer:abc")


def test_scenariospec_unknown_name_names_field():
    with pytest.raises(ScenarioError, match="ScenarioSpec.name"):
        ScenarioSpec.parse("warp_core:16")


def test_scenariospec_negative_seed_names_field():
    with pytest.raises(ScenarioError, match="ScenarioSpec.seed"):
        ScenarioSpec(name="plummer", n=16, seed=-1).validate()


def test_scenariospec_unknown_param_names_field():
    with pytest.raises(ScenarioError, match="ScenarioSpec.params"):
        ScenarioSpec(name="plummer", n=16,
                     params={"warp_factor": 9}).validate()


def test_scenariospec_with_n_and_build():
    spec = ScenarioSpec.parse("plummer").with_n(24)
    assert spec.n == 24
    state = spec.build()
    assert state.pos.shape == (24, 3)
    with pytest.raises(ScenarioError, match="ScenarioSpec.n"):
        ScenarioSpec.parse("plummer").scenario()


def test_parse_mix_token_delegates_to_spec():
    assert scenarios.parse_mix_token("king:128") == ("king", 128)
    assert scenarios.parse_mix_token("king") == ("king", None)
    with pytest.raises(ScenarioError):
        scenarios.parse_mix_token("king:x")


# --------------------------------------------------------------------------
# RunReport: versioned, typed, round-trippable
# --------------------------------------------------------------------------
def test_finalize_returns_versioned_runreport():
    rec = telemetry.TelemetryRecorder({"scenario": "x"})
    rec.record_step(4, 0.1, 0.5)
    report = rec.finalize(n_bodies=8)
    assert isinstance(report, RunReport)
    assert isinstance(report, dict)          # legacy consumers keep working
    assert report.schema_version == REPORT_SCHEMA_VERSION
    assert report["schema_version"] == REPORT_SCHEMA_VERSION
    assert report.steps == 4 and report.wall_s == 0.5


def test_runreport_json_roundtrip():
    rec = telemetry.TelemetryRecorder({"scenario": "x"})
    rec.record_step(2, 0.05, 0.25)
    report = rec.finalize(n_bodies=8, n_active=[6])
    back = RunReport.from_json(report.to_json())
    assert back == json.loads(report.to_json())
    assert back.schema_version == report.schema_version
    assert back["n_active"] == [6]


def test_runreport_from_json_rejects_wrong_version():
    bad = json.dumps({"schema_version": REPORT_SCHEMA_VERSION + 1})
    with pytest.raises(ValueError, match="schema_version"):
        RunReport.from_json(bad)
    with pytest.raises(ValueError, match="JSON object"):
        RunReport.from_json("[1, 2]")


def test_runreport_as_dict_deprecated():
    report = RunReport({"wall_s": 1.0})
    with pytest.deprecated_call():
        plain = report.as_dict
    assert plain == dict(report) and type(plain) is dict
