"""Elastic scaling: a checkpoint written under one device configuration
restores under another (mesh-resharded device_put) — the restart-with-
different-pod-count path of DESIGN.md §6."""

import os
import subprocess
import sys

import pytest

_SAVE = r"""
import jax, sys
from repro.checkpoint import store
from repro.models import params as P
from repro.models.config import ArchConfig

cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 dtype="float32")
params = P.init_params(cfg, jax.random.PRNGKey(7))
store.save(sys.argv[1], 3, {"params": params})
print("SAVED", len(jax.tree.leaves(params)))
"""

_RESTORE = r"""
import numpy as np
import jax, sys
from jax.sharding import Mesh
from repro.checkpoint import store
from repro.distributed.shardings import MeshRules
from repro.models import params as P
from repro.models.config import ArchConfig

cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                 dtype="float32")
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
rules = MeshRules.for_mesh(mesh)
like = P.init_params(cfg, jax.random.PRNGKey(0))
shardings = P.param_shardings(cfg, rules)
step, tree = store.restore_latest(sys.argv[1], {"params": like},
                                  shardings={"params": shardings})
assert step == 3
ref = P.init_params(cfg, jax.random.PRNGKey(7))
for a, b in zip(jax.tree.leaves(tree["params"]), jax.tree.leaves(ref)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(a.sharding.device_set) in (1, 2, 4)  # actually placed
print("RESTORED-ON-4DEV OK")
"""


@pytest.mark.slow
def test_checkpoint_restores_across_device_counts(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")

    env.pop("XLA_FLAGS", None)   # writer: 1 device
    res = subprocess.run([sys.executable, "-c", _SAVE, str(tmp_path)],
                         env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr
    assert "SAVED" in res.stdout

    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    res = subprocess.run([sys.executable, "-c", _RESTORE, str(tmp_path)],
                         env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "RESTORED-ON-4DEV OK" in res.stdout
