"""SSM cell correctness: chunked forms vs naive recurrences, chunk-size
invariance, and parallel-vs-step agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm

F32 = jnp.float32


def _ssd_naive(x, dt, a_neg, b_mat, c_mat):
    """Direct O(S) recurrence: S_t = exp(dt_t a) S_{t-1} + dt_t B_t x_t^T."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = np.zeros((bsz, h, n, p))
    ys = np.zeros((bsz, s, h, p))
    for t in range(s):
        g = np.exp(np.asarray(dt[:, t]) * np.asarray(a_neg))      # (B,H)
        upd = np.einsum("bh,bn,bhp->bhnp", np.asarray(dt[:, t]),
                        np.asarray(b_mat[:, t]), np.asarray(x[:, t]))
        state = state * g[:, :, None, None] + upd
        ys[:, t] = np.einsum("bn,bhnp->bhp", np.asarray(c_mat[:, t]), state)
    return ys, state


def _ssd_inputs(bsz=2, s=32, h=3, p=4, n=5, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((bsz, s, h, p)), F32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (bsz, s, h)), F32)
    a_neg = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), F32)
    b_mat = jnp.asarray(rng.standard_normal((bsz, s, n)), F32)
    c_mat = jnp.asarray(rng.standard_normal((bsz, s, n)), F32)
    return x, dt, a_neg, b_mat, c_mat


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_ssd_chunked_matches_naive(chunk):
    x, dt, a_neg, b_mat, c_mat = _ssd_inputs()
    y, st = ssm.ssd_chunked(x, dt, a_neg, b_mat, c_mat, chunk=chunk)
    y_ref, st_ref = _ssd_naive(x, dt, a_neg, b_mat, c_mat)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st), st_ref, rtol=1e-4, atol=1e-5)


def test_ssd_step_continues_chunked():
    x, dt, a_neg, b_mat, c_mat = _ssd_inputs(s=16)
    _, st = ssm.ssd_chunked(x, dt, a_neg, b_mat, c_mat, chunk=8)
    x1, dt1, _, b1, c1 = _ssd_inputs(s=1, seed=9)
    y_step, st2 = ssm.ssd_step(x1[:, 0], dt1[:, 0], a_neg, b1[:, 0],
                               c1[:, 0], st)
    # against chunked over the concatenated sequence
    xx = jnp.concatenate([x, x1], axis=1)
    dd = jnp.concatenate([dt, dt1], axis=1)
    bb = jnp.concatenate([b_mat, b1], axis=1)
    cc = jnp.concatenate([c_mat, c1], axis=1)
    y_all, st_all = ssm.ssd_chunked(xx, dd, a_neg, bb, cc, chunk=17)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_all[:, -1]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_all),
                               rtol=1e-4, atol=1e-5)


def _mlstm_inputs(bsz=2, s=24, h=2, k=8, seed=1):
    rng = np.random.default_rng(seed)
    mk = lambda *sh: jnp.asarray(rng.standard_normal(sh), F32)  # noqa: E731
    return (mk(bsz, s, h, k), mk(bsz, s, h, k), mk(bsz, s, h, k),
            mk(bsz, s, h) * 2.0, mk(bsz, s, h) * 2.0)


@pytest.mark.parametrize("chunk", [4, 8, 12, 24])
def test_mlstm_chunk_invariance(chunk):
    q, k, v, gi, gf = _mlstm_inputs()
    h1, c1 = ssm.mlstm_chunked(q, k, v, gi, gf, chunk=chunk)
    h2, c2 = ssm.mlstm_chunked(q, k, v, gi, gf, chunk=24)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-5)
    for a, b in zip(c1, c2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_mlstm_chunked_matches_stepwise():
    q, k, v, gi, gf = _mlstm_inputs(s=12)
    h_par, _ = ssm.mlstm_chunked(q, k, v, gi, gf, chunk=4)
    carry = None
    bsz, s, h, kk = q.shape
    carry = (jnp.zeros((bsz, h, kk, kk), F32), jnp.zeros((bsz, h, kk), F32),
             jnp.zeros((bsz, h), F32))
    outs = []
    for t in range(s):
        o, carry = ssm.mlstm_step(q[:, t], k[:, t], v[:, t],
                                  gi[:, t], gf[:, t], carry)
        outs.append(o)
    h_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_seq),
                               rtol=1e-4, atol=1e-5)


def test_slstm_scan_matches_step():
    rng = np.random.default_rng(2)
    bsz, s, h, hd = 2, 10, 2, 4
    gx = jnp.asarray(rng.standard_normal((bsz, s, h, 4, hd)), F32)
    r = jnp.asarray(rng.standard_normal((h, hd, 4 * hd)) * 0.2, F32)
    h_par, carry_par = ssm.slstm_scan(gx, r, n_heads=h)
    z = jnp.zeros((bsz, h, hd), F32)
    carry = (z, z, z, z)
    outs = []
    for t in range(s):
        o, carry = ssm.slstm_step(gx[:, t], r, carry)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(h_par),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=1e-5, atol=1e-6)
    for a, b in zip(carry_par, carry):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_causal_conv_streaming_matches_padded():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 16, 6)), F32)
    w = jnp.asarray(rng.standard_normal((4, 6)), F32)
    y_full = ssm.causal_conv(x, w)
    cache = jnp.zeros((2, 3, 6), F32)
    y1, cache = ssm.causal_conv(x[:, :9], w, cache=cache)
    y2, cache = ssm.causal_conv(x[:, 9:], w, cache=cache)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-6, atol=1e-6)


def test_gates_stay_finite_extreme():
    """Log-space stabilization: extreme gate pre-activations stay finite."""
    q, k, v, gi, gf = _mlstm_inputs(s=16)
    h, _ = ssm.mlstm_chunked(q, k, v, gi + 40.0, gf - 40.0, chunk=8)
    assert bool(jnp.all(jnp.isfinite(h)))
    h2, _ = ssm.mlstm_chunked(q, k, v, gi - 40.0, gf + 40.0, chunk=8)
    assert bool(jnp.all(jnp.isfinite(h2)))
