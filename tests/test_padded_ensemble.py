"""Mask-aware padded ensembles: packing, engine equivalence, kernel switch,
driver telemetry honesty, and cross-strategy equivalence on a device mesh."""

import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hermite
from repro.core.evaluate import make_evaluator
from repro.sim import driver, ensemble as ens, scenarios

MIX = [("plummer", 24), ("king", 32), ("two_body", 2)]


def _padded_batch(mix=None, seed=0):
    specs = scenarios.make_mix(mix or MIX, seed=seed)
    return specs, *scenarios.build_padded(specs)


# --------------------------------------------------------------------------
# packing
# --------------------------------------------------------------------------
def test_build_padded_shapes_and_mask():
    specs, batched, n_active = _padded_batch()
    assert batched.pos.shape == (3, 32, 3)
    assert batched.mass.shape == (3, 32)
    np.testing.assert_array_equal(np.asarray(n_active), [24, 32, 2])
    # padding rows: zero mass, zero velocity (kinetic-blind), zero position
    for i, n in enumerate([24, 32, 2]):
        assert float(jnp.abs(batched.mass[i, n:]).sum()) == 0.0
        assert float(jnp.abs(batched.vel[i, n:]).sum()) == 0.0
    # active rows are the member's own particles, bit-identical
    st = scenarios.build(specs[0])
    np.testing.assert_array_equal(np.asarray(batched.pos[0, :24]),
                                  np.asarray(st.pos))


def test_build_padded_explicit_n_max_and_errors():
    specs = scenarios.make_mix([("plummer", 16)])
    batched, n_active = scenarios.build_padded(specs, n_max=64)
    assert batched.pos.shape == (1, 64, 3)
    with pytest.raises(scenarios.ScenarioError):
        scenarios.build_padded(specs, n_max=8)   # below the largest member
    with pytest.raises(scenarios.ScenarioError):
        scenarios.build_padded([])


def test_make_mix_repeat_and_seeds():
    specs = scenarios.make_mix([("plummer", 16), ("king", 24)], seed=5,
                               repeat=2)
    assert [(s.name, s.n) for s in specs] == \
        [("plummer", 16), ("king", 24)] * 2
    assert [s.seed for s in specs] == [5, 6, 7, 8]


def test_parse_mix_token():
    assert scenarios.parse_mix_token("king:256") == ("king", 256)
    assert scenarios.parse_mix_token("king") == ("king", None)
    with pytest.raises(scenarios.ScenarioError):
        scenarios.parse_mix_token("nope:12")
    with pytest.raises(scenarios.ScenarioError):
        scenarios.parse_mix_token("king:abc")
    with pytest.raises(scenarios.ScenarioError):
        scenarios.parse_mix_token("king:")   # trailing colon: N required


# --------------------------------------------------------------------------
# engine equivalence
# --------------------------------------------------------------------------
def test_padded_matches_unpadded_sequential():
    """Each member of a mixed padded batch reproduces its own unpadded
    sequential integration (fp32 summation-order tolerance)."""
    specs, batched, n_active = _padded_batch()
    out = ens.evolve_ensemble(batched, n_steps=4, dt=1e-2,
                              n_active=n_active)
    ev = make_evaluator(impl="xla")
    for i, spec in enumerate(specs):
        ref = hermite.evolve_scan(scenarios.build(spec), ev, n_steps=4,
                                  dt=1e-2)
        n = int(n_active[i])
        np.testing.assert_allclose(np.asarray(out.pos[i, :n]),
                                   np.asarray(ref.pos),
                                   rtol=0, atol=1e-8)
        np.testing.assert_allclose(np.asarray(out.vel[i, :n]),
                                   np.asarray(ref.vel),
                                   rtol=0, atol=1e-8)


@pytest.mark.parametrize("kernel", ens.KERNELS)
def test_kernel_switch_agrees(kernel):
    """ref and pallas kernels agree on the padded path (and the switch
    resolves to a vmappable impl)."""
    _, batched, n_active = _padded_batch()
    out = ens.evolve_ensemble(batched, n_steps=2, dt=1e-2,
                              n_active=n_active, kernel=kernel)
    ref = ens.evolve_ensemble(batched, n_steps=2, dt=1e-2,
                              n_active=n_active, impl="xla")
    np.testing.assert_allclose(np.asarray(out.pos), np.asarray(ref.pos),
                               rtol=0, atol=1e-8)


def test_resolve_kernel():
    assert ens.resolve_kernel(None) == "xla"
    assert ens.resolve_kernel("ref") == "xla"
    assert ens.resolve_kernel("pallas") in ("pallas", "pallas_interpret")
    with pytest.raises(ValueError):
        ens.resolve_kernel("bogus")


def test_explicit_impl_and_kernel_conflict():
    """kernel must not silently override an explicit impl (an fp64 golden
    request downgraded to fp32 would corrupt validation studies)."""
    with pytest.raises(ValueError):
        ens.resolve_eval_impl("fp64", "ref")
    with pytest.raises(ValueError):
        driver.run(driver.SimConfig(scenario="plummer", n=8, impl="fp64",
                                    kernel="ref", t_end=0.01, dt=1.0 / 256))
    with pytest.raises(ValueError):
        driver.run(driver.SimConfig(mix=(("plummer", 8),), impl="fp64",
                                    kernel="pallas", t_end=0.01,
                                    dt=1.0 / 256))
    # each alone stays valid
    assert ens.resolve_eval_impl("fp64", None) == "fp64"
    assert ens.resolve_eval_impl(None, "ref") == "xla"
    assert ens.resolve_eval_impl(None, None) == "xla"
    assert ens.resolve_eval_impl(None, None, default=None) is None


def test_padding_rows_stay_frozen():
    """Mask contract, fixed and adaptive dt: padding rows never move, never
    gain derivatives, never accrue potential."""
    _, batched, n_active = _padded_batch()
    out = ens.evolve_ensemble(batched, n_steps=4, dt=1e-2,
                              n_active=n_active)
    for arr in (out.pos, out.vel, out.acc, out.jerk, out.snap, out.pot):
        assert float(jnp.abs(arr[0, 24:]).sum()) == 0.0

    init = ens.ensemble_initialize(batched, n_active=n_active)
    state, h, cnt = ens.ensemble_run_adaptive(
        init, t_end=0.03, n_steps=8, n_active=n_active)
    assert float(jnp.abs(state.pos[0, 24:]).sum()) == 0.0
    assert float(jnp.abs(state.acc[0, 24:]).sum()) == 0.0


def test_adaptive_padded_matches_unpadded():
    """Padding must not perturb the per-run Aarseth timestep: the same run,
    padded and unpadded, takes the same steps to the same state."""
    spec = [("plummer", 24)]
    _, unpadded, na_u = _padded_batch(spec)           # N_max == 24
    specs = scenarios.make_mix(spec)
    padded, na_p = scenarios.build_padded(specs, n_max=40)

    def drive(batched, na):
        b = ens.ensemble_initialize(batched, n_active=na)
        h = cnt = None
        for _ in range(64):
            b, h, cnt = ens.ensemble_run_adaptive(
                b, t_end=0.0625, n_steps=8, h_prev=h, n_taken=cnt,
                n_active=na)
            if float(np.min(np.asarray(b.time))) >= 0.0625:
                break
        return b, np.asarray(cnt)

    out_u, cnt_u = drive(unpadded, na_u)
    out_p, cnt_p = drive(padded, na_p)
    np.testing.assert_array_equal(cnt_u, cnt_p)
    np.testing.assert_allclose(np.asarray(out_p.pos[0, :24]),
                               np.asarray(out_u.pos[0]),
                               rtol=0, atol=1e-7)


def test_n_active_shape_validated():
    _, batched, _ = _padded_batch()
    with pytest.raises(ValueError):
        ens.ensemble_initialize(batched, n_active=jnp.asarray([24]))


# --------------------------------------------------------------------------
# driver + telemetry honesty
# --------------------------------------------------------------------------
def test_driver_mixed_report_counts_active_interactions(tmp_path):
    out = str(tmp_path / "mixed.json")
    cfg = driver.SimConfig(mix=(("plummer", 24), ("king", 32),
                                ("two_body", 2)),
                           t_end=0.05, dt=1.0 / 256, diag_every=4, out=out)
    report = driver.run(cfg)
    assert report["scenario"] == "mixed"
    assert report["n_bodies"] == 32                       # padded N_max
    assert report["n_active"] == [24, 32, 2]
    assert [r["scenario"] for r in report["runs"]] == \
        ["plummer", "king", "two_body"]
    # interactions/s must be built from n_active**2, not N_max**2
    steps = report["steps"]
    expected = 2.0 * steps * sum(n * n for n in [24, 32, 2])
    overstated = 2.0 * steps * 3 * 32 * 32
    counted = report["interactions_per_s"] * report["wall_s"]
    assert math.isclose(counted, expected, rel_tol=1e-9)
    assert counted < overstated
    # per-run diagnostics exist and are honest about equilibrium
    assert report["de_rel"] < 1e-3
    king = report["runs"][1]
    assert abs(king["virial_ratio"] - 0.5) < 0.2
    two_body = report["runs"][2]
    assert two_body["de_rel"] < 1e-5


def test_driver_mixed_adaptive_uses_per_run_steps():
    report = driver.run(driver.SimConfig(
        mix=(("plummer", 16), ("two_body", 2)), t_end=0.03, diag_every=8))
    per_run = [r["steps"] for r in report["runs"]]
    assert all(s > 0 for s in per_run)
    counted = report["interactions_per_s"] * report["wall_s"]
    expected = 2.0 * (per_run[0] * 16 * 16 + per_run[1] * 2 * 2)
    assert math.isclose(counted, expected, rel_tol=1e-9)


def test_driver_mixed_rejects_orphan_params():
    """A param no scenario in the mix accepts must raise, exactly like the
    homogeneous path does (a typo'd sweep key must not silently no-op)."""
    with pytest.raises(scenarios.ScenarioError):
        driver.run(driver.SimConfig(
            mix=(("king", 24), ("plummer", 16)), t_end=0.01, dt=1.0 / 256,
            scenario_params={"bogus_param": 3}))
    # a key accepted by ONE member still applies (and only to that member)
    report = driver.run(driver.SimConfig(
        mix=(("king", 24), ("plummer", 16)), t_end=0.01, dt=1.0 / 256,
        diag_every=4, scenario_params={"w0": 4.0}))
    assert report["params"] == {"w0": 4.0}


def test_sim_run_cli_mixed(tmp_path, capsys):
    """The name:N CLI front door end to end (mixed parse, pad, report)."""
    from repro.launch import sim_run
    out = str(tmp_path / "cli.json")
    rc = sim_run.main(["--scenario", "plummer:24", "two_body:2",
                       "--pad", "auto", "--kernel", "ref",
                       "--t-end", "0.02", "--dt", "0.00390625",
                       "--diag-every", "4", "--out", out])
    assert rc == 0
    printed = capsys.readouterr().out
    assert "n_active=[24, 2]" in printed
    with open(out) as f:
        doc = json.load(f)
    assert doc["scenario"] == "mixed" and doc["n_active"] == [24, 2]
    assert doc["kernel"] == "ref" and doc["mix"] == [["plummer", 24],
                                                     ["two_body", 2]]


def test_sim_run_cli_single_token_stays_homogeneous(tmp_path):
    """A lone name:N token is --n shorthand: real scenario label, no padding
    machinery, so report consumers grouping by scenario see the truth."""
    from repro.launch import sim_run
    out = str(tmp_path / "single.json")
    rc = sim_run.main(["--scenario", "plummer:24", "--t-end", "0.01",
                       "--dt", "0.00390625", "--diag-every", "4",
                       "--out", out])
    assert rc == 0
    with open(out) as f:
        doc = json.load(f)
    assert doc["scenario"] == "plummer" and doc["n_bodies"] == 24
    assert "mix" not in doc and "n_active" not in doc


def test_driver_mixed_kernel_pallas_smoke(tmp_path):
    report = driver.run(driver.SimConfig(
        mix=(("plummer", 16), ("two_body", 2)), kernel="pallas",
        t_end=0.02, dt=1.0 / 256, diag_every=4))
    assert report["kernel"] == "pallas"
    assert report["de_rel"] < 1e-4


# --------------------------------------------------------------------------
# cross-strategy equivalence (2-device mesh; exercised by the CI matrix leg
# that sets XLA_FLAGS=--xla_force_host_platform_device_count=2)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ("replicated", "mesh_sharded", "ring"))
def test_cross_strategy_padded_ensemble_2dev(strategy):
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    specs, batched, n_active = _padded_batch()
    ref = ens.evolve_ensemble(batched, n_steps=3, dt=1e-2,
                              n_active=n_active, strategy="single")
    out = ens.evolve_ensemble(batched, n_steps=3, dt=1e-2,
                              n_active=n_active, strategy=strategy,
                              devices=jax.devices())
    np.testing.assert_allclose(np.asarray(out.pos), np.asarray(ref.pos),
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(out.vel), np.asarray(ref.vel),
                               rtol=0, atol=1e-12)
