"""Precision-axis units: compensated accumulation, dtype plumbing, tiers.

The mixed mode mirrors the Tensix fidelity pattern (unpack fp32 / compute
reduced / pack fp32): each pairwise contribution is rounded through bfloat16
and the j-loop accumulates in fp32 with a two-sum (kernel) or Neumaier
(reference) compensation.  These units pin the three layers separately:

* the compensated reduction itself, at ULP level, against a naive
  sequential fp32 sum on adversarial wide-magnitude inputs;
* the dtype plumbing — ``dtype="fp32"`` must stay BIT-IDENTICAL to the
  historical default path, ``"fp64"`` must refuse to reach the kernels;
* the capacity model — element widths change tile byte costs and occupancy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hermite
from repro.kernels import nbody_force, ops, ref

F32 = jnp.float32


# --------------------------------------------------------------------------
# compensated summation, at ULP level
# --------------------------------------------------------------------------
def _naive_fp32_sum(x):
    """The uncompensated sequential j-loop: one fp32 add per element."""
    acc = np.float32(0.0)
    for v in x:
        acc = np.float32(acc + v)
    return float(acc)


def test_compensated_sum_recovers_absorbed_term():
    """The classic absorption case: 1e8 + 1 - 1e8.  A naive fp32 sum
    swallows the 1 entirely; the Neumaier compensation returns it exactly."""
    x = np.asarray([1e8, 1.0, -1e8], np.float32)
    assert _naive_fp32_sum(x) == 0.0
    assert float(ref.compensated_sum(jnp.asarray(x))) == 1.0


@pytest.mark.parametrize("seed", (2, 3, 4))
def test_compensated_sum_beats_naive_at_ulp_level(seed):
    """Adversarial input: 4096 terms spanning eight decades with random
    signs.  The naive sequential fp32 sum drifts tens of ULPs from the fp64
    truth; the compensated reduction stays correctly rounded (<= 1 ULP)."""
    rng = np.random.default_rng(seed)
    n = 4096
    x = (10.0 ** rng.uniform(-4, 4, n)
         * rng.choice([-1.0, 1.0], n)).astype(np.float32)
    true = np.sum(x.astype(np.float64))
    ulp = np.spacing(np.float32(abs(true)))
    naive_ulp = abs(_naive_fp32_sum(x) - true) / ulp
    comp_ulp = abs(float(ref.compensated_sum(jnp.asarray(x))) - true) / ulp
    assert comp_ulp <= 1.0, f"compensated sum off by {comp_ulp:.2f} ULP"
    assert naive_ulp >= 10.0, \
        f"input not adversarial enough (naive only {naive_ulp:.2f} ULP)"
    assert comp_ulp < naive_ulp / 10.0


def test_compensated_sum_axis_handling():
    """Axis semantics match jnp.sum over the reduced axis."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, 7, 3)), F32)
    for axis in (0, 1):
        got = ref.compensated_sum(x, axis=axis)
        want = jnp.sum(x.astype(jnp.float64), axis=axis)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)


def _cloud(n, seed, mass_span=4.0):
    """Cluster with masses spanning ``10**mass_span`` decades — wide-
    magnitude per-pair contributions, the case compensation exists for."""
    rng = np.random.default_rng(seed)
    pos = jnp.asarray(rng.standard_normal((n, 3)), F32)
    vel = jnp.asarray(rng.standard_normal((n, 3)) * 0.1, F32)
    mass = jnp.asarray(10.0 ** rng.uniform(-mass_span, 0, n) / n, F32)
    return pos, vel, mass


def test_mixed_kernel_two_sum_matches_neumaier_ref():
    """Two independent compensated implementations — the Pallas kernel's
    two-sum across j-blocks and the reference Neumaier scan — agree to
    fp32 rounding on a wide-magnitude cluster tiled over MANY j-blocks
    (block_j=32 at N=256 gives 8 accumulation steps per row).  Without
    compensation the block-boundary partial sums would differ at ~1e-4."""
    pos, vel, mass = _cloud(256, seed=11)
    kw = dict(eps=1e-7, block_i=32, block_j=32)
    a_ref, j_ref, p_ref = ops.acc_jerk_pot_rect(
        pos, vel, pos, vel, mass, impl="xla", dtype="mixed", **kw)
    a_k, j_k, p_k = ops.acc_jerk_pot_rect(
        pos, vel, pos, vel, mass, impl="pallas_interpret", dtype="mixed",
        **kw)
    # the two compensated schemes round differently by a few fp32 ULPs of
    # each row sum — 1e-5 relative is ~400x tighter than bf16's 2**-8
    # rounding, so an uncompensated accumulation still fails loudly here
    scale = float(jnp.max(jnp.abs(a_ref)))
    assert float(jnp.max(jnp.abs(a_k - a_ref))) < 1e-5 * scale
    assert float(jnp.max(jnp.abs(p_k - p_ref))) < 1e-5 * float(
        jnp.max(jnp.abs(p_ref)))
    s_ref = ops.snap_rect(pos, vel, a_ref, pos, vel, a_ref, mass,
                          impl="xla", dtype="mixed", **kw)
    s_k = ops.snap_rect(pos, vel, a_ref, pos, vel, a_ref, mass,
                        impl="pallas_interpret", dtype="mixed", **kw)
    assert float(jnp.max(jnp.abs(s_k - s_ref))) < 1e-5 * max(
        float(jnp.max(jnp.abs(s_ref))), 1.0)


def test_mixed_matches_fp64_within_bf16_rounding():
    """The mixed force is the fp64 force plus bf16 per-pair rounding noise
    (relative ~2**-8); the compensated accumulation must not let the error
    grow with the number of j-blocks."""
    pos, vel, mass = _cloud(192, seed=5, mass_span=2.0)
    a64, _, _ = ref.acc_jerk_pot_rect(
        pos.astype(jnp.float64), vel.astype(jnp.float64),
        pos.astype(jnp.float64), vel.astype(jnp.float64),
        mass.astype(jnp.float64), eps=1e-7)
    for impl in ("xla", "pallas_interpret"):
        am, _, _ = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass,
                                         impl=impl, dtype="mixed",
                                         eps=1e-7, block_i=32, block_j=32)
        rel = float(jnp.max(jnp.abs(am - a64.astype(F32)))
                    / jnp.max(jnp.abs(a64)))
        assert rel < 2.0 ** -7, f"{impl}: mixed rel error {rel:.2e}"


# --------------------------------------------------------------------------
# dtype plumbing: fp32 bit-identity, fp64 refusal
# --------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ("xla", "pallas_interpret"))
def test_fp32_dtype_is_bit_identical_to_default(impl):
    """dtype='fp32' must lower to EXACTLY the historical path — the golden
    lockdown of this PR's refactor (identity rounding, plain jnp.sum)."""
    pos, vel, mass = _cloud(96, seed=3)
    kw = dict(eps=1e-7, block_i=64, block_j=64, impl=impl)
    base = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, **kw)
    tagged = ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass,
                                   dtype="fp32", **kw)
    for b, t in zip(base, tagged):
        assert jnp.array_equal(b, t), "dtype='fp32' changed bits"
    s_base = ops.snap_rect(pos, vel, base[0], pos, vel, base[0], mass, **kw)
    s_tag = ops.snap_rect(pos, vel, base[0], pos, vel, base[0], mass,
                          dtype="fp32", **kw)
    assert jnp.array_equal(s_base, s_tag)


def test_compute_dtype_for_mapping():
    assert ops.compute_dtype_for("fp32") is None
    assert ops.compute_dtype_for("mixed") == "bfloat16"
    with pytest.raises(ValueError):
        ops.compute_dtype_for("fp64")  # oracle path, never a kernel dtype
    with pytest.raises(ValueError):
        ops.compute_dtype_for("fp16")


def test_rect_ops_reject_unknown_dtype():
    pos, vel, mass = _cloud(32, seed=0)
    with pytest.raises(ValueError):
        ops.acc_jerk_pot_rect(pos, vel, pos, vel, mass, impl="xla",
                              dtype="fp64")


def test_evaluator_dtype_fp64_routes_to_oracle():
    """make_evaluator(dtype='fp64') is the golden oracle — bit-identical to
    precision='fp64', untouched by kernel/impl switches."""
    from repro.core.evaluate import make_evaluator
    pos, vel, mass = _cloud(24, seed=9)
    pos64 = pos.astype(jnp.float64)
    a = make_evaluator(precision="fp64")(pos64, vel.astype(jnp.float64),
                                         mass.astype(jnp.float64))
    b = make_evaluator(dtype="fp64")(pos64, vel.astype(jnp.float64),
                                     mass.astype(jnp.float64))
    assert jnp.array_equal(a.acc, b.acc) and a.acc.dtype == jnp.float64


def test_ensemble_rejects_fp64_impl_mixed_dtype_conflict():
    from repro.sim import ensemble as ens
    from repro.sim import scenarios
    state = scenarios.make("plummer", 16, seed=0)
    with pytest.raises(ValueError, match="conflict"):
        ens.evolve_ensemble(ens.stack_states([state]), n_steps=1, dt=0.01,
                            impl="fp64", dtype="mixed")


# --------------------------------------------------------------------------
# capacity model: element width drives tile byte cost and occupancy
# --------------------------------------------------------------------------
def test_capacity_plan_dtype_byte_costs():
    mk = lambda d: ops.CapacityPlan(256, 256, 64, 64, dtype=d)  # noqa: E731
    fp64, fp32, mixed = mk("fp64"), mk("fp32"), mk("mixed")
    assert (fp64.io_bytes_per_element, fp64.compute_bytes_per_element) \
        == (8, 8)
    assert (fp32.io_bytes_per_element, fp32.compute_bytes_per_element) \
        == (4, 4)
    # mixed: fp32 operands in/out (unpack/pack), bf16 inside the compute
    assert (mixed.io_bytes_per_element, mixed.compute_bytes_per_element) \
        == (4, 2)
    assert fp64.tile_vmem_bytes > fp32.tile_vmem_bytes \
        > mixed.tile_vmem_bytes
    assert mixed.tile_io_bytes == fp32.tile_io_bytes
    vmem = 1 << 20
    assert mixed.tiles_per_vmem(vmem) >= fp32.tiles_per_vmem(vmem) \
        >= fp64.tiles_per_vmem(vmem)
    with pytest.raises(ValueError):
        ops.CapacityPlan(256, 256, 64, 64, dtype="int8")


def test_capacity_plan_dtype_survives_shard_and_restrict():
    plan = ops.CapacityPlan(256, 256, 64, 64, dtype="mixed")
    assert plan.shard(2).dtype == "mixed"
    assert plan.restrict(1).dtype == "mixed"


def test_capacity_plan_neighbor_source_window_byte_costs():
    """dtype x sources: the gathered neighbor window adds its own staging
    traffic to the tile byte model (the window rows move twice: gather into
    the contiguous buffer, then stream into the kernel), so a neighbor tile
    costs strictly more I/O than a full tile of the same dtype and never
    fits MORE tiles in a vmem budget."""
    vmem = 1 << 20
    for d in ops.DTYPES:
        full = ops.CapacityPlan(256, 256, 64, 64, dtype=d)
        nbr = ops.CapacityPlan(256, 256, 64, 64, dtype=d,
                               sources="neighbor")
        assert nbr.tile_io_bytes > full.tile_io_bytes
        assert nbr.tile_vmem_bytes > full.tile_vmem_bytes
        assert nbr.tiles_per_vmem(vmem) <= full.tiles_per_vmem(vmem)
        # the extra traffic scales with the element width, exactly
        assert (nbr.tile_io_bytes - full.tile_io_bytes) \
            == 2 * 8 * nbr.block_j * nbr.io_bytes_per_element
    with pytest.raises(ValueError):
        ops.CapacityPlan(256, 256, 64, 64, sources="windowed")


# --------------------------------------------------------------------------
# hermite.block_level_dt: dtype pinned to dt_max, not the x64 flag
# --------------------------------------------------------------------------
def test_block_level_dt_pins_state_dtype():
    """Regression: the level dt used to be reconstructed at
    jnp.result_type(float), which follows jax_enable_x64 (on in this suite)
    — an fp32 state silently got fp64 steps.  It now follows dt_max."""
    levels = jnp.asarray([0, 1, 3], jnp.int32)
    dt32 = hermite.block_level_dt(levels, jnp.float32(0.0625))
    assert dt32.dtype == jnp.float32
    dt64 = hermite.block_level_dt(levels, jnp.float64(0.0625))
    assert dt64.dtype == jnp.float64
    pinned = hermite.block_level_dt(levels, 0.0625, dtype=jnp.float32)
    assert pinned.dtype == jnp.float32
    # XLA's exp2 lowers via exp(x*ln2): 1-ULP slack on exact powers of two
    np.testing.assert_allclose(np.asarray(dt64),
                               [0.0625, 0.03125, 0.0078125], rtol=1e-15)
    np.testing.assert_allclose(np.asarray(dt32), np.asarray(dt64),
                               rtol=1e-6)


def test_block_level_dt_python_float_follows_default():
    """A bare python dt_max keeps the historical default-dtype behavior
    (x64 is on in this suite), so existing callers see no change."""
    levels = jnp.asarray([0, 2], jnp.int32)
    out = hermite.block_level_dt(levels, 0.0625)
    assert out.dtype == jnp.result_type(float)


# --------------------------------------------------------------------------
# kernel internals: the two-sum fold is gated to the LAST j-step only
# --------------------------------------------------------------------------
def test_packed_kernel_compute_dtype_none_matches_untagged():
    """The packed kernels with compute_dtype=None lower the single-output
    wiring — bitwise the historical kernel."""
    pos, vel, mass = _cloud(64, seed=1)
    npad = 64
    tgt = ops.pack_targets(pos, vel, npad)
    src = ops.pack_sources(pos, vel, mass, npad)
    base = nbody_force.acc_jerk_pot_packed(tgt, src, eps=1e-7, block_i=32,
                                           block_j=32, interpret=True)
    tagged = nbody_force.acc_jerk_pot_packed(tgt, src, eps=1e-7, block_i=32,
                                             block_j=32, interpret=True,
                                             compute_dtype=None)
    assert jnp.array_equal(base, tagged)
