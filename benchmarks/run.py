"""Benchmark harness entry point — one module per paper table/figure:

  table1_strategies : Table 1 (strategy time-to-solution + EDP)
  table1_scenarios  : Table 1 sweep over the repro.sim scenario library
  fig4_validation   : Fig. 4 (accuracy bands + energy-distribution overlap)
  fig5_scaling      : Fig. 5 (strong scaling 1/2/4 devices)
  fig6_energy       : Fig. 6 (energy-to-solution / peak power, EDP minimum)
  ensemble_throughput : batched B-run ensemble vs B sequential invocations
  mixed_ensemble    : padded mixed-scenario batch vs sequential + dispersion
  serve_throughput  : continuous-batching SimServer vs one-process-per-run
  bench_ci          : CI smoke trajectory (steppers + ensembles) -> BENCH_ci
  lm_step           : LM-side reduced-config step microbench
  roofline_table    : dry-run roofline summary (EXPERIMENTS.md §Roofline)

``python -m benchmarks.run [--quick] [--smoke] [--only NAME]``

Every ``benchmarks/*.py`` module with a ``run()`` entry point must be
registered in ``SUITES`` (``tests/test_block_stepper.py`` asserts the
registry is complete), so one command reproduces the full suite.
"""

from __future__ import annotations

import argparse
import inspect
import time


def suites() -> dict:
    """Name -> callable registry of every benchmark entry point."""
    from benchmarks import (bench_ci, ensemble_throughput, fig4_validation,
                            fig5_scaling, fig6_energy, lm_step,
                            mixed_ensemble, roofline_table,
                            serve_throughput, table1_strategies)

    return {
        "fig4_validation": fig4_validation.run,
        "fig5_scaling": fig5_scaling.run,
        "fig6_energy": fig6_energy.run,
        "table1_strategies": table1_strategies.run,
        "table1_scenarios": table1_strategies.run_scenarios,
        "ensemble_throughput": ensemble_throughput.run,
        "mixed_ensemble": mixed_ensemble.run,
        "serve_throughput": serve_throughput.run,
        "bench_ci": bench_ci.run,
        "lm_step": lm_step.run,
        "roofline_table": roofline_table.run,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller N / fewer archs (CI mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal smoke sizes where a suite supports them "
                         "(the CI bench-smoke job's mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    registry = suites()
    names = [args.only] if args.only else list(registry)
    for name in names:
        fn = registry[name]
        kw = {"quick": args.quick}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kw["smoke"] = True
        t0 = time.perf_counter()
        fn(**kw)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s\n")


if __name__ == "__main__":
    main()
