"""Roofline table generator: reads experiments/dryrun/*.json and emits the
per-(arch x shape x mesh) three-term table (EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks import common


def load(tag: str = "") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(common.DRYRUN_DIR, "*.json"))):
        name = os.path.basename(path)[:-5]
        parts = name.split("__")
        variant = parts[2].split("_", 1)[1] if "_" in parts[2] else "baseline"
        with open(path) as f:
            r = json.load(f)
        r["variant"] = variant
        rows.append(r)
    return rows


def fmt_row(r: dict) -> dict:
    if "skipped" in r:
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "variant": r.get("variant", "baseline"),
                "status": "SKIP (" + r["skipped"].split(":")[0] + ")"}
    if "error" in r:
        return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": "ERROR"}
    t = r["roofline"]
    pd = r["per_device"]
    variant = r.get("variant", "baseline")
    step = t["step_time_s"]
    # achievable fraction of the compute roofline: compute term / step time
    frac = t["compute_s"] / step if step else 0.0
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "variant": variant,
        "status": "ok",
        "compute_s": f"{t['compute_s']:.4f}",
        "memory_s": f"{t['memory_s']:.4f}",
        "collective_s": f"{t['collective_s']:.4f}",
        "bottleneck": t["bottleneck"],
        "roofline_frac": f"{frac:.3f}",
        "peak_GiB": f"{pd['peak_bytes'] / 2**30:.2f}",
        "useful_flops_frac": f"{min(r['useful_flops_fraction'], 9.99):.3f}",
    }


HEADERS = ["arch", "shape", "mesh", "variant", "status", "compute_s", "memory_s",
           "collective_s", "bottleneck", "roofline_frac", "peak_GiB",
           "useful_flops_frac"]


def markdown(rows: list) -> str:
    out = ["| " + " | ".join(HEADERS) + " |",
           "|" + "---|" * len(HEADERS)]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(h, "")) for h in HEADERS)
                   + " |")
    return "\n".join(out)


def run(quick: bool = False):
    rows = [fmt_row(r) for r in load()]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"],
                             r.get("variant", "")))
    common.emit("roofline_table", rows, HEADERS)
    return rows


if __name__ == "__main__":
    print(markdown(run()))
