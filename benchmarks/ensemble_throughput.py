"""Ensemble throughput: one batched call vs B sequential driver invocations.

The serving question behind ``repro.sim.ensemble``: given B independent
small-N simulations, is packing them into one stacked ``vmap`` call faster
end-to-end than running the driver B times?  Each sequential invocation pays
its own process start, jax import, trace/compile and per-step dispatch; the
batched call pays them once and amortizes every fixed cost over the batch —
the same economics as batched inference serving.

Both paths run in subprocesses (the standard multi-device benchmark harness
in ``benchmarks/common``), so the comparison is invocation-to-invocation:

  sequential: B processes x [import + compile + N-step run]
  batched:    1 process   x [import + compile + N-step run of the B-stack]

A second (informative) row reports the warm in-process ratio — batched step
throughput vs sequential step throughput with compile and import excluded —
which on a CPU host is memory-bandwidth-bound rather than dispatch-bound.
"""

from __future__ import annotations

import time

from benchmarks import common

N = 256
B = 8
DT = 1.0 / 512

_DRIVER = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario="plummer", n={n}, seed={seed},
                                ensemble={ensemble}, dt={dt}, t_end={t_end},
                                impl="xla", diag_every=32))
print("WALL", r["wall_s"])
"""

_WARM = """
import time
from repro.sim import driver
cfg = dict(scenario="plummer", n={n}, dt={dt}, t_end={t_end}, impl="xla",
           diag_every=32)
driver.run(driver.SimConfig(seed=100, ensemble={ensemble}, **cfg))  # warm
t0 = time.perf_counter()
driver.run(driver.SimConfig(seed=0, ensemble={ensemble}, **cfg))
print("WALL", time.perf_counter() - t0)
"""


def run(quick: bool = False, smoke: bool = False):
    """``smoke=True`` is the CI bench-smoke mode: a minimal batch and a short
    horizon, and the warm in-process row is skipped — just enough signal for
    the ``BENCH_ci.json`` perf trajectory inside the CI time budget."""
    t_end = 0.0625 if smoke else (0.125 if quick else 0.25)
    b = 3 if smoke else B
    rows = []

    # --- end-to-end: B sequential invocations vs one batched invocation ---
    t0 = time.perf_counter()
    seq_inner = 0.0
    for seed in range(b):
        out = common.run_subprocess(
            _DRIVER.format(n=N, seed=seed, ensemble=1, dt=DT, t_end=t_end))
        seq_inner += common.stdout_field(out, "WALL")
    seq_total = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = common.run_subprocess(
        _DRIVER.format(n=N, seed=0, ensemble=b, dt=DT, t_end=t_end))
    batch_inner = common.stdout_field(out, "WALL")
    batch_total = time.perf_counter() - t0

    rows.append({
        "mode": "end_to_end",
        "runs": b, "n": N, "t_end": t_end,
        "sequential_s": round(seq_total, 2),
        "batched_s": round(batch_total, 2),
        "speedup": round(seq_total / batch_total, 2),
        "sequential_inner_s": round(seq_inner, 2),
        "batched_inner_s": round(batch_inner, 2),
    })

    if not smoke:
        # --- warm in-process: steady-state step throughput only -----------
        out = common.run_subprocess(
            _WARM.format(n=N, ensemble=1, dt=DT, t_end=t_end))
        warm_seq = b * common.stdout_field(out, "WALL")
        out = common.run_subprocess(
            _WARM.format(n=N, ensemble=b, dt=DT, t_end=t_end))
        warm_batch = common.stdout_field(out, "WALL")
        rows.append({
            "mode": "warm_steady_state",
            "runs": b, "n": N, "t_end": t_end,
            "sequential_s": round(warm_seq, 2),
            "batched_s": round(warm_batch, 2),
            "speedup": round(warm_seq / warm_batch, 2),
        })

    common.emit("ensemble_throughput", rows,
                ["mode", "runs", "n", "t_end", "sequential_s", "batched_s",
                 "speedup", "sequential_inner_s", "batched_inner_s"])
    e2e = rows[0]["speedup"]
    target = 1.0 if smoke else 2.0
    print(f"# batched ensemble end-to-end speedup: {e2e:.2f}x "
          f"({'meets' if e2e >= target else 'BELOW'} the {target:.0f}x "
          "target)")
    return rows


if __name__ == "__main__":
    run()
