"""Shared benchmark utilities: timing, subprocess multi-device runs, and the
energy model used for the paper's Table 1 / Fig. 6 analogues.

The energy model itself lives in ``repro.obs.energy`` (the single source of
truth also used by ``repro.sim.telemetry``); the constants and
``modeled_energy`` are re-exported here so benchmark modules keep reading
``common.modeled_energy`` / ``common.P_CHIP``.
"""

from __future__ import annotations

import json
import os
import statistics
import subprocess
import sys
import time

from repro.obs.energy import (  # noqa: F401  (re-exported)
    IDLE_FRAC, P_CHIP, P_HOST, modeled_energy)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")
OUT_DIR = os.path.join(REPO, "experiments", "bench")


def time_fn(fn, *args, repeat: int = 5, warmup: int = 1):
    """(median_s, std_s) of fn(*args) with block_until_ready."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return (statistics.median(times),
            statistics.stdev(times) if len(times) > 1 else 0.0)


def run_subprocess(script: str, *, devices: int = 1, timeout: int = 1200,
                   x64: bool = True) -> str:
    """Run a python snippet with N host-platform devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    pre = ("import jax; jax.config.update('jax_enable_x64', True)\n"
           if x64 else "")
    res = subprocess.run([sys.executable, "-c", pre + script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return res.stdout


def stdout_field(out: str, key: str) -> float:
    """Extract ``<key> <float>`` from a subprocess's stdout marker lines."""
    for line in out.splitlines():
        if line.startswith(key + " "):
            return float(line.split()[-1])
    raise RuntimeError(f"no {key} line in output:\n{out}")


def emit(name: str, rows: list, header: list):
    """Print rows as CSV and persist to experiments/bench/<name>.json."""
    os.makedirs(OUT_DIR, exist_ok=True)
    print(f"# --- {name} ---")
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1)
