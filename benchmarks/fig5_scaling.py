"""Paper Fig. 5 analogue: strong scaling of the force evaluation over 1/2/4
devices for the two leading strategies (time-to-solution, speedup, parallel
efficiency)."""

from __future__ import annotations

from benchmarks import common

_SNIPPET = """
import time, jax
from repro.core import nbody, hermite
from repro.core.strategies import make_strategy_evaluator

state = nbody.plummer({n}, seed=0)
ev = make_strategy_evaluator("{strategy}", devices=jax.devices()[:{devices}],
                             impl="xla", chips_per_card={cpc})
state0 = hermite.initialize(state, ev)
jax.block_until_ready(state0.pos)
t0 = time.perf_counter()
out = hermite.evolve_scan(state0, ev, n_steps=3, dt=1e-3)
jax.block_until_ready(out.pos)
print("TIME", time.perf_counter() - t0)
"""


def run(quick: bool = False):
    n = 2048 if quick else 4096
    rows = []
    for strategy in ("replicated", "two_level"):
        t1 = None
        for devices in (1, 2, 4):
            cpc = 2 if (strategy == "two_level" and devices > 1) else 1
            out = common.run_subprocess(
                _SNIPPET.format(strategy=strategy, devices=devices, n=n,
                                cpc=cpc),
                devices=devices)
            t = float(out.strip().split()[-1])
            if t1 is None:
                t1 = t
            speedup = t1 / t
            rows.append({
                "strategy": strategy,
                "devices": devices,
                "time_s": round(t, 3),
                "speedup": round(speedup, 3),
                "efficiency_pct": round(100 * speedup / devices, 1),
            })
    common.emit("fig5_scaling", rows,
                ["strategy", "devices", "time_s", "speedup",
                 "efficiency_pct"])
    return rows


if __name__ == "__main__":
    run()
