"""Paper Fig. 6 analogue: energy-to-solution and peak power vs device count,
from the measured strong-scaling times (fig5) + the documented energy model.

Reproduces the paper's structural result: time-to-solution decreases
monotonically with devices, while energy-to-solution (and EDP) has a minimum
at an intermediate device count — because below-ideal parallel efficiency
burns chip-seconds faster than it saves wall-seconds."""

from __future__ import annotations

import json
import os

from benchmarks import common
from benchmarks import fig5_scaling


def run(quick: bool = False):
    path = os.path.join(common.OUT_DIR, "fig5_scaling.json")
    if os.path.exists(path):
        with open(path) as f:
            scaling = json.load(f)
    else:
        scaling = fig5_scaling.run(quick=quick)
    rows = []
    for r in scaling:
        if r["strategy"] != "replicated":
            continue
        util = 0.6 * r["efficiency_pct"] / 100.0
        e = common.modeled_energy(r["time_s"], r["devices"], util)
        rows.append({
            "devices": r["devices"],
            "time_s": r["time_s"],
            "energy_J": round(e["energy_J"], 1),
            "peak_W": round(e["peak_W"], 1),
            "EDP_Js": round(e["edp_Js"], 1),
        })
    # the EDP-minimum summary is meaningful for any sweep of >= 2 counts
    # (the seed's == 3 gate silently dropped it for other sweep lengths)
    if len(rows) >= 2:
        emin = min(rows, key=lambda r: r["EDP_Js"])
        for r in rows:
            r["edp_minimum"] = r is emin
    common.emit("fig6_energy", rows,
                ["devices", "time_s", "energy_J", "peak_W", "EDP_Js",
                 "edp_minimum"])
    return rows


if __name__ == "__main__":
    run()
