"""Paper Fig. 4 analogue: end-of-run particle-energy distribution of the
mixed-precision (FP32-kernel) run vs the FP64 golden reference, plus the
§4.1 accuracy bands (acc <= 0.05 %, jerk <= 0.2 %)."""

from __future__ import annotations

import numpy as np

from benchmarks import common


def run(quick: bool = False):
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.core import hermite, nbody
    from repro.core.evaluate import make_evaluator
    from repro.kernels import ops, ref

    n = 256 if quick else 1024
    state = nbody.plummer(n, seed=0)

    # --- accuracy bands (paper §4.1) ---
    a64, j64, _ = ref.acc_jerk_pot(state.pos, state.vel, state.mass)
    f32 = jnp.float32
    a32, j32, _ = ops.acc_jerk_pot(
        state.pos.astype(f32), state.vel.astype(f32),
        state.mass.astype(f32), impl="pallas_interpret")

    def band(x, y):
        scale = jnp.maximum(jnp.abs(y), jnp.abs(y).mean())
        return float(jnp.max(jnp.abs(x.astype(jnp.float64) - y) / scale))

    acc_dev = band(a32, a64)
    jerk_dev = band(j32, j64)

    # --- end-of-run energy distribution overlap ---
    t_end = 0.25 if quick else 1.0
    golden = make_evaluator(precision="fp64")
    device = make_evaluator(impl="pallas_interpret")
    out_g = hermite.evolve(state, golden, t_end=t_end, dt=1 / 256)
    out_d = hermite.evolve(state, device, t_end=t_end, dt=1 / 256)
    eg = np.asarray(nbody.particle_energies(out_g))
    ed = np.asarray(nbody.particle_energies(out_d))
    lo, hi = min(eg.min(), ed.min()), max(eg.max(), ed.max())
    hg, edges = np.histogram(eg, bins=30, range=(lo, hi), density=True)
    hd, _ = np.histogram(ed, bins=30, range=(lo, hi), density=True)
    width = edges[1] - edges[0]
    overlap = float(np.minimum(hg, hd).sum() * width)

    rows = [{
        "N": n,
        "acc_max_rel_dev": f"{acc_dev:.2e}",
        "acc_band_0.05pct": acc_dev < 5e-4,
        "jerk_max_rel_dev": f"{jerk_dev:.2e}",
        "jerk_band_0.2pct": jerk_dev < 2e-3,
        "energy_hist_overlap": round(overlap, 4),
        "energy_mean_rel_diff": f"{abs(eg.mean() - ed.mean()) / abs(eg.mean()):.2e}",
    }]
    common.emit("fig4_validation", rows, list(rows[0].keys()))
    return rows


if __name__ == "__main__":
    run()
