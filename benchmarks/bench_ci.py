"""CI bench-smoke: the per-PR perf trajectory, consolidated to BENCH_ci.json.

Three fast probes, one JSON artifact:

1. ``ensemble_throughput`` (smoke mode) — batched vs sequential invocations;
2. ``mixed_ensemble`` (smoke mode) — padded heterogeneous batch vs
   per-scenario processes;
3. a **stepper sweep** on ``binary_plummer`` (N=256, matched ``t_end``):
   ``fixed`` / ``adaptive`` / ``block`` through the driver, recording
   steps/s, interactions/s, |dE/E| and the *measured* per-run
   force-evaluation counts — the block stepper's acceptance metric
   (same-or-better energy error than shared-adaptive lockstep at >= 2x
   fewer force evaluations; the block row runs at half the adaptive eta,
   i.e. the matched-error operating point).

The consolidated ``BENCH_ci.json`` is written at the repo root; the CI
``bench-smoke`` job uploads it as a workflow artifact on every push, so
perf regressions show up as a trajectory, not an anecdote.

``python -m benchmarks.bench_ci`` (or via ``benchmarks.run --only bench_ci``).
"""

from __future__ import annotations

import json
import os
import time

from benchmarks import common

#: The stepper-sweep workload: wide timestep dynamic range (tight binaries
#: inside a Plummer sphere) — the case block timesteps exist for.
SCENARIO = "binary_plummer"
N = 256
T_END = 0.25
SEED = 0

OUT_PATH = os.path.join(common.REPO, "BENCH_ci.json")

_STEPPER = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario={scenario!r}, n={n}, seed={seed},
                                t_end={t_end}, stepper={stepper!r}, {extra}
                                impl="xla", diag_every=64))
print("WALL", r["wall_s"])
print("STEPS", r["steps"])
print("STEPS_PER_S", r["steps_per_s"])
print("PAIRS_PER_S", r["interactions_per_s"])
print("FORCE_EVALS", r["force_evals_total"])
print("DE_REL", r["de_rel"])
"""

#: Per-stepper extra SimConfig fields.  The block row halves eta: block
#: quantization rounds each particle's step down, so half the adaptive eta
#: lands at the adaptive run's energy error with far fewer evaluations.
STEPPER_CONFIGS = {
    "fixed": "dt=1.0/256,",
    "adaptive": "eta=0.02, dt_max=0.0625,",
    "block": "eta=0.01, dt_max=0.0625, n_levels=12,",
}


def stepper_sweep(quick: bool = False):
    rows = []
    t_end = T_END / 2 if quick else T_END
    for stepper, extra in STEPPER_CONFIGS.items():
        out = common.run_subprocess(_STEPPER.format(
            scenario=SCENARIO, n=N, seed=SEED, t_end=t_end, stepper=stepper,
            extra=extra))
        rows.append({
            "stepper": stepper,
            "scenario": SCENARIO, "n": N, "t_end": t_end, "seed": SEED,
            "wall_s": round(common.stdout_field(out, "WALL"), 2),
            "steps": int(common.stdout_field(out, "STEPS")),
            "steps_per_s": round(common.stdout_field(out, "STEPS_PER_S"), 1),
            "interactions_per_s":
                f"{common.stdout_field(out, 'PAIRS_PER_S'):.3e}",
            "force_evals": common.stdout_field(out, "FORCE_EVALS"),
            "de_rel": f"{common.stdout_field(out, 'DE_REL'):.3e}",
        })
    by = {r["stepper"]: r for r in rows}
    if "adaptive" in by and "block" in by:
        ratio = by["adaptive"]["force_evals"] / by["block"]["force_evals"]
        matched = (float(by["block"]["de_rel"])
                   <= float(by["adaptive"]["de_rel"]))
        print(f"# block vs adaptive: {ratio:.1f}x fewer force evals, "
              f"|dE/E| {by['block']['de_rel']} vs {by['adaptive']['de_rel']} "
              f"({'matched-or-better' if matched else 'NOT matched'}; "
              f"bar: >= 2x at matched error -> "
              f"{'PASS' if ratio >= 2.0 and matched else 'FAIL'})")
    common.emit("stepper_modes", rows,
                ["stepper", "scenario", "n", "t_end", "wall_s", "steps",
                 "steps_per_s", "interactions_per_s", "force_evals",
                 "de_rel"])
    return rows


def run(quick: bool = False, smoke: bool = True):
    """Run all three probes and write the consolidated BENCH_ci.json."""
    del smoke  # this module IS the smoke mode
    from benchmarks import ensemble_throughput, mixed_ensemble

    t0 = time.perf_counter()
    doc = {
        "suite": "bench_ci",
        "unix_time": int(time.time()),
        "ensemble_throughput": ensemble_throughput.run(smoke=True),
        "mixed_ensemble": mixed_ensemble.run(smoke=True),
        "stepper_modes": stepper_sweep(quick=quick),
    }
    doc["wall_s_total"] = round(time.perf_counter() - t0, 1)
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_ci.json written to {OUT_PATH} "
          f"({doc['wall_s_total']:.0f}s total)")
    return doc


if __name__ == "__main__":
    run()
