"""CI bench-smoke: the per-PR perf trajectory, consolidated to BENCH_ci.json.

Nine fast probes, one JSON artifact:

1. ``ensemble_throughput`` (smoke mode) — batched vs sequential invocations;
2. ``mixed_ensemble`` (smoke mode) — padded heterogeneous batch vs
   per-scenario processes;
3. a **stepper sweep** on ``binary_plummer`` (N=256, matched ``t_end``):
   ``fixed`` / ``adaptive`` / ``block`` through the driver, recording
   steps/s, interactions/s, wall time per event/step, |dE/E| and the
   *measured* per-run force-evaluation counts — the block stepper's
   acceptance metric (same-or-better energy error than shared-adaptive
   lockstep at >= 2x fewer force evaluations; the block row runs at half
   the adaptive eta, i.e. the matched-error operating point);
4. a **compaction sweep** on the same workload (seeds 0-2): the block
   stepper with ``compaction=none`` (masked full grid, ``pl.when``-skipped
   i-blocks still enqueued) vs ``compaction=gather`` (active targets
   gathered to a dense block-aligned buffer, grid shrunk to the live
   block).  Both runs are bit-for-bit identical physics, so the rows
   isolate the *launch* cost: grid tiles per macro step (bar: >= 2x fewer)
   and median wall per event (bar: no worse; >= 1.5x better on this
   workload, whose mean active fraction is well under 25%).  Wall time is
   taken from the median diag chunk so first-chunk compilation does not
   pollute the ratio;
5. a **strategy-compaction sweep**: the same A/B through the
   ``mesh_sharded`` strategy on a forced 2-device host mesh — each shard
   gathers its *local* active targets and launches
   ``ceil(cap_local/BI) x N/BJ`` tiles.  Bars: >= 1.5x fewer local tiles at
   <= 25% mean active fraction (the ISSUE acceptance gate), wall per event
   no worse.  Rows record the per-shard tile vectors from
   ``grid_tiles_per_shard``;
6. a **precision sweep** on the same workload (seeds 0-1): the shared-
   adaptive lockstep through all three ``--dtype`` modes — ``fp64`` (golden
   oracle), ``fp32`` (paper device precision) and ``mixed`` (bfloat16
   per-pair arithmetic with compensated fp32 accumulation, the Tensix
   unpack-fp32/compute-reduced/pack-fp32 fidelity pattern).  One row per
   dtype records the median wall per event and the worst-seed |dE/E|; the
   regress gate keys these rows by dtype, so fp32 wall only ever compares
   against fp32 wall and a mixed |dE/E| blow-up is its own regression;
7. a **neighbor sweep** on ``plummer`` at the fp64 tier: the block stepper
   with ``sources=full`` (every event sweeps all N sources) vs
   ``sources=neighbor`` (the Ahmad-Cohen split: near force from gathered
   per-block windows, far field NM08-predicted between refreshes).  One row
   per N records wall per event for both modes, the *measured* per-run
   force-evaluation totals, the worst |dE/E| and the refresh/overflow
   counters.  CI runs the N=1024 row (gated: absolute wall + fp64 energy
   tier); ``BENCH_NEIGHBOR_FULL=1`` extends the sweep to N=4096/16384
   locally, where the >= 3x wall-per-event acceptance bar applies
   (recorded, untracked — the fp64 full-source reference is minutes of
   single-process CPU at 16k);
8. a **ring-overlap A/B** at 2 and 4 forced-host devices: the
   double-buffered ring source sweep (prefetch the next shard's window
   before the local kernel runs, exactly ``p - 1`` ``ppermute`` rounds per
   pass) vs the synchronous baseline (``p`` rounds, the last one computed
   and discarded).  Rows record the exact per-evaluation shift-round
   counts from the trace-time ``ring.shifts_issued`` counter, the measured
   wall per evaluation and the achieved ``ring.overlap_frac``; the bar is
   the link-serialized comm wall ratio ``p / (p - 1)`` (>= 1.2x at 4
   devices), and the regress gate tracks the overlap rows' measured wall
   *and* shift count — reintroducing the dead shift is a +33% regression;
9. a **server smoke** (``serve_throughput``, smoke mode) — a deterministic
   Poisson arrival trace (B=4 slot pods, 2 forced-host devices) through the
   continuous-batching ``repro.serve.sim_engine.SimServer`` vs the naive
   one-process-per-request baseline.  The server subprocess asserts zero
   ``engine.cache_miss`` after warmup (admission/retire/backfill must reuse
   the warm engines); bars: >= 2x sustained requests/s, and the regress
   gate tracks the server row's ``s_per_request`` / ``p99_turnaround_s``.

The consolidated record is *appended* to the ``BENCH_ci.json`` trajectory
at the repo root, stamped with its provenance (git SHA, trajectory
``schema_version``, jax version, device count); the CI ``bench-smoke`` job
uploads the trajectory as a workflow artifact on every push and gates it
with ``python -m repro.obs.regress`` — a >20% regression of wall per
event, launched tiles or modeled EDP against the latest comparable
committed record fails the job (see ``docs/observability.md``).

``python -m benchmarks.bench_ci`` (or via ``benchmarks.run --only bench_ci``).
"""

from __future__ import annotations

import os
import time

from benchmarks import common
from repro.obs import regress

#: The stepper-sweep workload: wide timestep dynamic range (tight binaries
#: inside a Plummer sphere) — the case block timesteps exist for.
SCENARIO = "binary_plummer"
N = 256
T_END = 0.25
SEED = 0

OUT_PATH = os.path.join(common.REPO, "BENCH_ci.json")

#: diag chunk length shared by the sweep template and the per-event math
#: (the median chunk wall / DIAG_EVERY is the compile-free wall per event)
DIAG_EVERY = 64

_STEPPER = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario={scenario!r}, n={n}, seed={seed},
                                t_end={t_end}, stepper={stepper!r}, {extra}
                                impl="xla", diag_every={diag_every}))
print("WALL", r["wall_s"])
print("STEPS", r["steps"])
print("STEPS_PER_S", r["steps_per_s"])
print("PAIRS_PER_S", r["interactions_per_s"])
print("FORCE_EVALS", r["force_evals_total"])
print("DE_REL", r["de_rel"])
print("MEDIAN_CHUNK", r["step_wall_s"]["median"])
print("GRID_TILES", r.get("grid_tiles_total", 0.0))
print("EDP", r["modeled"]["edp_Js"])
"""

#: Per-stepper extra SimConfig fields.  The block row halves eta: block
#: quantization rounds each particle's step down, so half the adaptive eta
#: lands at the adaptive run's energy error with far fewer evaluations.
STEPPER_CONFIGS = {
    "fixed": "dt=1.0/256,",
    "adaptive": "eta=0.02, dt_max=0.0625,",
    "block": "eta=0.01, dt_max=0.0625, n_levels=12,",
}


def stepper_sweep(quick: bool = False):
    rows = []
    t_end = T_END / 2 if quick else T_END
    for stepper, extra in STEPPER_CONFIGS.items():
        out = common.run_subprocess(_STEPPER.format(
            scenario=SCENARIO, n=N, seed=SEED, t_end=t_end, stepper=stepper,
            extra=extra, diag_every=DIAG_EVERY))
        steps = int(common.stdout_field(out, "STEPS"))
        wall = common.stdout_field(out, "WALL")
        rows.append({
            "stepper": stepper,
            "scenario": SCENARIO, "n": N, "t_end": t_end, "seed": SEED,
            "wall_s": round(wall, 2),
            "steps": steps,
            # median diag chunk / DIAG_EVERY: the compile-free per-event
            # wall, same protocol as the compaction sweep's ratio
            "wall_per_event_s": round(
                common.stdout_field(out, "MEDIAN_CHUNK") / DIAG_EVERY, 6),
            "steps_per_s": round(common.stdout_field(out, "STEPS_PER_S"), 1),
            "interactions_per_s":
                f"{common.stdout_field(out, 'PAIRS_PER_S'):.3e}",
            "force_evals": common.stdout_field(out, "FORCE_EVALS"),
            "de_rel": f"{common.stdout_field(out, 'DE_REL'):.3e}",
            "edp_Js": round(common.stdout_field(out, "EDP"), 2),
        })
    by = {r["stepper"]: r for r in rows}
    if "adaptive" in by and "block" in by:
        ratio = by["adaptive"]["force_evals"] / by["block"]["force_evals"]
        matched = (float(by["block"]["de_rel"])
                   <= float(by["adaptive"]["de_rel"]))
        print(f"# block vs adaptive: {ratio:.1f}x fewer force evals, "
              f"|dE/E| {by['block']['de_rel']} vs {by['adaptive']['de_rel']} "
              f"({'matched-or-better' if matched else 'NOT matched'}; "
              f"bar: >= 2x at matched error -> "
              f"{'PASS' if ratio >= 2.0 and matched else 'FAIL'})")
    common.emit("stepper_modes", rows,
                ["stepper", "scenario", "n", "t_end", "wall_s", "steps",
                 "wall_per_event_s", "steps_per_s", "interactions_per_s",
                 "force_evals", "de_rel", "edp_Js"])
    return rows


#: The compaction A/B: identical physics (bit-for-bit), different launch.
#: block_i=32 gives the 256-particle grid 8 i-tiles for compaction to drop;
#: DIAG_EVERY-event chunks make the median chunk a compile-free wall sample.
_COMPACTION_EXTRA = ("eta=0.01, dt_max=0.0625, n_levels=12, "
                     "compaction={compaction!r}, block_i=32, block_j=256,")


def compaction_sweep(quick: bool = False):
    """Masked vs compacted block stepper on ``binary_plummer`` N=256.

    Acceptance bars (printed, recorded in the rows): >= 2x fewer grid tiles
    per macro step, median wall per event no worse — and >= 1.5x better
    here, where the mean active fraction sits well under 25% (the hardening
    binary owns most events).
    """
    rows = []
    t_end = T_END / 2 if quick else T_END
    seeds = (SEED,) if quick else (0, 1, 2)
    for seed in seeds:
        by = {}
        for compaction in ("none", "gather"):
            extra = _COMPACTION_EXTRA.format(compaction=compaction)
            out = common.run_subprocess(_STEPPER.format(
                scenario=SCENARIO, n=N, seed=seed, t_end=t_end,
                stepper="block", extra=extra, diag_every=DIAG_EVERY))
            events = int(common.stdout_field(out, "STEPS"))
            by[compaction] = {
                "events": events,
                "wall_s": common.stdout_field(out, "WALL"),
                # median diag chunk: excludes the compile chunk
                "wall_per_event_s":
                    common.stdout_field(out, "MEDIAN_CHUNK") / DIAG_EVERY,
                "grid_tiles": common.stdout_field(out, "GRID_TILES"),
                "force_evals": common.stdout_field(out, "FORCE_EVALS"),
                "de_rel": common.stdout_field(out, "DE_REL"),
            }
        none, gather = by["none"], by["gather"]
        # both runs share the event schedule, so totals compare directly
        tiles_ratio = none["grid_tiles"] / gather["grid_tiles"]
        speedup = none["wall_per_event_s"] / gather["wall_per_event_s"]
        active_frac = none["force_evals"] / (none["events"] * N * N)
        ok = (tiles_ratio >= 2.0 and speedup >= 1.0
              and (active_frac > 0.25 or speedup >= 1.5))
        print(f"# compaction seed={seed}: {tiles_ratio:.1f}x fewer tiles, "
              f"{speedup:.1f}x wall/event, active_frac={active_frac:.3f} "
              f"(bars: >=2x tiles, >=1x wall, >=1.5x at <=25% active -> "
              f"{'PASS' if ok else 'FAIL'})")
        rows.append({
            "scenario": SCENARIO, "n": N, "t_end": t_end, "seed": seed,
            "events": none["events"],
            "wall_per_event_none_s": round(none["wall_per_event_s"], 6),
            "wall_per_event_gather_s": round(gather["wall_per_event_s"], 6),
            "speedup": round(speedup, 2),
            "tiles_none": none["grid_tiles"],
            "tiles_gather": gather["grid_tiles"],
            "tiles_ratio": round(tiles_ratio, 2),
            "active_frac": round(active_frac, 4),
            "de_rel_match": none["de_rel"] == gather["de_rel"],
            "pass": ok,
        })
    common.emit("block_compaction", rows,
                ["scenario", "n", "t_end", "seed", "events",
                 "wall_per_event_none_s", "wall_per_event_gather_s",
                 "speedup", "tiles_none", "tiles_gather", "tiles_ratio",
                 "active_frac", "de_rel_match", "pass"])
    return rows


#: The distributed A/B: mesh_sharded on 2 forced-host devices, each shard
#: compacting its own local targets.  N/P = 128 local rows at block_i=32
#: give each shard 4 i-tiles for its local buckets to drop.
_STRATEGY = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario={scenario!r}, n={n}, seed={seed},
                                t_end={t_end}, stepper="block",
                                strategy="mesh_sharded", devices=2,
                                eta=0.01, dt_max=0.0625, n_levels=12,
                                compaction={compaction!r},
                                block_i=32, block_j=256,
                                impl="xla", diag_every={diag_every}))
print("WALL", r["wall_s"])
print("STEPS", r["steps"])
print("FORCE_EVALS", r["force_evals_total"])
print("DE_REL", r["de_rel"])
print("MEDIAN_CHUNK", r["step_wall_s"]["median"])
print("GRID_TILES", r["grid_tiles_total"])
print("TILES_SHARD_MAX", max(r["grid_tiles_per_shard"]))
"""


def strategy_compaction_sweep(quick: bool = False):
    """Shard-local masked vs compacted block stepper under ``mesh_sharded``
    on a forced 2-device host mesh (``binary_plummer`` N=256).

    Acceptance bars (printed, recorded in the rows): >= 1.5x fewer *local*
    grid tiles at <= 25% mean active fraction, median wall per event no
    worse.  Physics is bit-for-bit identical between the two runs, so the
    rows isolate what shard-local compaction does to the per-chip launch
    schedule.
    """
    rows = []
    t_end = T_END / 2  # two subprocesses per seed x 2 devices: keep it lean
    seeds = (SEED,) if quick else (0, 1)
    for seed in seeds:
        by = {}
        for compaction in ("none", "gather"):
            out = common.run_subprocess(
                _STRATEGY.format(scenario=SCENARIO, n=N, seed=seed,
                                 t_end=t_end, compaction=compaction,
                                 diag_every=DIAG_EVERY),
                devices=2)
            by[compaction] = {
                "events": int(common.stdout_field(out, "STEPS")),
                "wall_per_event_s":
                    common.stdout_field(out, "MEDIAN_CHUNK") / DIAG_EVERY,
                "grid_tiles": common.stdout_field(out, "GRID_TILES"),
                "tiles_shard_max":
                    common.stdout_field(out, "TILES_SHARD_MAX"),
                "force_evals": common.stdout_field(out, "FORCE_EVALS"),
                "de_rel": common.stdout_field(out, "DE_REL"),
            }
        none, gather = by["none"], by["gather"]
        tiles_ratio = none["grid_tiles"] / gather["grid_tiles"]
        local_ratio = none["tiles_shard_max"] / gather["tiles_shard_max"]
        speedup = none["wall_per_event_s"] / gather["wall_per_event_s"]
        active_frac = none["force_evals"] / (none["events"] * N * N)
        ok = (speedup >= 1.0
              and (active_frac > 0.25 or local_ratio >= 1.5))
        print(f"# strategy_compaction seed={seed}: {local_ratio:.1f}x fewer "
              f"local tiles ({tiles_ratio:.1f}x total), {speedup:.1f}x "
              f"wall/event, active_frac={active_frac:.3f} "
              f"(bars: >=1.5x local tiles at <=25% active, >=1x wall -> "
              f"{'PASS' if ok else 'FAIL'})")
        rows.append({
            "scenario": SCENARIO, "n": N, "t_end": t_end, "seed": seed,
            "strategy": "mesh_sharded", "devices": 2,
            "events": none["events"],
            "wall_per_event_none_s": round(none["wall_per_event_s"], 6),
            "wall_per_event_gather_s": round(gather["wall_per_event_s"], 6),
            "speedup": round(speedup, 2),
            "tiles_none": none["grid_tiles"],
            "tiles_gather": gather["grid_tiles"],
            "tiles_shard_max_none": none["tiles_shard_max"],
            "tiles_shard_max_gather": gather["tiles_shard_max"],
            "local_tiles_ratio": round(local_ratio, 2),
            "active_frac": round(active_frac, 4),
            "de_rel_match": none["de_rel"] == gather["de_rel"],
            "pass": ok,
        })
    common.emit("strategy_compaction", rows,
                ["scenario", "n", "t_end", "seed", "strategy", "devices",
                 "events", "wall_per_event_none_s", "wall_per_event_gather_s",
                 "speedup", "tiles_none", "tiles_gather",
                 "tiles_shard_max_none", "tiles_shard_max_gather",
                 "local_tiles_ratio", "active_frac", "de_rel_match", "pass"])
    return rows


#: The precision sweep: the same workload through each dtype mode.  fp64
#: routes to the pure-jnp oracle, so it carries no impl switch (the driver
#: refuses the conflicting pair); the kernel dtypes pin impl="xla" like the
#: other sweeps.
_PRECISION = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario={scenario!r}, n={n}, seed={seed},
                                t_end={t_end}, stepper="adaptive",
                                eta=0.02, dt_max=0.0625, dtype={dtype!r},
                                {impl} diag_every={diag_every}))
print("WALL", r["wall_s"])
print("STEPS", r["steps"])
print("DE_REL", r["de_rel"])
print("MEDIAN_CHUNK", r["step_wall_s"]["median"])
"""

#: documented |dE/E| tolerance tiers of the precision modes on this
#: workload (docs/ensembles.md "Precision modes"); printed as bars
DE_TIERS = {"fp64": 1e-6, "fp32": 1e-4, "mixed": 1e-3}


def precision_sweep(quick: bool = False):
    """All three dtype modes on ``binary_plummer`` N=256, seeds 0-1.

    One row per dtype: median wall per event across seeds (median diag
    chunk, compile-free) and the worst-seed |dE/E|.  The printed bar checks
    each dtype against its documented energy tier — the reduced-precision
    mode must buy its cheaper arithmetic without leaving its tier.
    """
    rows = []
    t_end = T_END / 2 if quick else T_END
    seeds = (SEED,) if quick else (0, 1)
    for dtype in ("fp64", "fp32", "mixed"):
        walls, des = [], []
        for seed in seeds:
            out = common.run_subprocess(_PRECISION.format(
                scenario=SCENARIO, n=N, seed=seed, t_end=t_end, dtype=dtype,
                impl="" if dtype == "fp64" else 'impl="xla",',
                diag_every=DIAG_EVERY))
            walls.append(
                common.stdout_field(out, "MEDIAN_CHUNK") / DIAG_EVERY)
            des.append(common.stdout_field(out, "DE_REL"))
        wall_per_event = sorted(walls)[len(walls) // 2]
        de_rel = max(des)
        tier = DE_TIERS[dtype]
        print(f"# precision dtype={dtype}: wall/event="
              f"{wall_per_event:.2e}s |dE/E|={de_rel:.3e} "
              f"(tier <= {tier:.0e} -> "
              f"{'PASS' if de_rel <= tier else 'FAIL'})")
        rows.append({
            "dtype": dtype,
            "scenario": SCENARIO, "n": N, "t_end": t_end,
            "seeds": list(seeds),
            "wall_per_event_s": round(wall_per_event, 6),
            "de_rel": de_rel,
            "de_tier": tier,
            "pass": de_rel <= tier,
        })
    common.emit("precision_sweep", rows,
                ["dtype", "scenario", "n", "t_end", "seeds",
                 "wall_per_event_s", "de_rel", "de_tier", "pass"])
    return rows


#: The Ahmad-Cohen A/B: the block stepper at the fp64 tier with the full
#: O(N^2) source sweep vs the neighbor split (windowed near force +
#: NM08-predicted far field).  Both runs share the level schedule on this
#: workload, so the rows isolate what the windows buy per event.  eps and
#: the radius follow the N^-1 softening convention of the large-N scaling
#: runs; refresh_levels=2 refreshes the far field every quarter macro step.
_NEIGHBOR = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario="plummer", n={n}, seed={seed},
                                t_end=0.0625, stepper="block",
                                dt_max=0.0625, n_levels=8, eta=0.01,
                                dtype="fp64", eps={eps},
                                block_i=32, block_j=32,
                                sources={sources!r},
                                neighbor_radius=0.125, refresh_levels=2,
                                validate_ic=False,
                                diag_every={diag_every}))
print("WALL", r["wall_s"])
print("STEPS", r["steps"])
print("FORCE_EVALS", r["force_evals_total"])
print("DE_REL", r["de_rel"])
print("MEDIAN_CHUNK", r["step_wall_s"]["median"])
print("REFRESHES", r.get("neighbor_refreshes", 0))
print("OVERFLOWS", r.get("neighbor_overflows", 0))
"""

#: fp64-tier energy bar of the neighbor split (the ISSUE acceptance gate:
#: the far-field prediction must not push the run out of the oracle tier)
NEIGHBOR_DE_TIER = 1e-6

#: N values of the CI leg (gated rows) and of the local full sweep
#: (``BENCH_NEIGHBOR_FULL=1``, recorded-but-untracked: the fp64 oracle's
#: O(N^2) full-source reference is minutes of single-process CPU at 16k)
NEIGHBOR_NS_CI = (1024,)
NEIGHBOR_NS_FULL = (1024, 4096, 16384)


def neighbor_sweep(quick: bool = False):
    """Full vs neighbor source sweep, block stepper at the fp64 tier.

    One row per N: the compile-free median wall per event of both source
    modes, the measured force-evaluation totals (the O(N^2) -> O(N*k)
    claim, not a model), the worst |dE/E| and the refresh/overflow
    counters.  The printed bar checks the fp64 energy tier everywhere and
    the >= 3x wall-per-event speedup at N >= 16384 (the ISSUE acceptance
    point, reached only in the ``BENCH_NEIGHBOR_FULL=1`` local sweep —
    CI gates the N=1024 row's absolute wall and energy instead, marked
    ``gate=True``)."""
    del quick  # one subprocess pair per N; the CI leg is already minimal
    ns = NEIGHBOR_NS_FULL if os.environ.get("BENCH_NEIGHBOR_FULL") \
        else NEIGHBOR_NS_CI
    rows = []
    for n in ns:
        eps = 4.0 / n
        by = {}
        for sources in ("full", "neighbor"):
            # the 16k full-source fp64 reference is ~half an hour of
            # single-process CPU; only the local full sweep ever waits that
            out = common.run_subprocess(_NEIGHBOR.format(
                n=n, seed=SEED, eps=eps, sources=sources,
                diag_every=DIAG_EVERY),
                timeout=1200 if n <= 1024 else 7200)
            by[sources] = {
                "events": int(common.stdout_field(out, "STEPS")),
                "wall_per_event_s":
                    common.stdout_field(out, "MEDIAN_CHUNK") / DIAG_EVERY,
                "force_evals": common.stdout_field(out, "FORCE_EVALS"),
                "de_rel": common.stdout_field(out, "DE_REL"),
                "refreshes": common.stdout_field(out, "REFRESHES"),
                "overflows": common.stdout_field(out, "OVERFLOWS"),
            }
        full, nbr = by["full"], by["neighbor"]
        speedup = full["wall_per_event_s"] / nbr["wall_per_event_s"]
        evals_ratio = full["force_evals"] / nbr["force_evals"]
        de_rel = max(full["de_rel"], nbr["de_rel"])
        ok = de_rel <= NEIGHBOR_DE_TIER and (n < 16384 or speedup >= 3.0)
        print(f"# neighbor N={n}: {speedup:.1f}x wall/event, "
              f"{evals_ratio:.1f}x fewer force evals, |dE/E|={de_rel:.3e}, "
              f"{nbr['refreshes']:.0f} refreshes / "
              f"{nbr['overflows']:.0f} overflows "
              f"(bars: tier <= {NEIGHBOR_DE_TIER:.0e}, >=3x at N>=16384 -> "
              f"{'PASS' if ok else 'FAIL'})")
        rows.append({
            "scenario": "plummer", "n": n, "seed": SEED, "t_end": 0.0625,
            "events": nbr["events"],
            "wall_per_event_full_s": round(full["wall_per_event_s"], 6),
            "wall_per_event_neighbor_s": round(nbr["wall_per_event_s"], 6),
            "speedup": round(speedup, 2),
            "force_evals_full": full["force_evals"],
            "force_evals_neighbor": nbr["force_evals"],
            "force_evals_ratio": round(evals_ratio, 2),
            "de_rel_full": f"{full['de_rel']:.3e}",
            "de_rel_neighbor": f"{nbr['de_rel']:.3e}",
            "refreshes": nbr["refreshes"],
            "overflows": nbr["overflows"],
            # only CI-reproducible rows feed the regress gate: the large-N
            # rows exist only under BENCH_NEIGHBOR_FULL=1, and a tracked
            # metric that vanishes from a record reads as a regression
            "gate": n in NEIGHBOR_NS_CI,
            "pass": ok,
        })
    common.emit("neighbor_sweep", rows,
                ["scenario", "n", "seed", "t_end", "events",
                 "wall_per_event_full_s", "wall_per_event_neighbor_s",
                 "speedup", "force_evals_full", "force_evals_neighbor",
                 "force_evals_ratio", "de_rel_full", "de_rel_neighbor",
                 "refreshes", "overflows", "gate", "pass"])
    return rows


#: The ring-overlap A/B: the double-buffered ring source sweep (prefetch
#: shard k+1's window before computing on window k, exactly p-1 ppermute
#: rounds per pass) vs the synchronous baseline (shift-after-compute, p
#: rounds, the last one dead).  The counter reads come from the trace-time
#: ``ring.shifts_issued`` metric; walls are medians over repeated timed
#: batches of the jitted evaluator (compile excluded).
_RING = """
import time
import jax
from repro.core.strategies import make_strategy_evaluator
from repro.obs import metrics as obs_metrics
from repro.sim import scenarios

state = scenarios.make({scenario!r}, {n}, seed={seed})
walls, shifts = {{}}, {{}}
for mode in ("sync", "overlap"):
    reg = obs_metrics.MetricsRegistry()
    with obs_metrics.use(reg):
        ev = make_strategy_evaluator("ring", devices=jax.devices(),
                                     impl="xla", ring_mode=mode)
        f = jax.jit(lambda p, v, m: ev(p, v, m))
        out = f(state.pos, state.vel, state.mass)
        jax.block_until_ready(out.acc)
    shifts[mode] = reg._metrics.get("ring.shifts_issued").value
    reps = []
    for _ in range({reps}):
        t0 = time.perf_counter()
        for _ in range({iters}):
            out = f(state.pos, state.vel, state.mass)
        jax.block_until_ready(out.acc)
        reps.append((time.perf_counter() - t0) / {iters})
    walls[mode] = sorted(reps)[len(reps) // 2]
frac = 1.0 - walls["overlap"] / walls["sync"]
obs_metrics.registry().gauge(
    "ring.overlap_frac", unit="fraction",
    help="measured wall fraction the overlapped ring saves").set(frac)
print("WALL_SYNC", walls["sync"])
print("WALL_OVERLAP", walls["overlap"])
print("SHIFTS_SYNC", shifts["sync"])
print("SHIFTS_OVERLAP", shifts["overlap"])
print("OVERLAP_FRAC", frac)
"""

#: device counts of the ring A/B rows (the acceptance bar applies at 4)
RING_DEVICES = (2, 4)


def ring_overlap_sweep(quick: bool = False):
    """Double-buffered vs synchronous ring at 2 and 4 forced-host devices.

    One row per device count: the per-pass ``ppermute`` rounds of both
    schedules (exact, from the trace-time counter), the measured wall per
    evaluation and the achieved-overlap fraction.  The acceptance bar is
    the **link-serialized communication wall per event** — on hardware
    whose inter-chip hops serialize (the regime the paper's scaling
    section targets) comm wall is proportional to shift rounds, so the
    improvement is exactly ``p / (p - 1)``: 2.0x at p=2, 1.33x at p=4
    (bar: >= 1.2x at 4 devices).  The *measured* CPU wall is recorded and
    regress-tracked but not gated on a ratio: forced host devices emulate
    collectives as thread rendezvous, so link time is invisible to it
    (``overlap_frac`` reports whatever the host mesh achieves, noise
    included).
    """
    rows = []
    iters = 50 if quick else 200
    for devices in RING_DEVICES:
        out = common.run_subprocess(
            _RING.format(scenario=SCENARIO, n=N, seed=SEED,
                         reps=3 if quick else 5, iters=iters),
            devices=devices)
        sh_sync = common.stdout_field(out, "SHIFTS_SYNC")
        sh_over = common.stdout_field(out, "SHIFTS_OVERLAP")
        wall_sync = common.stdout_field(out, "WALL_SYNC")
        wall_over = common.stdout_field(out, "WALL_OVERLAP")
        frac = common.stdout_field(out, "OVERLAP_FRAC")
        # each traced evaluation runs two ring sweeps (acc + snap passes)
        comm_ratio = sh_sync / sh_over
        ok = (sh_over == 2 * (devices - 1) and sh_sync == 2 * devices
              and comm_ratio >= 1.2)
        print(f"# ring_overlap p={devices}: {comm_ratio:.2f}x fewer "
              f"ppermute rounds ({sh_sync:.0f} -> {sh_over:.0f} per eval; "
              f"link-serialized comm wall/event, bar >= 1.2x at p=4 -> "
              f"{'PASS' if ok else 'FAIL'}); measured wall/eval "
              f"{wall_sync / wall_over:.2f}x, overlap_frac={frac:+.3f} "
              f"(host-emulated mesh: rendezvous only)")
        rows.append({
            "scenario": SCENARIO, "n": N, "seed": SEED, "devices": devices,
            "shift_rounds_sync": sh_sync,
            "shift_rounds_overlap": sh_over,
            "comm_ratio": round(comm_ratio, 2),
            "wall_per_eval_sync_s": round(wall_sync, 6),
            "wall_per_eval_overlap_s": round(wall_over, 6),
            "overlap_frac": round(frac, 4),
            "pass": ok,
        })
    common.emit("ring_overlap", rows,
                ["scenario", "n", "seed", "devices", "shift_rounds_sync",
                 "shift_rounds_overlap", "comm_ratio",
                 "wall_per_eval_sync_s", "wall_per_eval_overlap_s",
                 "overlap_frac", "pass"])
    return rows


#: forced-host device count of the distributed probe — part of the
#: provenance stamp (records from differently-shaped suites never compare)
STRATEGY_DEVICES = 2


def run(quick: bool = False, smoke: bool = True):
    """Run every probe and *append* one stamped record to BENCH_ci.json.

    The record carries a ``provenance`` stamp (git SHA, trajectory
    ``schema_version``, jax version, device count) so the
    ``repro.obs.regress`` gate can refuse incomparable baselines; the gate
    itself runs as a separate CI step (``python -m repro.obs.regress``) so a
    regression fails the job with the full summary in the log.
    """
    del smoke  # this module IS the smoke mode
    from benchmarks import (ensemble_throughput, mixed_ensemble,
                            serve_throughput)

    t0 = time.perf_counter()
    doc = {
        "suite": "bench_ci",
        "unix_time": int(time.time()),
        "ensemble_throughput": ensemble_throughput.run(smoke=True),
        "mixed_ensemble": mixed_ensemble.run(smoke=True),
        "stepper_modes": stepper_sweep(quick=quick),
        "block_compaction": compaction_sweep(quick=quick),
        "strategy_compaction": strategy_compaction_sweep(quick=quick),
        "precision_sweep": precision_sweep(quick=quick),
        "neighbor_sweep": neighbor_sweep(quick=quick),
        "ring_overlap": ring_overlap_sweep(quick=quick),
        "serve_throughput": serve_throughput.run(smoke=True),
    }
    doc["wall_s_total"] = round(time.perf_counter() - t0, 1)
    doc["provenance"] = regress.provenance(STRATEGY_DEVICES, repo=common.REPO)
    records = regress.append_record(OUT_PATH, doc)
    print(f"# BENCH_ci.json: appended record {len(records)} "
          f"(sha {doc['provenance']['git_sha'][:12]}, "
          f"{doc['wall_s_total']:.0f}s total)")
    print(regress.check(OUT_PATH).summary())
    return doc


if __name__ == "__main__":
    run()
