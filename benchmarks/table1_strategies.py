"""Paper Table 1 analogue: time-to-solution + EDP per scaling strategy.

Measured part (CPU host, 4 placeholder devices, reduced N, 3 Hermite steps —
the paper's own step count): wall time per strategy, normalized to the
single-chip configuration.  Modeled part: the 409600-particle full-scale
energy/EDP from the measured time scaled by (N_full/N_bench)^2 and the
energy model in benchmarks/common.py.

The paper's ranking to reproduce: single-chip DP fastest; multi-chip ~+3.6%;
mesh-based (runtime-managed reshards) slowest; EDP minimized at 2 ranks.
"""

from __future__ import annotations

from benchmarks import common

N_BENCH = 4096
N_FULL = 409_600
STEPS = 3

_SNIPPET = """
import time, jax, jax.numpy as jnp
from repro.core import hermite
from repro.core.strategies import make_strategy_evaluator

{setup}
ev = make_strategy_evaluator("{strategy}", devices=jax.devices()[:{devices}],
                             impl="xla", chips_per_card=2)
state0 = hermite.initialize(state, ev)   # compile + bootstrap
jax.block_until_ready(state0.pos)
t0 = time.perf_counter()
out = hermite.evolve_scan(state0, ev, n_steps={steps}, dt=1e-3)
jax.block_until_ready(out.pos)
print("TIME", time.perf_counter() - t0)
"""

_PLUMMER_SETUP = """\
from repro.core import nbody
state = nbody.plummer({n}, seed=0)"""

_SCENARIO_SETUP = """\
from repro.sim import scenarios
state = scenarios.make("{scenario}", {n}, seed=0)"""


def run(quick: bool = False):
    n = 2048 if quick else N_BENCH
    rows = []
    cases = [
        ("replicated", 1, "Multi-Host Single-Chip (1 chip)"),
        ("replicated", 2, "Multi-Host Single-Chip (2 chips)"),
        ("two_level", 2, "Multi-Host Multi-Chip (1 card, 2 chips)"),
        ("mesh_sharded", 2, "Mesh-Based (1 card, 2 chips)"),
        ("ring", 2, "Ring systolic (beyond-paper, 2 chips)"),
        ("replicated", 4, "Multi-Host Single-Chip (4 chips)"),
    ]
    base_time = None
    for strategy, devices, label in cases:
        out = common.run_subprocess(
            _SNIPPET.format(setup=_PLUMMER_SETUP.format(n=n),
                            strategy=strategy, devices=devices, steps=STEPS),
            devices=max(devices, 1))
        t = float(out.strip().split()[-1])
        if base_time is None:
            base_time = t
        scale = (N_FULL / n) ** 2 / devices * 1  # O(N^2), ideal DP speedup
        t_model = t * (N_FULL / n) ** 2 * 1.0    # measured incl. its devices
        energy = common.modeled_energy(t_model, devices, util=0.6)
        rows.append({
            "configuration": label,
            "strategy": strategy,
            "chips": devices,
            "bench_time_s": round(t, 3),
            "vs_single": round(t / base_time, 3),
            "modeled_full_time_s": round(t_model, 1),
            "modeled_EDP_kJs": round(
                energy["edp_Js"] * (t_model / t_model) / 1e3, 1),
        })
        del scale
    common.emit("table1_strategies", rows,
                ["configuration", "strategy", "chips", "bench_time_s",
                 "vs_single", "modeled_full_time_s", "modeled_EDP_kJs"])
    return rows


SCENARIO_SWEEP = ("plummer", "king", "merger", "cold_collapse")


def run_scenarios(quick: bool = False):
    """Scenario sweep of the strategy ranking (workload-shape sensitivity).

    Related work shows strategy rankings shift with workload shape; this
    repeats the Table 1 measurement over the ``repro.sim`` scenario library
    and reports, per scenario, each strategy's time normalized to the
    single-chip baseline plus its rank.
    """
    n = 512 if quick else 2048
    names = SCENARIO_SWEEP[:2] if quick else SCENARIO_SWEEP
    cases = [("replicated", 1), ("replicated", 2), ("two_level", 2),
             ("mesh_sharded", 2), ("ring", 2)]
    rows = []
    for scenario in names:
        base_time = None
        scen_rows = []
        for strategy, devices in cases:
            out = common.run_subprocess(
                _SNIPPET.format(
                    setup=_SCENARIO_SETUP.format(scenario=scenario, n=n),
                    strategy=strategy, devices=devices, steps=STEPS),
                devices=max(devices, 1))
            t = float(out.strip().split()[-1])
            if base_time is None:
                base_time = t
            scen_rows.append({
                "scenario": scenario,
                "strategy": strategy,
                "chips": devices,
                "bench_time_s": round(t, 3),
                "vs_single": round(t / base_time, 3),
            })
        for rank, r in enumerate(
                sorted(scen_rows, key=lambda r: r["bench_time_s"]), 1):
            r["rank"] = rank
        rows.extend(scen_rows)
    common.emit("table1_scenarios", rows,
                ["scenario", "strategy", "chips", "bench_time_s",
                 "vs_single", "rank"])
    return rows


if __name__ == "__main__":
    run()
    run_scenarios()
