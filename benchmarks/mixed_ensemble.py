"""Mixed-scenario padded ensembles: batched vs sequential, and the cost of
padding as the batch's N-dispersion grows.

Two questions behind serving heterogeneous traffic from one compiled
executable:

1. **Is one padded batch faster than running each scenario separately?**
   A B-member mix (different generators, different N) is packed to
   ``(B, N_max)`` with zero-mass padding and advanced by the mask-aware
   ensemble engine; the sequential baseline runs each scenario in its own
   process at its own N (each paying import + trace/compile + dispatch).

2. **What does padding cost as the mix gets more ragged?**  A padded batch
   does ``B * N_max^2`` pair work but only ``sum(n_i^2)`` of it is active;
   ``pad_factor`` is that ratio (1.0 = rectangular, no waste).  The sweep
   holds B fixed and widens the N spread, reporting the measured wall time
   next to the theoretical factor — when ``pad_factor`` outgrows the
   batching win, split the traffic into per-shape batches instead.

Telemetry honesty: every reported interactions/s uses per-run ``n_active``
(zero-mass rows are never credited as throughput).
"""

from __future__ import annotations

import time

from benchmarks import common

DT = 1.0 / 256

#: The B=4 serving mix for the batched-vs-sequential comparison.
MIX = (("king", 256), ("merger", 512), ("plummer", 128),
       ("cold_collapse", 192))

#: Constant B, widening N-dispersion (uniform -> mildly -> wildly ragged).
DISPERSION_MIXES = {
    "uniform": (("plummer", 256),) * 4,
    "mild": (("plummer", 192), ("plummer", 256), ("plummer", 256),
             ("plummer", 320)),
    "wide": (("plummer", 64), ("plummer", 128), ("plummer", 256),
             ("plummer", 512)),
}

_SINGLE = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario={name!r}, n={n}, seed={seed},
                                dt={dt}, t_end={t_end}, impl="xla",
                                diag_every=32))
print("WALL", r["wall_s"])
"""

_MIXED = """
from repro.sim import driver
r = driver.run(driver.SimConfig(mix={mix!r}, dt={dt}, t_end={t_end},
                                kernel="ref", diag_every=32))
print("WALL", r["wall_s"])
print("PAIRS_PER_S", r["interactions_per_s"])
"""


def pad_factor(mix) -> float:
    ns = [n for _, n in mix]
    n_max = max(ns)
    return len(ns) * n_max * n_max / sum(n * n for n in ns)


def run(quick: bool = False, smoke: bool = False):
    """``smoke=True`` (CI bench-smoke): a 2-member mix, short horizon, and
    only the widest dispersion row — the trajectory point, not the sweep."""
    t_end = 0.03125 if smoke else (0.0625 if quick else 0.125)
    mix0 = MIX[:2] if smoke else MIX
    rows = []

    # --- 1: B sequential per-scenario processes vs one padded batch -------
    t0 = time.perf_counter()
    seq_inner = 0.0
    for i, (name, n) in enumerate(mix0):
        out = common.run_subprocess(
            _SINGLE.format(name=name, n=n, seed=i, dt=DT, t_end=t_end))
        seq_inner += common.stdout_field(out, "WALL")
    seq_total = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = common.run_subprocess(
        _MIXED.format(mix=tuple(mix0), dt=DT, t_end=t_end))
    batch_inner = common.stdout_field(out, "WALL")
    batch_total = time.perf_counter() - t0

    rows.append({
        "mode": "end_to_end",
        "mix": " ".join(f"{nm}:{n}" for nm, n in mix0),
        "pad_factor": round(pad_factor(mix0), 2),
        "sequential_s": round(seq_total, 2),
        "batched_s": round(batch_total, 2),
        "speedup": round(seq_total / batch_total, 2),
        "sequential_inner_s": round(seq_inner, 2),
        "batched_inner_s": round(batch_inner, 2),
    })

    # --- 2: padding overhead vs N-dispersion (constant B) -----------------
    dispersion = {"wide": DISPERSION_MIXES["wide"]} if smoke \
        else DISPERSION_MIXES
    for label, mix in dispersion.items():
        out = common.run_subprocess(
            _MIXED.format(mix=tuple(mix), dt=DT, t_end=t_end))
        wall = common.stdout_field(out, "WALL")
        rows.append({
            "mode": f"dispersion_{label}",
            "mix": " ".join(f"{nm}:{n}" for nm, n in mix),
            "pad_factor": round(pad_factor(mix), 2),
            # inner driver wall only — comparable across dispersion rows,
            # NOT with the end_to_end row's process-inclusive timings
            "batched_inner_s": round(wall, 2),
            "active_pairs_per_s": f"{common.stdout_field(out, 'PAIRS_PER_S'):.3e}",
        })

    common.emit("mixed_ensemble", rows,
                ["mode", "mix", "pad_factor", "sequential_s", "batched_s",
                 "speedup", "sequential_inner_s", "batched_inner_s",
                 "active_pairs_per_s"])
    e2e = rows[0]["speedup"]
    print(f"# padded mixed-ensemble end-to-end speedup: {e2e:.2f}x "
          f"({'meets' if e2e >= 1.0 else 'BELOW'} the >= 1x acceptance bar "
          f"at B={len(mix0)})")
    return rows


if __name__ == "__main__":
    run()
