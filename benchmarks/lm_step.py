"""LM-side microbenchmark: train-step and decode-step wall time for reduced
configs of every assigned architecture (CPU regression numbers; the full
configs are characterized by the dry-run roofline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.data import SyntheticLM, batch_spec_for
from repro.distributed.shardings import MeshRules
from repro.launch.train import scaled_config
from repro.models import config as C
from repro.models import params as P
from repro.optim import AdamW
from repro.train import make_train_step

ARCHS = ["stablelm-3b", "qwen3-0.6b", "zamba2-7b", "xlstm-1.3b",
         "phi3.5-moe-42b-a6.6b", "deepseek-v2-236b", "qwen2-vl-2b",
         "seamless-m4t-medium"]


def run(quick: bool = False):
    rules = MeshRules.single_device()
    archs = ARCHS[:3] if quick else ARCHS
    b, s = 2, 64
    rows = []
    for arch in archs:
        cfg = scaled_config(C.get(arch), 0.04)
        data = SyntheticLM(cfg, batch_spec_for(cfg, b, s))
        batch = {k: jnp.asarray(v) for k, v in data(0).items()}
        params = P.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(learning_rate=1e-3)
        step = jax.jit(make_train_step(cfg, rules, opt))
        opt_state = opt.init(params)
        t, sd = common.time_fn(
            lambda: step(params, opt_state, batch)[2]["loss"],
            repeat=3)
        tokens = b * batch["labels"].shape[1]
        rows.append({
            "arch": arch,
            "family": cfg.family,
            "params": P.count_params(cfg),
            "train_step_ms": round(t * 1e3, 1),
            "tok_per_s": round(tokens / t, 1),
        })
    common.emit("lm_step", rows,
                ["arch", "family", "params", "train_step_ms", "tok_per_s"])
    return rows


if __name__ == "__main__":
    run()
