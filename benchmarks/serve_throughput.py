"""Serving throughput: the continuous-batching server vs one-process-per-run.

A deterministic Poisson arrival trace (exponential inter-arrival gaps,
``numpy`` PRNG seed 0) of small scenario requests is replayed two ways:

* **server** — one ``repro.serve.sim_engine.SimServer`` subprocess (B=4
  slot pods on a forced 2-device host mesh).  The server warms up the
  ``(stepper, capacity)`` pods the trace maps to, then admits arrivals into
  running padded ensembles, advancing all members in lockstep and
  backfilling retired slots.  The subprocess asserts the steady-state
  ``engine.cache_miss`` delta is **zero** — admissions and retirements must
  reuse the warm engines — and reports it as ``CACHE_MISS_POST_WARMUP``;
* **per_process** — the naive baseline: every request is its own
  ``driver.run`` subprocess, paying process spawn + jax import + engine
  compile per request, serialized (one at a time, arrival order).

Rows record sustained requests/s, seconds per request (the gated
lower-is-better form) and the p50/p99 submit-to-retire turnaround.  Bar
(printed and recorded): the server sustains **>= 2x** the baseline's
requests/s.  The ``repro.obs.regress`` gate tracks the server row's
``s_per_request`` and ``p99_turnaround_s`` across the BENCH_ci trajectory.

``python -m benchmarks.serve_throughput`` (or via ``benchmarks.run``).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common

DEVICES = 2
SLOTS_PER_POD = 4
N_MAX = 128
CHUNK_EVENTS = 8
T_END = 0.04
MEAN_GAP_S = 0.05

#: request shapes the trace cycles through: two capacity buckets
#: (48 -> cap 64, 96 -> cap 128 at block_i=32) x both servable steppers
REQUEST_SHAPES = ((48, "adaptive"), (96, "block"),
                  (48, "block"), (96, "adaptive"))


def poisson_trace(n_requests: int, mean_gap_s: float = MEAN_GAP_S,
                  seed: int = 0):
    """[(arrival_s, n, stepper, seed), ...] — deterministic Poisson trace."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_gap_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    return [(float(arrivals[i]), *REQUEST_SHAPES[i % len(REQUEST_SHAPES)], i)
            for i in range(n_requests)]


_SERVER = """
import time
from repro.serve.sim_engine import SimServer, ServerConfig, SimRequest
from repro.sim.scenarios import ScenarioSpec

TRACE = {trace!r}
cfg = ServerConfig(slots_per_pod={slots}, n_max={n_max},
                   chunk_events={chunk}, block_i=32, block_j=32,
                   devices={devices})
server = SimServer(cfg)
pending = [(t, SimRequest(spec=ScenarioSpec.parse("plummer:%d" % n, seed=s),
                          stepper=st, t_end={t_end}))
           for (t, n, st, s) in TRACE]
server.warmup([r for _, r in pending])
m0 = server.cache_misses()
t0 = time.perf_counter()
while pending or server.busy():
    now = time.perf_counter() - t0
    while pending and pending[0][0] <= now:
        server.submit(pending.pop(0)[1])
    if server.busy():
        server.step()
    else:
        time.sleep(0.001)
wall = time.perf_counter() - t0
turn = sorted(r["turnaround_s"] for r in server.reports)
assert server.cache_misses() == m0, "recompile after warmup"
print("REQUESTS", len(server.reports))
print("WALL", wall)
print("P50_TURNAROUND", turn[len(turn) // 2])
print("P99_TURNAROUND",
      turn[min(int(0.99 * (len(turn) - 1) + 0.5), len(turn) - 1)])
print("CACHE_MISS_POST_WARMUP", server.cache_misses() - m0)
"""

_BASELINE = """
from repro.sim import driver
r = driver.run(driver.SimConfig(scenario="plummer", n={n}, seed={seed},
                                t_end={t_end}, stepper={stepper!r},
                                eta=0.02, dt_max=0.0625, n_levels=8,
                                impl="xla"))
print("WALL", r["wall_s"])
"""


def run(quick: bool = False, smoke: bool = False):
    # 4-request traces end before the server's concurrency can amortize the
    # per-process spawn+compile cost it is measured against — 6 is the
    # smallest trace that clears the 2x bar with margin
    n_requests = 6 if (quick or smoke) else 8
    trace = poisson_trace(n_requests)

    out = common.run_subprocess(
        _SERVER.format(trace=trace, slots=SLOTS_PER_POD, n_max=N_MAX,
                       chunk=CHUNK_EVENTS, devices=DEVICES, t_end=T_END),
        devices=DEVICES)
    served = int(common.stdout_field(out, "REQUESTS"))
    wall_server = common.stdout_field(out, "WALL")
    cache_miss = common.stdout_field(out, "CACHE_MISS_POST_WARMUP")

    # the naive baseline: every request its own process, serialized — each
    # pays spawn + jax import + compile; wall is measured around the whole
    # subprocess because that IS the one-process-per-request cost
    wall_baseline = 0.0
    for _, n, stepper, seed in trace:
        t0 = time.perf_counter()
        common.run_subprocess(
            _BASELINE.format(n=n, seed=seed, t_end=T_END, stepper=stepper),
            devices=DEVICES)
        wall_baseline += time.perf_counter() - t0

    rps_server = served / wall_server
    rps_baseline = n_requests / wall_baseline
    speedup = rps_server / rps_baseline
    print(f"# serve_throughput: server {rps_server:.2f} req/s vs "
          f"per-process {rps_baseline:.2f} req/s = {speedup:.1f}x, "
          f"cache_miss_post_warmup={cache_miss:.0f} "
          f"(bars: >= 2x req/s, zero recompiles -> "
          f"{'PASS' if speedup >= 2.0 and cache_miss == 0.0 else 'FAIL'})")
    rows = [
        {"mode": "server", "requests": served, "devices": DEVICES,
         "slots_per_pod": SLOTS_PER_POD,
         "wall_s": round(wall_server, 3),
         "requests_per_s": round(rps_server, 3),
         "s_per_request": round(wall_server / served, 4),
         "p50_turnaround_s":
             round(common.stdout_field(out, "P50_TURNAROUND"), 4),
         "p99_turnaround_s":
             round(common.stdout_field(out, "P99_TURNAROUND"), 4),
         "cache_miss_post_warmup": cache_miss,
         "speedup": round(speedup, 2),
         "pass": speedup >= 2.0 and cache_miss == 0.0},
        {"mode": "per_process", "requests": n_requests, "devices": DEVICES,
         "wall_s": round(wall_baseline, 3),
         "requests_per_s": round(rps_baseline, 3),
         "s_per_request": round(wall_baseline / n_requests, 4)},
    ]
    common.emit("serve_throughput", rows,
                ["mode", "requests", "devices", "slots_per_pod", "wall_s",
                 "requests_per_s", "s_per_request", "p50_turnaround_s",
                 "p99_turnaround_s", "cache_miss_post_warmup", "speedup",
                 "pass"])
    return rows


if __name__ == "__main__":
    run()
