from repro.core import evaluate, hermite, nbody, strategies  # noqa: F401
