"""N-body system state, initial conditions and energy diagnostics.

State follows the paper's split: dynamical quantities live at host precision
(FP64 when x64 is enabled — the paper's CPU side), while force evaluation is
delegated to the FP32 device kernels (``repro.kernels``).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ParticleState:
    """Full Hermite-6 integrator state (all (N,3) except mass (N,))."""

    pos: jax.Array
    vel: jax.Array
    acc: jax.Array
    jerk: jax.Array
    snap: jax.Array
    crackle: jax.Array
    mass: jax.Array
    pot: jax.Array                      # per-particle potential (diagnostics)
    time: jax.Array                     # scalar

    @property
    def n(self) -> int:
        return self.pos.shape[0]

    @property
    def dtype(self):
        return self.pos.dtype


def zeros_like_state(pos, vel, mass) -> ParticleState:
    z = jnp.zeros_like(pos)
    return ParticleState(
        pos=pos, vel=vel, acc=z, jerk=z, snap=z, crackle=z,
        mass=mass, pot=jnp.zeros_like(mass),
        time=jnp.zeros((), pos.dtype),
    )


def plummer(
    n: int,
    *,
    seed: int = 0,
    total_mass: float = 1.0,
    dtype=jnp.float64,
    cutoff: float = 22.8042468,  # standard 99%-mass radius cut (Aarseth 1974)
) -> ParticleState:
    """Plummer-sphere initial conditions in standard N-body units.

    Uses the Aarseth, Henon & Wielen (1974) recipe with von Neumann rejection
    for the velocity sampling; positions/velocities are centred and rescaled
    to virial equilibrium (E = -1/4, G = M = 1).
    """
    rng = np.random.default_rng(seed)
    m = np.full(n, total_mass / n)

    # radii from the cumulative mass profile, with an outer cutoff
    x1 = rng.uniform(0.0, 1.0, size=n)
    frac = cutoff / np.sqrt(1.0 + cutoff**2)
    x1 = x1 * frac**3  # restrict to the mass fraction inside the cutoff
    r = (x1 ** (-2.0 / 3.0) - 1.0) ** (-0.5)

    def iso(rr):
        u = rng.uniform(-1.0, 1.0, size=rr.shape[0])
        phi = rng.uniform(0.0, 2 * np.pi, size=rr.shape[0])
        st = np.sqrt(1.0 - u * u)
        return rr[:, None] * np.stack(
            [st * np.cos(phi), st * np.sin(phi), u], axis=1
        )

    pos = iso(r)

    # velocity: q = v/v_esc with g(q) = q^2 (1-q^2)^{7/2}, rejection sampling
    q = np.zeros(n)
    todo = np.ones(n, dtype=bool)
    while todo.any():
        k = int(todo.sum())
        x2 = rng.uniform(0.0, 1.0, size=k)
        x3 = rng.uniform(0.0, 0.1, size=k)
        ok = x3 < x2**2 * (1.0 - x2**2) ** 3.5
        idx = np.flatnonzero(todo)[ok]
        q[idx] = x2[ok]
        todo[idx] = False
    v_esc = np.sqrt(2.0) * (1.0 + r * r) ** (-0.25)
    vel = iso(q * v_esc)

    # centre of mass / momentum frame
    pos -= (m[:, None] * pos).sum(0) / m.sum()
    vel -= (m[:, None] * vel).sum(0) / m.sum()

    # rescale to standard units: E = -1/4 (scale factor 16/(3*pi))
    pos *= 3.0 * np.pi / 16.0
    vel *= np.sqrt(16.0 / (3.0 * np.pi))

    return zeros_like_state(
        jnp.asarray(pos, dtype), jnp.asarray(vel, dtype), jnp.asarray(m, dtype)
    )


def two_body_circular(dtype=jnp.float64) -> ParticleState:
    """Equal-mass circular binary — analytic test case (period = 2*pi*r^1.5...)."""
    pos = jnp.asarray([[0.5, 0.0, 0.0], [-0.5, 0.0, 0.0]], dtype)
    # G=1, m=0.5 each, separation 1 -> v_circ of each about COM: v = sqrt(mu/r)/...
    # orbital speed: v = sqrt(G * m_other^2 / (M * r)) with M=1, r=1 -> 0.5
    vel = jnp.asarray([[0.0, 0.5, 0.0], [0.0, -0.5, 0.0]], dtype)
    mass = jnp.asarray([0.5, 0.5], dtype)
    return zeros_like_state(pos, vel, mass)


def kinetic_energy(state: ParticleState) -> jax.Array:
    return 0.5 * jnp.sum(state.mass * jnp.sum(state.vel**2, axis=1))


def potential_energy(state: ParticleState) -> jax.Array:
    return 0.5 * jnp.sum(state.mass * state.pot)


def total_energy(state: ParticleState) -> jax.Array:
    return kinetic_energy(state) + potential_energy(state)


def particle_energies(state: ParticleState) -> jax.Array:
    """Per-particle specific energies (paper Fig. 4 distribution)."""
    return 0.5 * jnp.sum(state.vel**2, axis=1) + state.pot
