"""Evaluator factories: the FP32 force-evaluation stage of the Hermite loop.

``make_evaluator`` builds the single-device evaluator (the paper's one-chip
configuration); the multi-device strategies live in
``repro.core.strategies`` and share the same ``Evaluator`` signature.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.hermite import Evaluation, Evaluator
from repro.kernels import nbody_force, ops


def make_evaluator(
    *,
    eps: float = 1e-7,
    order: int = 6,
    impl: Optional[str] = None,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    precision: str = "fp32",  # "fp32" (paper device precision) | "fp64" golden
) -> Evaluator:
    """Single-device evaluator (Pallas kernel or XLA fallback).

    ``precision="fp64"`` is the golden-reference mode (pure-jnp oracle at
    host precision, no kernel) used for validation and convergence tests.
    """
    if precision == "fp64":
        from repro.kernels import ref

        def evaluate_golden(pos, vel, mass) -> Evaluation:
            acc, jerk, pot = ref.acc_jerk_pot_rect(pos, vel, pos, vel, mass, eps=eps)
            if order >= 6:
                snp = ref.snap_rect(pos, vel, acc, pos, vel, acc, mass, eps=eps)
            else:
                snp = jnp.zeros_like(acc)
            return Evaluation(acc=acc, jerk=jerk, snap=snp, pot=pot)

        return evaluate_golden

    impl_ = impl or ops.default_impl()
    kw = dict(eps=eps, block_i=block_i, block_j=block_j, impl=impl_)

    def evaluate(pos, vel, mass) -> Evaluation:
        f32 = jnp.float32
        p, v, m = jnp.asarray(pos, f32), jnp.asarray(vel, f32), jnp.asarray(mass, f32)
        acc, jerk, pot = ops.acc_jerk_pot_rect(p, v, p, v, m, **kw)
        if order >= 6:
            snp = ops.snap_rect(p, v, acc, p, v, acc, m, **kw)
        else:
            snp = jnp.zeros_like(acc)
        return Evaluation(acc=acc, jerk=jerk, snap=snp, pot=pot)

    return evaluate


# Block evaluator signature: (pos, vel, acc_pred, mass, mask_t) -> Evaluation
# with per-target activity mask; acc_pred supplies the snap pass's source
# accelerations for targets that were NOT evaluated this substep.
def make_block_evaluator(
    *,
    eps: float = 1e-7,
    order: int = 6,
    impl: Optional[str] = None,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    precision: str = "fp32",
):
    """Active-target evaluator for the hierarchical block-timestep scheme.

    Pass 1 computes acc/jerk/potential *on the active targets only* (sources
    stay full).  The 6th-order snap pass needs the acceleration of every
    source at the current time; inactive sources were not evaluated, so
    their Taylor-predicted acceleration ``acc_pred`` (Nitadori & Makino 2008
    j-particle predictor) substitutes — active sources use the fresh pass-1
    value.  With an all-ones mask this reduces exactly to the lockstep
    evaluator (evaluated accelerations are used everywhere).
    """
    if precision == "fp64":
        from repro.kernels import ref

        def evaluate_golden(pos, vel, acc_pred, mass, mask_t) -> Evaluation:
            m3 = mask_t[:, None]
            acc, jerk, pot = ref.acc_jerk_pot_rect(pos, vel, pos, vel, mass,
                                                   eps=eps)
            acc = jnp.where(m3, acc, 0.0)
            jerk = jnp.where(m3, jerk, 0.0)
            pot = jnp.where(mask_t, pot, 0.0)
            if order >= 6:
                acc_s = jnp.where(m3, acc, acc_pred)
                snp = jnp.where(m3, ref.snap_rect(pos, vel, acc, pos, vel,
                                                  acc_s, mass, eps=eps), 0.0)
            else:
                snp = jnp.zeros_like(acc)
            return Evaluation(acc=acc, jerk=jerk, snap=snp, pot=pot)

        return evaluate_golden

    impl_ = impl or ops.default_impl()
    kw = dict(eps=eps, block_i=block_i, block_j=block_j, impl=impl_)

    def evaluate(pos, vel, acc_pred, mass, mask_t) -> Evaluation:
        f32 = jnp.float32
        p, v, m = (jnp.asarray(pos, f32), jnp.asarray(vel, f32),
                   jnp.asarray(mass, f32))
        acc, jerk, pot = ops.acc_jerk_pot_rect(p, v, p, v, m, mask_t=mask_t,
                                               **kw)
        if order >= 6:
            acc_s = jnp.where(mask_t[:, None], acc,
                              jnp.asarray(acc_pred, f32))
            snp = ops.snap_rect(p, v, acc, p, v, acc_s, m, mask_t=mask_t,
                                **kw)
        else:
            snp = jnp.zeros_like(acc)
        return Evaluation(acc=acc, jerk=jerk, snap=snp, pot=pot)

    return evaluate
