"""Evaluator factories: the FP32 force-evaluation stage of the Hermite loop.

``make_block_evaluator`` is the single implementation body: an active-target
evaluator (per-target activity mask, sources stay full) with an optional
**compaction** layer that gathers the active targets into a dense,
block-aligned buffer before launching the kernels.  ``make_evaluator`` — the
lockstep evaluator used by the fixed/adaptive paths and the paper's one-chip
configuration — is the all-ones-mask special case of the same body (pinned
exact by ``test_mask_all_ones_is_identity``).  The multi-device strategies
live in ``repro.core.strategies`` and share the ``Evaluator`` signature.

Compaction (``compaction="gather"``): at each call the active targets are
gathered (via a caller-supplied permutation putting active rows first) into
a buffer of one of a few static capacities (``ops.capacity_buckets``), both
kernels run on a ``ceil(cap/BI) x N/BJ`` grid instead of ``N/BI x N/BJ``,
and the outputs scatter back to particle slots.  The capacity bucket is
picked by a traced index dispatched through ``lax.switch`` over pre-lowered
instances, so XLA only ever sees static shapes; under ``jax.vmap`` the
caller must pass the bucket index *unbatched* (``in_axes=None`` — e.g. the
max active count across the batch) so the switch stays a real branch instead
of degrading to an execute-all-branches select.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.hermite import Evaluation, Evaluator
from repro.kernels import nbody_force, ops

#: compaction modes of the block evaluator
COMPACTIONS = ("none", "gather")


def shared_cap_index(plan: ops.CapacityPlan, bounds) -> jax.Array:
    """Unbatched capacity-bucket index shared by a group of members.

    ``lax.switch`` must see an *unbatched* operand under ``jax.vmap`` to
    stay a real branch (a batched index degrades to an execute-all-branches
    select), so every caller that dispatches one switch for many members —
    the ensemble engine's bucket groups, the fused ``(batch, dev)``
    evaluator's per-shard switch — shares the max of the members'
    active-count ``bounds`` (any shape; flattened).  Sound because a shared
    cap bounds every member's own count: gathered window rows past a
    member's active set are mask-zeroed by the kernels, so the scattered
    result is bit-for-bit the per-member bucket's — only the launch grid
    widens.  The bound is clamped to the plan's widest bucket, so an
    over-wide analytic bound (e.g. ``hermite.block_level_occupancy`` over
    rows that include padding) lands on the full-window bucket instead of
    out of range.
    """
    bound = jnp.max(jnp.asarray(bounds, jnp.int32).reshape(-1))
    return plan.bucket(jnp.minimum(bound, plan.caps[-1]))


def _rect_passes(*, eps, impl, block_i, block_j, precision, dtype):
    """The two Hermite passes in rectangular (targets x sources) form with
    the activity mask applied — the only layer that differs between the
    FP32 kernels and the FP64 oracle.  Shared by the full-source block
    evaluator and the Ahmad-Cohen neighbor-window evaluator; returns
    ``(cast, rect1, rect2)``."""
    if dtype is None:
        dtype = "fp64" if precision == "fp64" else "fp32"
    if dtype not in ops.DTYPES:
        raise ValueError(f"dtype must be one of {ops.DTYPES}; got {dtype!r}")
    if dtype == "fp64" or precision == "fp64":
        from repro.kernels import ref

        def cast(x):
            return jnp.asarray(x)

        def rect1(pt, vt, ps, vs, m, mask_c):
            acc, jerk, pot = ref.acc_jerk_pot_rect(pt, vt, ps, vs, m, eps=eps)
            m3 = mask_c[:, None]
            return (jnp.where(m3, acc, 0.0), jnp.where(m3, jerk, 0.0),
                    jnp.where(mask_c, pot, 0.0))

        def rect2(pt, vt, at, ps, vs, as_, m, mask_c):
            snp = ref.snap_rect(pt, vt, at, ps, vs, as_, m, eps=eps)
            return jnp.where(mask_c[:, None], snp, 0.0)
    else:
        impl_ = impl or ops.default_impl()
        kw = dict(eps=eps, block_i=block_i, block_j=block_j, impl=impl_,
                  dtype=dtype)

        def cast(x):
            return jnp.asarray(x, jnp.float32)

        def rect1(pt, vt, ps, vs, m, mask_c):
            return ops.acc_jerk_pot_rect(pt, vt, ps, vs, m, mask_t=mask_c,
                                         **kw)

        def rect2(pt, vt, at, ps, vs, as_, m, mask_c):
            return ops.snap_rect(pt, vt, at, ps, vs, as_, m, mask_t=mask_c,
                                 **kw)

    return cast, rect1, rect2


def make_block_evaluator(
    *,
    eps: float = 1e-7,
    order: int = 6,
    impl: Optional[str] = None,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    precision: str = "fp32",  # "fp32" (paper device precision) | "fp64" golden
    compaction: str = "none",
    n_caps: Optional[int] = None,
    dtype: Optional[str] = None,
):
    """Active-target evaluator for the hierarchical block-timestep scheme.

    Pass 1 computes acc/jerk/potential *on the active targets only* (sources
    stay full).  The 6th-order snap pass needs the acceleration of every
    source at the current time; inactive sources were not evaluated, so
    their Taylor-predicted acceleration ``acc_pred`` (Nitadori & Makino 2008
    j-particle predictor) substitutes — active sources use the fresh pass-1
    value.  With an all-ones mask this reduces exactly to the lockstep
    evaluator (evaluated accelerations are used everywhere).

    Signatures by ``compaction``:

    * ``"none"`` — ``evaluate(pos, vel, acc_pred, mass, mask_t)``: the dense
      masked launch (inactive i-blocks are ``pl.when``-skipped but their
      tiles are still enqueued).
    * ``"gather"`` — ``evaluate(pos, vel, acc_pred, mass, mask_t, perm,
      cap_idx)``: ``perm`` orders active targets first (``jnp.argsort`` of
      the negated mask), ``cap_idx`` selects the static capacity bucket
      (``ops.capacity_buckets(n, block_i)``) — it must bound the true active
      count, and must be unbatched under ``vmap``.  Output is bit-for-bit
      the ``"none"`` result: each target row is a row-local reduction over
      identical source blocks in identical order, whatever i-block it
      occupies.

    ``n_caps`` (gather mode only) truncates the capacity schedule to its
    first ``n_caps`` buckets — the *bucket group* of callers whose active
    count provably never exceeds ``caps[n_caps-1]`` (a mixed batch groups
    members by their static ``n_active`` ceiling; see
    ``ops.CapacityPlan.restrict``).  ``cap_idx`` then indexes the truncated
    schedule, and only those buckets are ever lowered.

    ``precision="fp64"`` is the golden-reference mode (pure-jnp oracle at
    host precision, no kernel) used for validation and convergence tests;
    it supports both compaction modes through the same gather/scatter path.

    ``dtype`` is the full precision axis (``ops.DTYPES``): ``"fp64"`` is a
    synonym for ``precision="fp64"``, ``"fp32"`` the historical kernel
    path, and ``"mixed"`` the Tensix-fidelity reduced-precision mode
    (bfloat16 per-pair arithmetic, compensated fp32 accumulation) in both
    kernel implementations.  ``dtype=None`` defers to ``precision`` so
    existing callers are untouched.
    """
    if compaction not in COMPACTIONS:
        raise ValueError(
            f"compaction must be one of {COMPACTIONS}; got {compaction!r}")
    cast, rect1, rect2 = _rect_passes(eps=eps, impl=impl, block_i=block_i,
                                      block_j=block_j, precision=precision,
                                      dtype=dtype)

    if compaction == "none":

        def evaluate(pos, vel, acc_pred, mass, mask_t) -> Evaluation:
            p, v, m = cast(pos), cast(vel), cast(mass)
            acc, jerk, pot = rect1(p, v, p, v, m, mask_t)
            if order >= 6:
                acc_s = jnp.where(mask_t[:, None], acc, cast(acc_pred))
                snp = rect2(p, v, acc, p, v, acc_s, m, mask_t)
            else:
                snp = jnp.zeros_like(acc)
            return Evaluation(acc=acc, jerk=jerk, snap=snp, pot=pot)

        return evaluate

    def evaluate_gather(pos, vel, acc_pred, mass, mask_t, perm,
                        cap_idx) -> Evaluation:
        n = pos.shape[0]
        caps = ops.capacity_buckets(n, block_i)
        if n_caps is not None:
            caps = caps[: min(n_caps, len(caps))]
        p, v, m, ap = cast(pos), cast(vel), cast(mass), cast(acc_pred)

        def make_branch(cap: int):
            def branch(p, v, ap, m, mask_t, perm) -> Evaluation:
                p_c, v_c, mask_c = ops.compact_targets(perm, cap,
                                                       p, v, mask_t)
                acc_c, jerk_c, pot_c = rect1(p_c, v_c, p, v, m, mask_c)
                acc, jerk, pot = ops.scatter_outputs(perm, cap, n,
                                                     acc_c, jerk_c, pot_c)
                if order >= 6:
                    # source-side compaction: the compacted fresh rows are
                    # scattered straight into the predicted-acc operand —
                    # bit-for-bit where(mask, acc, ap) without the dense
                    # intermediate blend
                    acc_s = ops.scatter_sources(perm, cap, ap, acc_c, mask_c)
                    snp_c = rect2(p_c, v_c, acc_c, p, v, acc_s, m, mask_c)
                    (snp,) = ops.scatter_outputs(perm, cap, n, snp_c)
                else:
                    snp = jnp.zeros_like(acc)
                return Evaluation(acc=acc, jerk=jerk, snap=snp, pot=pot)

            return branch

        return jax.lax.switch(cap_idx, [make_branch(c) for c in caps],
                              p, v, ap, m, mask_t, perm)

    return evaluate_gather


def make_neighbor_block_evaluator(
    *,
    n: int,
    eps: float = 1e-7,
    impl: Optional[str] = None,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    precision: str = "fp32",
    dtype: Optional[str] = None,
):
    """Near-window (regular-force) evaluator of the Ahmad-Cohen split.

    The source-axis dual of :func:`make_block_evaluator`'s compaction: the
    *targets* stay dense (every block launches — the activity mask handles
    inactive rows), but each target block sweeps only its gathered window
    of neighbor source blocks (``kernels.neighbor.build_windows``) instead
    of the full source extent.  The window capacity is one of the plan's
    static ``source_caps`` buckets, dispatched through ``lax.switch`` —
    ``w_idx`` must bound every live window count and, under ``vmap``, must
    be unbatched (``in_axes=None``), exactly like the target-side
    ``cap_idx``.  The last bucket is the full padded source extent, so an
    overflowing window dispatches the exact all-pairs sweep.

    Returns ``(near1, near2)``::

        near1(pos, vel, mass, mask_t, win_idx, win_cnt, w_idx)
            -> (acc, jerk, pot)                     # near-field only
        near2(pos, vel, acc_t, acc_s, mass, mask_t, win_idx, win_cnt, w_idx)
            -> snap                                 # near-field only

    ``acc_t`` is the *total* (near + far) acceleration of the targets and
    ``acc_s`` the total acceleration of every source row — the snap term
    depends on both particles' full accelerations even when only the near
    pairs are summed.  Window slots past ``win_cnt`` gather with their mass
    zeroed, so by the kernels' mask contract they contribute exactly zero:
    growing a shared bucket only appends exact zeros to each row's
    reduction tail.
    """
    cast, rect1, rect2 = _rect_passes(eps=eps, impl=impl, block_i=block_i,
                                      block_j=block_j, precision=precision,
                                      dtype=dtype)
    nbt = -(-n // block_i)
    nsb = -(-n // block_j)
    nt_pad, ns_pad = nbt * block_i, nsb * block_j
    # window capacities in source *blocks* per target block
    w_caps = tuple(c // block_j for c in ops.capacity_buckets(n, block_j))

    def _blocks(x, nb, block, rows):
        pad = ((0, rows - n),) + ((0, 0),) * (x.ndim - 1)
        return jnp.pad(x, pad).reshape((nb, block) + x.shape[1:])

    def _blocks_t(x):
        return _blocks(x, nbt, block_i, nt_pad)

    def _blocks_s(x):
        return _blocks(x, nsb, block_j, ns_pad)

    def _unblock(x):
        return x.reshape((nt_pad,) + x.shape[2:])[:n]

    def _gather(win_idx, win_cnt, w, sm, *blocks):
        """First ``w`` window entries of every target block, flattened to
        (nbt, w*block_j, ...); slots past ``win_cnt`` zero their mass."""
        idx = win_idx[:, :w]
        val = jnp.arange(w)[None, :] < win_cnt[:, None]
        gm = jnp.where(val[..., None], sm[idx], 0.0)
        flat = [gm.reshape(nbt, w * block_j)]
        for b in blocks:
            g = b[idx]
            flat.append(g.reshape((nbt, w * block_j) + g.shape[3:]))
        return flat

    def near1(pos, vel, mass, mask_t, win_idx, win_cnt, w_idx):
        p, v, m = cast(pos), cast(vel), cast(mass)
        tm = _blocks_t(jnp.asarray(mask_t, bool))
        tp, tv = _blocks_t(p), _blocks_t(v)
        sp, sv, sm = _blocks_s(p), _blocks_s(v), _blocks_s(m)

        def make_branch(w: int):
            def branch(tp, tv, tm, sp, sv, sm, win_idx, win_cnt):
                gm, gp, gv = _gather(win_idx, win_cnt, w, sm, sp, sv)
                return jax.vmap(rect1)(tp, tv, gp, gv, gm, tm)

            return branch

        acc, jerk, pot = jax.lax.switch(
            w_idx, [make_branch(w) for w in w_caps],
            tp, tv, tm, sp, sv, sm, win_idx, win_cnt)
        return _unblock(acc), _unblock(jerk), _unblock(pot)

    def near2(pos, vel, acc_t, acc_s, mass, mask_t, win_idx, win_cnt, w_idx):
        p, v, m = cast(pos), cast(vel), cast(mass)
        at, as_ = cast(acc_t), cast(acc_s)
        tm = _blocks_t(jnp.asarray(mask_t, bool))
        tp, tv, ta = _blocks_t(p), _blocks_t(v), _blocks_t(at)
        sp, sv, sa, sm = (_blocks_s(p), _blocks_s(v), _blocks_s(as_),
                          _blocks_s(m))

        def make_branch(w: int):
            def branch(tp, tv, ta, tm, sp, sv, sa, sm, win_idx, win_cnt):
                gm, gp, gv, ga = _gather(win_idx, win_cnt, w, sm, sp, sv, sa)
                return jax.vmap(rect2)(tp, tv, ta, gp, gv, ga, gm, tm)

            return branch

        snp = jax.lax.switch(
            w_idx, [make_branch(w) for w in w_caps],
            tp, tv, ta, tm, sp, sv, sa, sm, win_idx, win_cnt)
        return _unblock(snp)

    return near1, near2


def make_evaluator(
    *,
    eps: float = 1e-7,
    order: int = 6,
    impl: Optional[str] = None,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    precision: str = "fp32",  # "fp32" (paper device precision) | "fp64" golden
    dtype: Optional[str] = None,
) -> Evaluator:
    """Single-device lockstep evaluator (Pallas kernel or XLA fallback).

    The all-ones-mask specialization of :func:`make_block_evaluator` — the
    identity the block stepper degenerates to in lockstep, pinned exact by
    ``test_mask_all_ones_is_identity`` (the kernel's activity column is 1.0
    either way, so the packed operands are bitwise identical).  The blended
    snap-source acceleration reduces to the fresh pass-1 value everywhere,
    so the zero ``acc_pred`` placeholder is never read.

    ``precision="fp64"`` is the golden-reference mode (pure-jnp oracle at
    host precision, no kernel) used for validation and convergence tests.
    """
    block_eval = make_block_evaluator(
        eps=eps, order=order, impl=impl, block_i=block_i, block_j=block_j,
        precision=precision, dtype=dtype)

    def evaluate(pos, vel, mass) -> Evaluation:
        mask = jnp.ones(pos.shape[0], bool)
        return block_eval(pos, vel, jnp.zeros_like(pos), mass, mask)

    return evaluate
