"""Multi-device distribution strategies for the N-body force evaluation.

These are the paper's three scaling configurations (§3, Fig. 3) mapped onto
JAX collectives, plus one beyond-paper strategy (DESIGN.md §3):

* ``replicated``   — paper's Multi-Host Single-Chip: targets sharded over all
  devices, the full source set all-gathered onto every device once per
  evaluation (each chip holds a full replicated copy).
* ``two_level``    — paper's Multi-Host Multi-Chip: identical math, but the
  source gather is staged hierarchically over a (card, chip) view of the
  devices — all-gather across the chips of a card first, then across cards —
  modelling the explicit per-card partitioning of the paper.
* ``mesh_sharded`` — paper's Mesh-Based configuration: no explicit
  collectives; targets carry a sharded layout constraint and sources a
  replicated one, and the runtime (XLA SPMD here, TT-NN there) inserts the
  communication.  "Sharded buffers for domain-decomposed data, replicated
  buffers for globally shared particle data."
* ``ring``         — beyond-paper: systolic ``ppermute`` ring; every device
  keeps only N/P sources resident and overlaps each (N/P)^2 interaction block
  with the shift of the next source shard.  O(N/P) memory instead of O(N).

All strategies implement the same ``Evaluator`` contract and are numerically
equivalent to the single-device evaluation (tested property), because
all-pairs summation is order-invariant in the source index.

Each strategy additionally has a **compaction-aware block evaluator**
(:func:`make_strategy_block_evaluator`) for the hierarchical block-timestep
scheme: an active-target mask rides with the sharded targets, and with
``compaction="gather"`` every shard gathers its *local* active targets into
a dense block-aligned buffer of one of a few static capacities before
launching the kernels — the distributed analogue of
``core.evaluate.make_block_evaluator``, with per-shard launched-tile
accounting for telemetry.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: the experimental location
    from jax.experimental.shard_map import shard_map as _shard_map


def _smap(mesh, in_specs, out_specs, impl: str = "xla"):
    """shard_map decorator; replication checking disabled for Pallas impls.

    ``pallas_call`` has no replication rule, so running the tiled kernel
    inside a shard needs checking off (``check_rep=False`` on jax 0.4/0.5,
    renamed ``check_vma`` later — both are tried).  For non-Pallas impls the
    check stays ON: it still catches mis-specified collectives at trace time.
    """

    def deco(fn):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if not str(impl).startswith("pallas"):
            return _shard_map(fn, **kw)
        for flag in ({"check_rep": False}, {"check_vma": False}):
            try:
                return _shard_map(fn, **flag, **kw)
            except TypeError:
                continue
        return _shard_map(fn, **kw)

    return deco

from repro.core.hermite import Evaluation, Evaluator
from repro.kernels import nbody_force, ops
from repro.obs import metrics as obs_metrics

STRATEGIES = ("replicated", "two_level", "mesh_sharded", "ring")
#: compaction modes of the strategy block evaluators (mirrors core.evaluate)
COMPACTIONS = ("none", "gather")
#: ring source-shift schedules: "overlap" is the double-buffered default
#: (prefetch the next source window before the local kernels, exactly p-1
#: ppermute rounds per pass); "sync" is the pre-overlap baseline the bench
#: measures against (shift after compute inside a fori_loop, p rounds per
#: pass — the p-th round's result is discarded, the dead collective the
#: overlap schedule eliminates)
RING_MODES = ("overlap", "sync")


def make_batch_mesh(
    devices: Optional[Sequence[jax.Device]] = None,
    *,
    axis_name: str = "batch",
) -> Mesh:
    """1-D mesh over ``devices`` for batch-axis (ensemble) data parallelism.

    This is the same flat device view the 1-D strategies build internally;
    ``repro.sim.ensemble`` shards the leading axis of stacked runs over it.
    """
    devs = np.asarray(list(devices) if devices is not None else jax.devices())
    return Mesh(devs.reshape(devs.size), (axis_name,))


def make_fused_mesh(
    devices: Optional[Sequence[jax.Device]] = None,
    *,
    mesh_shape: Sequence[int],
    axis_names: Sequence[str] = ("batch", "dev"),
) -> Mesh:
    """2-D ``(batch, dev)`` mesh fusing ensemble and domain parallelism.

    ``mesh_shape`` is ``(B_shards, P_shards)``: the batch axis of stacked
    runs is sharded ``B_shards``-way and each run's particle domain
    ``P_shards``-way, so one ``shard_map`` drives B members x P domain
    shards at once (:func:`make_fused_block_evaluator`).  The device count
    must equal ``B_shards * P_shards`` exactly — a silent remainder would
    drop devices from the fused launch.
    """
    devs = np.asarray(list(devices) if devices is not None else jax.devices())
    bdev, p = (int(x) for x in mesh_shape)
    if bdev < 1 or p < 1:
        raise ValueError(f"mesh_shape extents must be >= 1; got {mesh_shape}")
    if bdev * p != devs.size:
        raise ValueError(
            f"mesh_shape {bdev}x{p} needs {bdev * p} devices; got {devs.size}")
    return Mesh(devs.reshape(bdev, p), tuple(axis_names))


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_particles(pos, vel, mass, n_pad: int):
    n = pos.shape[0]
    return (
        jnp.pad(pos, ((0, n_pad - n), (0, 0))),
        jnp.pad(vel, ((0, n_pad - n), (0, 0))),
        jnp.pad(mass, ((0, n_pad - n),)),  # zero mass => zero contribution
    )


def _force_kw(impl, block_i, block_j, eps, dtype="fp32"):
    # the kw dict is passed straight into the ops rect wrappers, so the
    # precision axis rides with the tile shape and softening everywhere a
    # strategy launches a kernel
    return dict(eps=eps, impl=impl, block_i=block_i, block_j=block_j,
                dtype=dtype)


def make_strategy_evaluator(
    strategy: str,
    *,
    devices: Optional[Sequence[jax.Device]] = None,
    chips_per_card: int = 2,
    eps: float = 1e-7,
    order: int = 6,
    impl: str = "xla",
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    dtype: str = "fp32",
    ring_mode: str = "overlap",
) -> Evaluator:
    """Build an ``Evaluator`` that distributes the evaluation over devices.

    The strategy meshes are *internal views* over the given devices: a 1D
    ``('dev',)`` mesh for replicated/mesh_sharded/ring, a 2D
    ``('card', 'chip')`` view for two_level (paper: 2 chips per n300 card).

    ``dtype`` is the kernel precision axis (``"fp32"`` or ``"mixed"``);
    the strategies keep fp32 state and collectives either way — only the
    per-pair arithmetic inside each shard's launches narrows.

    ``ring_mode`` selects the ring strategy's source-shift schedule
    (:data:`RING_MODES`): the double-buffered ``"overlap"`` default issues
    exactly ``p - 1`` prefetch-first ``ppermute`` rounds per pass, the
    ``"sync"`` baseline keeps the legacy shift-after-compute loop with its
    dead ``p``-th round.  Both are bit-for-bit identical in output.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    if ring_mode not in RING_MODES:
        raise ValueError(
            f"ring_mode must be one of {RING_MODES}; got {ring_mode!r}")
    devs = np.asarray(devices if devices is not None else jax.devices())
    p = devs.size
    kw = _force_kw(impl, block_i, block_j, eps, dtype)

    if strategy == "two_level":
        if p % chips_per_card:
            raise ValueError(f"{p} devices not divisible by {chips_per_card=}")
        mesh = Mesh(devs.reshape(p // chips_per_card, chips_per_card),
                    ("card", "chip"))
        return _two_level(mesh, order, kw)
    mesh = Mesh(devs.reshape(p), ("dev",))
    if strategy == "replicated":
        return _replicated(mesh, order, kw)
    if strategy == "mesh_sharded":
        return _mesh_sharded(mesh, order, kw)
    return _ring(mesh, order, kw, ring_mode)


def _wrap(mesh, p, order, eval_padded):
    """Pad N to a multiple of the device count, evaluate, slice back."""

    def evaluate(pos, vel, mass) -> Evaluation:
        n = pos.shape[0]
        f32 = jnp.float32
        pos32 = jnp.asarray(pos, f32)
        vel32 = jnp.asarray(vel, f32)
        mass32 = jnp.asarray(mass, f32)
        n_pad = _round_up(n, p)
        pp, vp, mp = _pad_particles(pos32, vel32, mass32, n_pad)
        acc, jerk, snp, pot = eval_padded(pp, vp, mp)
        return Evaluation(acc[:n], jerk[:n], snp[:n], pot[:n])

    return evaluate


# --------------------------------------------------------------------------
# Strategy 1 — replicated (Multi-Host Single-Chip analogue)
# --------------------------------------------------------------------------
def _replicated(mesh: Mesh, order: int, kw) -> Evaluator:
    axes = mesh.axis_names

    @jax.jit
    @_smap(mesh, (P(axes), P(axes), P(axes)),
           (P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, mass):
        # each device: local targets x full (gathered) source set
        with jax.named_scope("collective.all_gather"):
            gp = jax.lax.all_gather(pos, axes, axis=0, tiled=True)
            gv = jax.lax.all_gather(vel, axes, axis=0, tiled=True)
            gm = jax.lax.all_gather(mass, axes, axis=0, tiled=True)
        acc, jerk, pot = ops.acc_jerk_pot_rect(pos, vel, gp, gv, gm, **kw)
        if order >= 6:
            with jax.named_scope("collective.all_gather"):
                ga = jax.lax.all_gather(acc, axes, axis=0, tiled=True)
            snp = ops.snap_rect(pos, vel, acc, gp, gv, ga, gm, **kw)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot

    return _wrap(mesh, mesh.size, order, eval_padded)


# --------------------------------------------------------------------------
# Strategy 2 — two_level (Multi-Host Multi-Chip analogue)
# --------------------------------------------------------------------------
def _two_level(mesh: Mesh, order: int, kw) -> Evaluator:
    axes = mesh.axis_names  # ("card", "chip")

    def gather2(x):
        # stage 1: within the card (the paper's explicit chip partitioning),
        # stage 2: across cards (the MPI level).  Source order differs from
        # the 1D gather but all-pairs summation is order-invariant.
        with jax.named_scope("collective.all_gather2"):
            x = jax.lax.all_gather(x, "chip", axis=0, tiled=True)
            return jax.lax.all_gather(x, "card", axis=0, tiled=True)

    @jax.jit
    @_smap(mesh, (P(axes), P(axes), P(axes)),
           (P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, mass):
        gp, gv, gm = gather2(pos), gather2(vel), gather2(mass)
        acc, jerk, pot = ops.acc_jerk_pot_rect(pos, vel, gp, gv, gm, **kw)
        if order >= 6:
            ga = gather2(acc)
            snp = ops.snap_rect(pos, vel, acc, gp, gv, ga, gm, **kw)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot

    return _wrap(mesh, mesh.size, order, eval_padded)


# --------------------------------------------------------------------------
# Strategy 3 — mesh_sharded (Mesh-Based analogue; runtime-managed comms)
# --------------------------------------------------------------------------
def _mesh_sharded(mesh: Mesh, order: int, kw) -> Evaluator:
    sharded = NamedSharding(mesh, P("dev"))          # domain-decomposed
    sharded2 = NamedSharding(mesh, P("dev", None))
    replicated = NamedSharding(mesh, P())            # globally shared

    @jax.jit
    def eval_padded(pos, vel, mass):
        wsc = jax.lax.with_sharding_constraint
        # "sharded buffers" for the targets ...
        pt, vt = wsc(pos, sharded2), wsc(vel, sharded2)
        # ... "replicated buffers" for the globally shared source data; the
        # runtime inserts the all-gathers (cf. TT-NN MeshDevice).
        with jax.named_scope("collective.replicate"):
            ps, vs, ms = (wsc(pos, replicated), wsc(vel, replicated),
                          wsc(mass, replicated))
        acc, jerk, pot = ops.acc_jerk_pot_rect(pt, vt, ps, vs, ms, **kw)
        acc = wsc(acc, sharded2)
        if order >= 6:
            snp = ops.snap_rect(
                pt, vt, acc, ps, vs, wsc(acc, replicated), ms, **kw
            )
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, wsc(snp, sharded2), wsc(pot, sharded)

    return _wrap(mesh, mesh.size, order, eval_padded)


# --------------------------------------------------------------------------
# Strategy 4 — ring (beyond-paper systolic pipeline)
# --------------------------------------------------------------------------
def _ring_shift(axis_name: str, ring):
    """One systolic shift *round*: every source-window array hops one
    device along the ring.  Counted into the ``ring.shifts_issued`` metric
    at trace time (``rounds`` carries a fori_loop body's trip count, so the
    counter always reflects the rounds the traced program executes) — the
    collective-count assertion of the overlap tests pins the schedule
    through this counter."""

    def shift(arrays, rounds: int = 1):
        obs_metrics.registry().counter(
            "ring.shifts_issued", unit="rounds",
            help="source-shift ppermute rounds per traced ring pass",
        ).inc(rounds)
        with jax.named_scope("collective.ppermute"):
            return tuple(jax.lax.ppermute(a, axis_name, ring)
                         for a in arrays)

    return shift


def _ring_sweep(p, shift, ring_mode, init, src, compute):
    """Accumulate ``compute(src_k)`` over the ``p`` ring positions of the
    source window ``src`` (a tuple of arrays that hops one device per
    round); returns the accumulated output tuple.

    ``overlap`` (default): Python-unrolled double buffer — round ``k+1``'s
    source window is put in flight *before* round ``k``'s local kernels, so
    on hardware with async collectives the hop hides behind the local
    interaction block, and the final round issues no shift at all: exactly
    ``p - 1`` rounds per pass.  The accumulation order is untouched, so the
    result is bit-for-bit the synchronous schedule's.

    ``sync``: the pre-overlap baseline — a ``fori_loop`` whose body shifts
    after computing, every one of ``p`` iterations, so the last round's
    shifted window is computed and discarded (the dead collective round the
    overlap schedule eliminates).  Kept only as the measured baseline of
    the ``ring_overlap`` bench.
    """
    if ring_mode == "sync":

        def body(_, carry):
            acc, win = carry
            out = compute(win)
            acc = tuple(x + o for x, o in zip(acc, out))
            # body traces once but runs p rounds — count the trip count
            return (acc, shift(win, rounds=p))

        acc, _ = jax.lax.fori_loop(0, p, body, (init, src))
        return acc

    acc, win = init, src
    for k in range(p):
        # prefetch: next window in flight before this round's kernels
        nxt = shift(win) if k + 1 < p else None
        out = compute(win)
        acc = tuple(x + o for x, o in zip(acc, out))
        if nxt is not None:
            win = nxt
    return acc


def _ring(mesh: Mesh, order: int, kw, ring_mode: str = "overlap") -> Evaluator:
    axes = mesh.axis_names
    p = mesh.size
    ring = [(i, (i + 1) % p) for i in range(p)]
    shift = _ring_shift(axes[0], ring)

    @jax.jit
    @_smap(mesh, (P(axes), P(axes), P(axes)),
           (P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, mass):
        zeros3 = jnp.zeros_like(pos)
        zeros1 = jnp.zeros_like(mass)

        def aj(win):
            sp, sv, sm = win
            return ops.acc_jerk_pot_rect(pos, vel, sp, sv, sm, **kw)

        acc, jerk, pot = _ring_sweep(p, shift, ring_mode,
                                     (zeros3, zeros3, zeros1),
                                     (pos, vel, mass), aj)
        if order >= 6:

            def sn(win):
                sp, sv, sa, sm = win
                return (ops.snap_rect(pos, vel, acc, sp, sv, sa, sm, **kw),)

            (snp,) = _ring_sweep(p, shift, ring_mode, (zeros3,),
                                 (pos, vel, acc, mass), sn)
        else:
            snp = zeros3
        return acc, jerk, snp, pot

    return _wrap(mesh, p, order, eval_padded)


# --------------------------------------------------------------------------
# compaction-aware block evaluators (shard-local active-target gathering)
# --------------------------------------------------------------------------
# Distributed analogue of ``core.evaluate.make_block_evaluator``: every shard
# holds N/P target rows and an activity mask over them; with
# ``compaction="gather"`` each shard gathers its *local* active targets into
# a dense block-aligned buffer of one of a few static capacities
# (``ops.CapacityPlan`` at the local extent) and launches
# ``ceil(cap_local/BI) x N/BJ`` tiles instead of ``(N/P)/BI x N/BJ``.
#
# The bucket is selected per shard by a ``lax.switch`` on the shard-local
# active count.  Under SPMD every device traces the same program, but the
# switch operand is a runtime value, so shards genuinely diverge — one chip
# can take its smallest bucket while another syncs its whole domain.  That
# divergence is only sound because every branch is COLLECTIVE-FREE: the
# source gathers (explicit ``all_gather``/``ppermute`` or the runtime-
# inserted replication of mesh_sharded) are hoisted outside the switch, so
# all shards always execute the same collective sequence.
#
# The gather/scatter themselves are hoisted out of the switch too: the
# window of the LARGEST local capacity is gathered once, each branch runs
# the kernels on a static *prefix* of it (``window[:cap]``, zero-padding its
# output back to the window), and the one scatter happens after the switch.
# Semantically identical (rows past the chosen cap are inactive whenever the
# bucket bounds the active count, so their scattered output is exactly zero
# either way), it keeps the branch bodies to pure kernel launches — which
# both matches the Tensix picture (the host resizes the tile *grid*, not the
# data movement plan) and avoids exercising data-dependent gather/scatter
# under jit-of-shard_map branches, where jax 0.4.x CPU lowering was observed
# to miscompile (tests/test_strategy_compaction.py would catch it: the
# differential suite is bit-exact).


def _shard_plan(n_local: int, n_sources: int, kw, n_passes: int):
    """The local plan a shard builds from its own static shapes.

    Identical to ``global_plan.shard(P)`` of the host-side plan (the
    property suite asserts the equivalence) — in-shard code sees only the
    local extent, so it constructs the local plan directly.
    """
    return ops.CapacityPlan(n_local, n_sources, kw["block_i"], kw["block_j"],
                            n_passes=n_passes,
                            dtype=kw.get("dtype", "fp32"))


def _window_switch(cap_idx, caps, launch, window, extra=()):
    """``lax.switch`` over the capacity buckets: each branch runs
    ``launch`` on a static *prefix* of the pre-gathered target ``window``
    and zero-pads the output(s) back to the window extent.

    This is the one place the prefix-launch-and-pad invariant lives: rows
    past the chosen cap are inactive whenever the bucket bounds the active
    count, so their padded (and later scattered) output is exactly the
    masked result.  ``window`` and ``extra`` arrays ride as explicit switch
    operands, keeping every branch a pure function of its operands (see
    the module note on the jit-of-shard_map miscompile).
    """
    w = window[0].shape[0]
    n_win = len(window)

    def make_branch(cap: int):
        c = min(cap, w)

        def branch(*args):
            outs = launch(tuple(x[:c] for x in args[:n_win]), *args[n_win:])
            if not isinstance(outs, tuple):
                outs = (outs,)
            padded = tuple(
                jnp.pad(o, ((0, w - c),) + ((0, 0),) * (o.ndim - 1))
                for o in outs)
            return padded if len(padded) > 1 else padded[0]

        return branch

    return jax.lax.switch(cap_idx, [make_branch(c) for c in caps],
                          *window, *extra)


def _shard_pass1(pos, vel, ap, mask, perm, cap_idx, plan, kw, src, order):
    """Pass 1 on the compacted local targets: ``lax.switch`` over the local
    capacity buckets, each branch a pure kernel launch on a static window
    prefix.  Returns the scattered (acc, jerk, pot) plus the blended snap
    source operand (fresh acc on active rows, predicted elsewhere — the
    source-side compaction of the snap operand: the blend touches only the
    gathered window, never a dense intermediate)."""
    n_local = pos.shape[0]
    cap_max = plan.caps[-1]
    window = ops.compact_targets(perm, cap_max, pos, vel, mask)
    m_w = window[2]

    def launch(win, gp, gv, gm):
        p_c, v_c, m_c = win
        return ops.acc_jerk_pot_rect(p_c, v_c, gp, gv, gm, mask_t=m_c, **kw)

    a_w, j_w, pt_w = _window_switch(cap_idx, plan.caps, launch, window, src)
    acc, jerk, pot = ops.scatter_outputs(perm, cap_max, n_local,
                                         a_w, j_w, pt_w)
    acc_s = ops.scatter_sources(perm, cap_max, ap, a_w, m_w) \
        if order >= 6 else ap
    return acc, jerk, pot, acc_s


def _shard_pass2(pos, vel, acc, mask, perm, cap_idx, plan, kw, src, ga):
    """Snap pass on the compacted local targets (same bucket as pass 1);
    ``ga`` is the already-gathered blended source acceleration."""
    gp, gv, gm = src
    n_local = pos.shape[0]
    cap_max = plan.caps[-1]
    window = ops.compact_targets(perm, cap_max, pos, vel, acc, mask)

    def launch(win, gp, gv, ga, gm):
        p_c, v_c, a_c, m_c = win
        return ops.snap_rect(p_c, v_c, a_c, gp, gv, ga, gm,
                             mask_t=m_c, **kw)

    s_w = _window_switch(cap_idx, plan.caps, launch, window,
                         (gp, gv, ga, gm))
    (snp,) = ops.scatter_outputs(perm, cap_max, n_local, s_w)
    return snp


def _dense_pass1(pos, vel, ap, mask, kw, src, order):
    """The ``compaction="none"`` baseline: masked full-local-extent launch
    (inactive i-blocks are ``pl.when``-skipped but still enqueued)."""
    gp, gv, gm = src
    acc, jerk, pot = ops.acc_jerk_pot_rect(pos, vel, gp, gv, gm,
                                           mask_t=mask, **kw)
    acc_s = jnp.where(mask[:, None], acc, ap) if order >= 6 else ap
    return acc, jerk, pot, acc_s


def _shard_bucket(plan, bound):
    """Bucket index from the shard's ``(1,)`` active-count bound (clamped
    to the local extent, so an over-wide analytic bound still lands on the
    full-window bucket instead of out of range)."""
    return plan.bucket(jnp.minimum(bound[0], plan.caps[-1]))


def _shard_block_body(pos, vel, ap, mask, bound, src, *, kw, order,
                      compaction, n_passes):
    """Shared per-shard two-pass block evaluation against resident sources.

    ``bound`` is the shard's ``(1,)`` active-count bound — the measured
    local count, or the analytic ``hermite.block_level_occupancy`` bound
    the block engine schedules tiles from (host-side sizing: never below
    the true active count, so the bucket never underestimates).

    Returns (acc, jerk, snp, pot, acc_s, tiles) in the local layout; the
    caller supplies the gather of ``acc_s`` between the passes (the only
    collective the snap pass needs) via :func:`_resident_snap`.
    """
    n_local, n_src = pos.shape[0], src[0].shape[0]
    plan = _shard_plan(n_local, n_src, kw, n_passes)
    if compaction == "gather":
        perm = jnp.argsort(~mask, stable=True)
        cap_idx = _shard_bucket(plan, bound)
        acc, jerk, pot, acc_s = _shard_pass1(pos, vel, ap, mask, perm,
                                             cap_idx, plan, kw, src, order)
        tiles = jnp.reshape(plan.tiles(cap_idx), (1,))
        return acc, jerk, pot, acc_s, (perm, cap_idx, plan), tiles
    acc, jerk, pot, acc_s = _dense_pass1(pos, vel, ap, mask, kw, src, order)
    tiles = jnp.full((1,), plan.dense_tiles, jnp.int32)
    return acc, jerk, pot, acc_s, None, tiles


def _resident_snap(pos, vel, acc, mask, src, ga, compacted, kw):
    """Dispatch the snap pass for strategies with resident full sources."""
    if compacted is not None:
        perm, cap_idx, plan = compacted
        return _shard_pass2(pos, vel, acc, mask, perm, cap_idx, plan, kw,
                            src, ga)
    return ops.snap_rect(pos, vel, acc, *src[:2], ga, src[2],
                         mask_t=mask, **kw)


def _wrap_block(p, eval_padded):
    """Pad N (and the activity mask/predicted acc) to a device multiple,
    evaluate, slice back.  Padding rows carry mask = False (never gathered
    as targets) and m = 0 (invisible as sources).

    ``n_bound`` (optional ``(P,)`` int32) is a per-shard active-count bound
    for the compaction bucket switch — the block engine passes the analytic
    ``hermite.block_level_occupancy`` bound over each shard's contiguous
    row chunk (host-side tile scheduling).  ``None`` falls back to the
    measured per-shard mask sum, which selects the identical bucket (the
    bound is exact for a schedule-consistent carry) — either way the
    chosen branch, and therefore the physics, is bit-for-bit unchanged."""

    def evaluate(pos, vel, acc_pred, mass, mask_t, n_bound=None):
        n = pos.shape[0]
        f32 = jnp.float32
        pos32 = jnp.asarray(pos, f32)
        vel32 = jnp.asarray(vel, f32)
        ap32 = jnp.asarray(acc_pred, f32)
        mass32 = jnp.asarray(mass, f32)
        mask = jnp.asarray(mask_t, bool)
        n_pad = _round_up(n, p)
        pp, vp, mp = _pad_particles(pos32, vel32, mass32, n_pad)
        app = jnp.pad(ap32, ((0, n_pad - n), (0, 0)))
        mk = jnp.pad(mask, ((0, n_pad - n),))
        if n_bound is None:
            bound = jnp.sum(mk.reshape(p, -1), axis=1).astype(jnp.int32)
        else:
            bound = jnp.asarray(n_bound, jnp.int32).reshape(p)
        acc, jerk, snp, pot, tiles = eval_padded(pp, vp, app, mp, mk, bound)
        return (Evaluation(acc[:n], jerk[:n], snp[:n], pot[:n]), tiles)

    return evaluate


def make_strategy_block_evaluator(
    strategy: str,
    *,
    devices: Optional[Sequence[jax.Device]] = None,
    chips_per_card: int = 2,
    eps: float = 1e-7,
    order: int = 6,
    impl: str = "xla",
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    compaction: str = "none",
    dtype: str = "fp32",
    sources: str = "full",
    ring_mode: str = "overlap",
):
    """Distributed active-target evaluator for the block-timestep scheme.

    Signature of the returned callable::

        evaluate(pos, vel, acc_pred, mass, mask_t, n_bound=None) \
            -> (Evaluation, tiles)

    ``mask_t`` is the (N,) target-activity mask; ``acc_pred`` the predicted
    acceleration of every particle (the snap pass's source operand for
    inactive rows).  ``n_bound``, when given, is a ``(P,)`` host-side upper
    bound on each shard's active-target count — ``compaction="gather"``
    sizes its launch bucket from it instead of a runtime mask reduction
    (ROADMAP 5c host-side tile scheduling; the block scheme's analytic
    :func:`repro.core.hermite.block_level_occupancy` bound is *exact*, so
    the selected bucket — and hence the physics and tile count — is
    identical to the measured path).  ``None`` falls back to measuring.
    ``tiles`` is the ``(P,)`` vector of kernel grid tiles each shard
    enqueued for this event (both Hermite passes) — the per-shard launch
    cost telemetry reports, and the count ``compaction="gather"`` shrinks
    by gathering each shard's local active targets before launch.

    With an all-ones mask and ``compaction="none"`` this reduces to the
    lockstep :func:`make_strategy_evaluator` math; with ``"gather"`` the
    result is **bit-for-bit** the masked dense result of the same strategy
    (each target row is a row-local reduction over identical source blocks
    in identical order, whatever i-block it occupies — the same identity the
    single-device compaction rests on, locked by
    ``tests/test_strategy_compaction.py``).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    if compaction not in COMPACTIONS:
        raise ValueError(
            f"compaction must be one of {COMPACTIONS}; got {compaction!r}")
    if sources not in ops.SOURCES:
        raise ValueError(f"sources must be one of {ops.SOURCES}; "
                         f"got {sources!r}")
    if sources == "neighbor":
        raise ValueError(
            "sources='neighbor' runs on the vmapped ensemble block engine "
            "(strategy='single'); the sharded strategies evaluate full "
            "sources only")
    if ring_mode not in RING_MODES:
        raise ValueError(
            f"ring_mode must be one of {RING_MODES}; got {ring_mode!r}")
    devs = np.asarray(devices if devices is not None else jax.devices())
    p = devs.size
    kw = _force_kw(impl, block_i, block_j, eps, dtype)
    n_passes = 2 if order >= 6 else 1

    if strategy == "two_level":
        if p % chips_per_card:
            raise ValueError(f"{p} devices not divisible by {chips_per_card=}")
        mesh = Mesh(devs.reshape(p // chips_per_card, chips_per_card),
                    ("card", "chip"))
        return _two_level_block(mesh, order, kw, compaction, n_passes)
    mesh = Mesh(devs.reshape(p), ("dev",))
    if strategy == "replicated":
        return _replicated_block(mesh, order, kw, compaction, n_passes)
    if strategy == "mesh_sharded":
        return _mesh_sharded_block(mesh, order, kw, compaction, n_passes)
    return _ring_block(mesh, order, kw, compaction, n_passes, ring_mode)


def _gathered_block(mesh, order, kw, compaction, n_passes, gather):
    """Shared body of replicated/two_level: explicit source gather(s), then
    the per-shard two-pass compacted evaluation."""
    axes = mesh.axis_names

    @jax.jit
    @_smap(mesh, (P(axes),) * 6,
           (P(axes), P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, ap, mass, mask, bound):
        src = (gather(pos), gather(vel), gather(mass))
        acc, jerk, pot, acc_s, compacted, tiles = _shard_block_body(
            pos, vel, ap, mask, bound, src, kw=kw, order=order,
            compaction=compaction, n_passes=n_passes)
        if order >= 6:
            ga = gather(acc_s)  # the one collective between the switches
            snp = _resident_snap(pos, vel, acc, mask, src, ga, compacted, kw)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot, tiles

    return _wrap_block(mesh.size, eval_padded)


def _replicated_block(mesh, order, kw, compaction, n_passes):
    axes = mesh.axis_names

    def gather(x):
        with jax.named_scope("collective.all_gather"):
            return jax.lax.all_gather(x, axes, axis=0, tiled=True)

    return _gathered_block(mesh, order, kw, compaction, n_passes, gather)


def _two_level_block(mesh, order, kw, compaction, n_passes):
    def gather2(x):
        with jax.named_scope("collective.all_gather2"):
            x = jax.lax.all_gather(x, "chip", axis=0, tiled=True)
            return jax.lax.all_gather(x, "card", axis=0, tiled=True)

    return _gathered_block(mesh, order, kw, compaction, n_passes, gather2)


def _mesh_sharded_block(mesh, order, kw, compaction, n_passes):
    """Runtime-managed comms: the kernel regions are shard_mapped with
    *replicated* in_specs for the source operands — the collective is implied
    by the spec (cf. TT-NN MeshDevice replicated buffers), never written."""
    axes = mesh.axis_names
    sh, rep = P(axes), P()

    @_smap(mesh, (sh, sh, sh, sh, sh, rep, rep, rep),
           (sh, sh, sh, sh, sh), kw["impl"])
    def pass1(pos, vel, ap, mask, bound, gp, gv, gm):
        acc, jerk, pot, acc_s, _, tiles = _shard_block_body(
            pos, vel, ap, mask, bound, (gp, gv, gm), kw=kw, order=order,
            compaction=compaction, n_passes=n_passes)
        return acc, jerk, pot, acc_s, tiles

    @_smap(mesh, (sh, sh, sh, sh, sh, rep, rep, rep, rep), sh, kw["impl"])
    def pass2(pos, vel, acc, mask, bound, gp, gv, ga, gm):
        src = (gp, gv, gm)
        n_local, n_src = pos.shape[0], gp.shape[0]
        plan = _shard_plan(n_local, n_src, kw, n_passes)
        if compaction == "gather":
            # same bucket as pass 1: the local active set did not change
            perm = jnp.argsort(~mask, stable=True)
            cap_idx = _shard_bucket(plan, bound)
            return _shard_pass2(pos, vel, acc, mask, perm, cap_idx, plan,
                                kw, src, ga)
        return ops.snap_rect(pos, vel, acc, gp, gv, ga, gm,
                             mask_t=mask, **kw)

    @jax.jit
    def eval_padded(pos, vel, ap, mass, mask, bound):
        # targets arrive sharded, sources replicated — the same arrays bound
        # twice with different specs; the runtime inserts the all-gathers
        acc, jerk, pot, acc_s, tiles = pass1(pos, vel, ap, mask, bound,
                                             pos, vel, mass)
        if order >= 6:
            snp = pass2(pos, vel, acc, mask, bound, pos, vel, acc_s, mass)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot, tiles

    return _wrap_block(mesh.size, eval_padded)


def _ring_block(mesh, order, kw, compaction, n_passes,
                ring_mode: str = "overlap"):
    """Systolic ring with shard-local compaction: the compacted local target
    block meets every streamed source shard, so the switch sits *inside* the
    loop body (pure local work per branch) while the ``ppermute`` shifts stay
    outside it — every shard runs the same collective schedule whatever
    bucket it took.  The shift schedule itself is :func:`_ring_sweep`'s:
    double-buffered prefetch (``p - 1`` rounds) by default, the legacy
    synchronous loop as the bench baseline."""
    axes = mesh.axis_names
    p = mesh.size
    ring = [(i, (i + 1) % p) for i in range(p)]
    shift = _ring_shift(axes[0], ring)

    @jax.jit
    @_smap(mesh, (P(axes),) * 6,
           (P(axes), P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, ap, mass, mask, bound):
        n_local = pos.shape[0]
        # each of the n_passes sweeps launches once per streamed shard
        plan = _shard_plan(n_local, n_local, kw, n_passes * p)
        zeros3 = jnp.zeros_like(pos)
        zeros1 = jnp.zeros_like(mass)

        if compaction == "gather":
            # window gathered ONCE, outside the source loop: the systolic
            # stream rotates sources, the compacted target block is fixed,
            # and partial sums accumulate in the window layout (same adds,
            # one scatter at the end)
            perm = jnp.argsort(~mask, stable=True)
            cap_idx = _shard_bucket(plan, bound)
            tiles = jnp.reshape(plan.tiles(cap_idx), (1,))
            cap_max = plan.caps[-1]
            window = ops.compact_targets(perm, cap_max, pos, vel, mask)
            m_w = window[2]
            w = window[0].shape[0]

            def launch1(win, sp, sv, sm):
                p_c, v_c, m_c = win
                return ops.acc_jerk_pot_rect(p_c, v_c, sp, sv, sm,
                                             mask_t=m_c, **kw)

            zw3 = jnp.zeros((w, 3), jnp.float32)
            zw1 = jnp.zeros((w,), jnp.float32)
            a_w, j_w, pt_w = _ring_sweep(
                p, shift, ring_mode, (zw3, zw3, zw1), (pos, vel, mass),
                lambda src: _window_switch(cap_idx, plan.caps, launch1,
                                           window, src))
            acc, jerk, pot = ops.scatter_outputs(perm, cap_max, n_local,
                                                 a_w, j_w, pt_w)

            if order >= 6:
                # blended snap-source operand via the window (source-side
                # compaction); a_w already holds the summed fresh acc
                acc_s = ops.scatter_sources(perm, cap_max, ap, a_w, m_w)
                snap_window = window[:2] + (a_w, m_w)

                def launch2(win, sp, sv, sa, sm):
                    p_c, v_c, a_c, m_c = win
                    return ops.snap_rect(p_c, v_c, a_c, sp, sv, sa, sm,
                                         mask_t=m_c, **kw)

                (s_w,) = _ring_sweep(
                    p, shift, ring_mode, (zw3,), (pos, vel, acc_s, mass),
                    lambda src: (_window_switch(cap_idx, plan.caps, launch2,
                                                snap_window, src),))
                (snp,) = ops.scatter_outputs(perm, cap_max, n_local, s_w)
            else:
                snp = zeros3
            return acc, jerk, snp, pot, tiles

        tiles = jnp.full((1,), plan.dense_tiles, jnp.int32)

        def aj(src):
            sp, sv, sm = src
            return ops.acc_jerk_pot_rect(pos, vel, sp, sv, sm,
                                         mask_t=mask, **kw)

        acc, jerk, pot = _ring_sweep(p, shift, ring_mode,
                                     (zeros3, zeros3, zeros1),
                                     (pos, vel, mass), aj)
        if order >= 6:
            acc_s = jnp.where(mask[:, None], acc, ap)

            def sn(src):
                sp, sv, sa, sm = src
                return (ops.snap_rect(pos, vel, acc, sp, sv, sa, sm,
                                      mask_t=mask, **kw),)

            (snp,) = _ring_sweep(p, shift, ring_mode, (zeros3,),
                                 (pos, vel, acc_s, mass), sn)
        else:
            snp = zeros3
        return acc, jerk, snp, pot, tiles

    return _wrap_block(mesh.size, eval_padded)


# --------------------------------------------------------------------------
# fused (batch, dev) block evaluator: B ensemble members x P domain shards
# --------------------------------------------------------------------------
def _wrap_fused_block(bdev, p, eval_padded):
    """Pad each member's N to a shard multiple, evaluate, slice back.

    The *batch* axis is the engine's to pad (``sim.ensemble._pad_batch``
    repeats the first run) — a non-multiple batch here is a caller bug, not
    something to paper over with silently duplicated physics."""

    def evaluate(pos, vel, acc_pred, mass, mask_t, n_bound=None):
        b, n = pos.shape[0], pos.shape[1]
        if b % bdev:
            raise ValueError(
                f"batch size {b} not divisible by the mesh's batch extent "
                f"{bdev}; pad the batch first (sim.ensemble._pad_batch)")
        f32 = jnp.float32
        dn = _round_up(n, p) - n
        pp = jnp.pad(jnp.asarray(pos, f32), ((0, 0), (0, dn), (0, 0)))
        vp = jnp.pad(jnp.asarray(vel, f32), ((0, 0), (0, dn), (0, 0)))
        app = jnp.pad(jnp.asarray(acc_pred, f32), ((0, 0), (0, dn), (0, 0)))
        mp = jnp.pad(jnp.asarray(mass, f32), ((0, 0), (0, dn)))
        mk = jnp.pad(jnp.asarray(mask_t, bool), ((0, 0), (0, dn)))
        if n_bound is None:
            bound = jnp.sum(mk.reshape(b, p, -1), axis=2).astype(jnp.int32)
        else:
            bound = jnp.asarray(n_bound, jnp.int32).reshape(b, p)
        acc, jerk, snp, pot, tiles = eval_padded(pp, vp, app, mp, mk, bound)
        return (Evaluation(acc[:, :n], jerk[:, :n], snp[:, :n], pot[:, :n]),
                tiles)

    return evaluate


def _fused_block(mesh, order, kw, compaction, n_passes):
    """One shard_map over the fused mesh: each device holds ``B/bdev``
    members x ``N/p`` target rows and vmaps the per-shard two-pass block
    evaluation (:func:`_shard_pass1` / :func:`_shard_pass2`) over its local
    members.  Sources bind with dev-replicated specs (mesh_sharded style:
    the same arrays bound twice, GSPMD inserts the along-``dev`` gathers,
    never across ``batch`` — members stay independent).  The capacity
    switch is shared across a shard's local members via
    :func:`repro.core.evaluate.shared_cap_index`, keeping it a real branch
    under the member vmap."""
    from repro.core.evaluate import shared_cap_index

    bdev, p = mesh.devices.shape
    tsh3, tsh2 = P("batch", "dev", None), P("batch", "dev")
    ssh3, ssh2 = P("batch", None, None), P("batch", None)

    def vperm(mask):
        return jax.vmap(lambda mk: jnp.argsort(~mk, stable=True))(mask)

    @_smap(mesh, (tsh3, tsh3, tsh3, tsh2, tsh2, ssh3, ssh3, ssh2),
           (tsh3, tsh3, tsh2, tsh3, tsh2), kw["impl"])
    def pass1(pos, vel, ap, mask, bound, gp, gv, gm):
        b_loc = pos.shape[0]
        plan = _shard_plan(pos.shape[1], gp.shape[1], kw, n_passes)
        if compaction == "gather":
            cap_idx = shared_cap_index(plan, bound)
            acc, jerk, pot, acc_s = jax.vmap(
                lambda po, ve, a, mk, pe, sp, sv, sm: _shard_pass1(
                    po, ve, a, mk, pe, cap_idx, plan, kw, (sp, sv, sm),
                    order)
            )(pos, vel, ap, mask, vperm(mask), gp, gv, gm)
            tiles = jnp.broadcast_to(
                jnp.reshape(plan.tiles(cap_idx), (1, 1)), (b_loc, 1))
        else:
            acc, jerk, pot, acc_s = jax.vmap(
                lambda po, ve, a, mk, sp, sv, sm: _dense_pass1(
                    po, ve, a, mk, kw, (sp, sv, sm), order)
            )(pos, vel, ap, mask, gp, gv, gm)
            tiles = jnp.full((b_loc, 1), plan.dense_tiles, jnp.int32)
        return acc, jerk, pot, acc_s, tiles

    @_smap(mesh, (tsh3, tsh3, tsh3, tsh2, tsh2, ssh3, ssh3, ssh3, ssh2),
           tsh3, kw["impl"])
    def pass2(pos, vel, acc, mask, bound, gp, gv, ga, gm):
        plan = _shard_plan(pos.shape[1], gp.shape[1], kw, n_passes)
        if compaction == "gather":
            # same shared bucket as pass 1: neither masks nor bounds moved
            cap_idx = shared_cap_index(plan, bound)
            return jax.vmap(
                lambda po, ve, a, mk, pe, sp, sv, sa, sm: _shard_pass2(
                    po, ve, a, mk, pe, cap_idx, plan, kw, (sp, sv, sm), sa)
            )(pos, vel, acc, mask, vperm(mask), gp, gv, ga, gm)
        return jax.vmap(
            lambda po, ve, a, mk, sp, sv, sa, sm: ops.snap_rect(
                po, ve, a, sp, sv, sa, sm, mask_t=mk, **kw)
        )(pos, vel, acc, mask, gp, gv, ga, gm)

    @jax.jit
    def eval_padded(pos, vel, ap, mass, mask, bound):
        acc, jerk, pot, acc_s, tiles = pass1(pos, vel, ap, mask, bound,
                                             pos, vel, mass)
        if order >= 6:
            snp = pass2(pos, vel, acc, mask, bound, pos, vel, acc_s, mass)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot, tiles

    return _wrap_fused_block(bdev, p, eval_padded)


def make_fused_block_evaluator(
    mesh_shape: Sequence[int],
    *,
    devices: Optional[Sequence[jax.Device]] = None,
    eps: float = 1e-7,
    order: int = 6,
    impl: str = "xla",
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    compaction: str = "none",
    dtype: str = "fp32",
):
    """Batched active-target evaluator over a fused ``(batch, dev)`` mesh.

    One ``shard_map`` runs ``B`` ensemble members x ``P`` domain shards at
    once (``mesh_shape = (B_shards, P_shards)``; see :func:`make_fused_mesh`)
    — the 2-D composition of the ensemble engine's batch sharding with the
    ``mesh_sharded`` strategy's domain decomposition, which is what lets a
    serving pod hold several large-N members on one device group.

    Signature of the returned callable::

        evaluate(pos, vel, acc_pred, mass, mask_t, n_bound=None) \
            -> (Evaluation, tiles)

    All target operands carry a leading ``(B,)`` batch axis; ``n_bound``,
    when given, is a ``(B, P)`` host-side upper bound on each member's
    per-shard active-target count (the analytic
    ``hermite.block_level_occupancy`` bound — host-side tile scheduling,
    no runtime gather feeds the bucket switch), and ``None`` falls back to
    the measured per-member per-shard mask sum.  ``tiles`` is the ``(B, P)``
    matrix of kernel grid tiles each member enqueued on each domain shard
    (both Hermite passes).

    Bit-for-bit: each target row is a row-local reduction over the full
    source set in source order, whatever shard or member-vmap lane it
    occupies, so the result equals both the 1-D batch-sharded ensemble
    evaluation and the 1-D ``mesh_sharded`` strategy evaluation of the
    same member (the fused golden pins all three).  ``compaction="gather"``
    shares one capacity bucket across a shard's local members
    (:func:`repro.core.evaluate.shared_cap_index`) — identical physics,
    the launch grid just follows the widest local member.
    """
    if compaction not in COMPACTIONS:
        raise ValueError(
            f"compaction must be one of {COMPACTIONS}; got {compaction!r}")
    mesh = make_fused_mesh(devices, mesh_shape=mesh_shape)
    kw = _force_kw(impl, block_i, block_j, eps, dtype)
    n_passes = 2 if order >= 6 else 1
    return _fused_block(mesh, order, kw, compaction, n_passes)
