"""Multi-device distribution strategies for the N-body force evaluation.

These are the paper's three scaling configurations (§3, Fig. 3) mapped onto
JAX collectives, plus one beyond-paper strategy (DESIGN.md §3):

* ``replicated``   — paper's Multi-Host Single-Chip: targets sharded over all
  devices, the full source set all-gathered onto every device once per
  evaluation (each chip holds a full replicated copy).
* ``two_level``    — paper's Multi-Host Multi-Chip: identical math, but the
  source gather is staged hierarchically over a (card, chip) view of the
  devices — all-gather across the chips of a card first, then across cards —
  modelling the explicit per-card partitioning of the paper.
* ``mesh_sharded`` — paper's Mesh-Based configuration: no explicit
  collectives; targets carry a sharded layout constraint and sources a
  replicated one, and the runtime (XLA SPMD here, TT-NN there) inserts the
  communication.  "Sharded buffers for domain-decomposed data, replicated
  buffers for globally shared particle data."
* ``ring``         — beyond-paper: systolic ``ppermute`` ring; every device
  keeps only N/P sources resident and overlaps each (N/P)^2 interaction block
  with the shift of the next source shard.  O(N/P) memory instead of O(N).

All strategies implement the same ``Evaluator`` contract and are numerically
equivalent to the single-device evaluation (tested property), because
all-pairs summation is order-invariant in the source index.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # 0.4.x: the experimental location
    from jax.experimental.shard_map import shard_map as _shard_map


def _smap(mesh, in_specs, out_specs, impl: str = "xla"):
    """shard_map decorator; replication checking disabled for Pallas impls.

    ``pallas_call`` has no replication rule, so running the tiled kernel
    inside a shard needs checking off (``check_rep=False`` on jax 0.4/0.5,
    renamed ``check_vma`` later — both are tried).  For non-Pallas impls the
    check stays ON: it still catches mis-specified collectives at trace time.
    """

    def deco(fn):
        kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
        if not str(impl).startswith("pallas"):
            return _shard_map(fn, **kw)
        for flag in ({"check_rep": False}, {"check_vma": False}):
            try:
                return _shard_map(fn, **flag, **kw)
            except TypeError:
                continue
        return _shard_map(fn, **kw)

    return deco

from repro.core.hermite import Evaluation, Evaluator
from repro.kernels import nbody_force, ops

STRATEGIES = ("replicated", "two_level", "mesh_sharded", "ring")


def make_batch_mesh(
    devices: Optional[Sequence[jax.Device]] = None,
    *,
    axis_name: str = "batch",
) -> Mesh:
    """1-D mesh over ``devices`` for batch-axis (ensemble) data parallelism.

    This is the same flat device view the 1-D strategies build internally;
    ``repro.sim.ensemble`` shards the leading axis of stacked runs over it.
    """
    devs = np.asarray(list(devices) if devices is not None else jax.devices())
    return Mesh(devs.reshape(devs.size), (axis_name,))


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pad_particles(pos, vel, mass, n_pad: int):
    n = pos.shape[0]
    return (
        jnp.pad(pos, ((0, n_pad - n), (0, 0))),
        jnp.pad(vel, ((0, n_pad - n), (0, 0))),
        jnp.pad(mass, ((0, n_pad - n),)),  # zero mass => zero contribution
    )


def _force_kw(impl, block_i, block_j, eps):
    return dict(eps=eps, impl=impl, block_i=block_i, block_j=block_j)


def make_strategy_evaluator(
    strategy: str,
    *,
    devices: Optional[Sequence[jax.Device]] = None,
    chips_per_card: int = 2,
    eps: float = 1e-7,
    order: int = 6,
    impl: str = "xla",
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
) -> Evaluator:
    """Build an ``Evaluator`` that distributes the evaluation over devices.

    The strategy meshes are *internal views* over the given devices: a 1D
    ``('dev',)`` mesh for replicated/mesh_sharded/ring, a 2D
    ``('card', 'chip')`` view for two_level (paper: 2 chips per n300 card).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    devs = np.asarray(devices if devices is not None else jax.devices())
    p = devs.size
    kw = _force_kw(impl, block_i, block_j, eps)

    if strategy == "two_level":
        if p % chips_per_card:
            raise ValueError(f"{p} devices not divisible by {chips_per_card=}")
        mesh = Mesh(devs.reshape(p // chips_per_card, chips_per_card),
                    ("card", "chip"))
        return _two_level(mesh, order, kw)
    mesh = Mesh(devs.reshape(p), ("dev",))
    if strategy == "replicated":
        return _replicated(mesh, order, kw)
    if strategy == "mesh_sharded":
        return _mesh_sharded(mesh, order, kw)
    return _ring(mesh, order, kw)


def _wrap(mesh, p, order, eval_padded):
    """Pad N to a multiple of the device count, evaluate, slice back."""

    def evaluate(pos, vel, mass) -> Evaluation:
        n = pos.shape[0]
        f32 = jnp.float32
        pos32 = jnp.asarray(pos, f32)
        vel32 = jnp.asarray(vel, f32)
        mass32 = jnp.asarray(mass, f32)
        n_pad = _round_up(n, p)
        pp, vp, mp = _pad_particles(pos32, vel32, mass32, n_pad)
        acc, jerk, snp, pot = eval_padded(pp, vp, mp)
        return Evaluation(acc[:n], jerk[:n], snp[:n], pot[:n])

    return evaluate


# --------------------------------------------------------------------------
# Strategy 1 — replicated (Multi-Host Single-Chip analogue)
# --------------------------------------------------------------------------
def _replicated(mesh: Mesh, order: int, kw) -> Evaluator:
    axes = mesh.axis_names

    @jax.jit
    @_smap(mesh, (P(axes), P(axes), P(axes)),
           (P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, mass):
        # each device: local targets x full (gathered) source set
        gp = jax.lax.all_gather(pos, axes, axis=0, tiled=True)
        gv = jax.lax.all_gather(vel, axes, axis=0, tiled=True)
        gm = jax.lax.all_gather(mass, axes, axis=0, tiled=True)
        acc, jerk, pot = ops.acc_jerk_pot_rect(pos, vel, gp, gv, gm, **kw)
        if order >= 6:
            ga = jax.lax.all_gather(acc, axes, axis=0, tiled=True)
            snp = ops.snap_rect(pos, vel, acc, gp, gv, ga, gm, **kw)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot

    return _wrap(mesh, mesh.size, order, eval_padded)


# --------------------------------------------------------------------------
# Strategy 2 — two_level (Multi-Host Multi-Chip analogue)
# --------------------------------------------------------------------------
def _two_level(mesh: Mesh, order: int, kw) -> Evaluator:
    axes = mesh.axis_names  # ("card", "chip")

    def gather2(x):
        # stage 1: within the card (the paper's explicit chip partitioning),
        # stage 2: across cards (the MPI level).  Source order differs from
        # the 1D gather but all-pairs summation is order-invariant.
        x = jax.lax.all_gather(x, "chip", axis=0, tiled=True)
        return jax.lax.all_gather(x, "card", axis=0, tiled=True)

    @jax.jit
    @_smap(mesh, (P(axes), P(axes), P(axes)),
           (P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, mass):
        gp, gv, gm = gather2(pos), gather2(vel), gather2(mass)
        acc, jerk, pot = ops.acc_jerk_pot_rect(pos, vel, gp, gv, gm, **kw)
        if order >= 6:
            ga = gather2(acc)
            snp = ops.snap_rect(pos, vel, acc, gp, gv, ga, gm, **kw)
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, snp, pot

    return _wrap(mesh, mesh.size, order, eval_padded)


# --------------------------------------------------------------------------
# Strategy 3 — mesh_sharded (Mesh-Based analogue; runtime-managed comms)
# --------------------------------------------------------------------------
def _mesh_sharded(mesh: Mesh, order: int, kw) -> Evaluator:
    sharded = NamedSharding(mesh, P("dev"))          # domain-decomposed
    sharded2 = NamedSharding(mesh, P("dev", None))
    replicated = NamedSharding(mesh, P())            # globally shared

    @jax.jit
    def eval_padded(pos, vel, mass):
        wsc = jax.lax.with_sharding_constraint
        # "sharded buffers" for the targets ...
        pt, vt = wsc(pos, sharded2), wsc(vel, sharded2)
        # ... "replicated buffers" for the globally shared source data; the
        # runtime inserts the all-gathers (cf. TT-NN MeshDevice).
        ps, vs, ms = wsc(pos, replicated), wsc(vel, replicated), wsc(mass, replicated)
        acc, jerk, pot = ops.acc_jerk_pot_rect(pt, vt, ps, vs, ms, **kw)
        acc = wsc(acc, sharded2)
        if order >= 6:
            snp = ops.snap_rect(
                pt, vt, acc, ps, vs, wsc(acc, replicated), ms, **kw
            )
        else:
            snp = jnp.zeros_like(acc)
        return acc, jerk, wsc(snp, sharded2), wsc(pot, sharded)

    return _wrap(mesh, mesh.size, order, eval_padded)


# --------------------------------------------------------------------------
# Strategy 4 — ring (beyond-paper systolic pipeline)
# --------------------------------------------------------------------------
def _ring(mesh: Mesh, order: int, kw) -> Evaluator:
    axes = mesh.axis_names
    p = mesh.size
    perm = [(i, (i + 1) % p) for i in range(p)]

    def shift(x):
        return jax.lax.ppermute(x, axes[0], perm)

    @jax.jit
    @_smap(mesh, (P(axes), P(axes), P(axes)),
           (P(axes), P(axes), P(axes), P(axes)), kw["impl"])
    def eval_padded(pos, vel, mass):
        zeros3 = jnp.zeros_like(pos)
        zeros1 = jnp.zeros_like(mass)

        def body_aj(_, carry):
            acc, jerk, pot, sp, sv, sm = carry
            a, j, pt = ops.acc_jerk_pot_rect(pos, vel, sp, sv, sm, **kw)
            # the shift of the next source shard overlaps with the local
            # (N/P)^2 interaction block on hardware (async collective)
            return (acc + a, jerk + j, pot + pt, shift(sp), shift(sv), shift(sm))

        acc, jerk, pot, *_ = jax.lax.fori_loop(
            0, p, body_aj, (zeros3, zeros3, zeros1, pos, vel, mass)
        )
        if order >= 6:
            def body_s(_, carry):
                snp, sp, sv, sa, sm = carry
                s = ops.snap_rect(pos, vel, acc, sp, sv, sa, sm, **kw)
                return (snp + s, shift(sp), shift(sv), shift(sa), shift(sm))

            snp, *_ = jax.lax.fori_loop(
                0, p, body_s, (zeros3, pos, vel, acc, mass)
            )
        else:
            snp = zeros3
        return acc, jerk, snp, pot

    return _wrap(mesh, p, order, eval_padded)
