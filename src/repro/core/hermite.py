"""Sixth-order Hermite predictor-evaluator-corrector (Nitadori & Makino 2008).

The scheme mirrors the paper's three iterative stages (§2.1):

* **predict** — positions/velocities extrapolated to t+dt with the Taylor
  series through crackle (5th derivative term), at host precision (FP64);
* **evaluate** — acc/jerk/snap from direct summation at device precision
  (FP32), via a pluggable ``Evaluator`` (single device, Pallas kernel, or one
  of the multi-device strategies in ``repro.core.strategies``);
* **correct** — the two-point 6th-order Hermite corrector, plus the
  interpolated crackle used by the next prediction.

A 4th-order mode (``order=4``) uses only acc+jerk — this is the exact device
contract of the paper's single-pass kernel (DESIGN.md §2.2).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.nbody import ParticleState


class Evaluation(NamedTuple):
    acc: jax.Array
    jerk: jax.Array
    snap: jax.Array
    pot: jax.Array


# Evaluator signature: (pos, vel, mass) -> Evaluation (FP32 contents).
Evaluator = Callable[[jax.Array, jax.Array, jax.Array], Evaluation]


def predict(state: ParticleState, dt) -> tuple[jax.Array, jax.Array]:
    """Taylor-series prediction of positions and velocities to t + dt.

    ``dt`` may be a scalar (lockstep) or an ``(N, 1)`` column of per-particle
    horizons — the block-timestep engine predicts every particle from its own
    last correction time to the shared substep time.
    """
    h = dt
    x, v, a, j, s, c = (
        state.pos, state.vel, state.acc, state.jerk, state.snap, state.crackle
    )
    xp = x + h * (v + h * (a / 2 + h * (j / 6 + h * (s / 24 + h * c / 120))))
    vp = v + h * (a + h * (j / 2 + h * (s / 6 + h * c / 24)))
    return xp, vp


def predict_acc(state: ParticleState, dt) -> jax.Array:
    """Taylor-predicted acceleration at t + dt (snap-pass source operand).

    The 6th-order scheme's second pass needs a_j of *every* source; under
    block timesteps inactive particles are not re-evaluated, so their
    acceleration is predicted through crackle (Nitadori & Makino 2008, the
    j-particle predictor).  ``dt`` broadcasts like :func:`predict`.
    """
    h = dt
    return state.acc + h * (state.jerk
                            + h * (state.snap / 2 + h * state.crackle / 6))


def correct(state: ParticleState, ev: Evaluation, dt, *, order: int = 6):
    """Two-point Hermite corrector; returns (pos, vel, crackle_at_t1).

    Like :func:`predict`, ``dt`` may be scalar or an ``(N, 1)`` per-particle
    column (each particle corrected over its own completed step).
    """
    h = dt
    a0, j0, s0 = state.acc, state.jerk, state.snap
    a1 = ev.acc.astype(state.dtype)
    j1 = ev.jerk.astype(state.dtype)
    s1 = ev.snap.astype(state.dtype)

    if order == 4:
        # classic 4th-order Hermite corrector (acc+jerk only)
        v1 = state.vel + h / 2 * (a0 + a1) + h * h / 12 * (j0 - j1)
        x1 = state.pos + h / 2 * (state.vel + v1) + h * h / 12 * (a0 - a1)
        crackle = jnp.zeros_like(a1)
        return x1, v1, crackle

    # 6th-order corrector (Nitadori & Makino 2008, eqs. 5-6)
    v1 = state.vel + h / 2 * (a0 + a1) + h**2 / 10 * (j0 - j1) \
        + h**3 / 120 * (s0 + s1)
    x1 = state.pos + h / 2 * (state.vel + v1) + h**2 / 10 * (a0 - a1) \
        + h**3 / 120 * (j0 + j1)

    # crackle at t1 from the 5th-degree interpolating polynomial of a(t)
    big_a = a1 - a0 - h * j0 - h * h / 2 * s0
    big_j = j1 - j0 - h * s0
    big_s = s1 - s0
    crackle = (60.0 * big_a - 36.0 * h * big_j + 9.0 * h * h * big_s) / h**3
    return x1, v1, crackle


def step(
    state: ParticleState,
    dt,
    evaluator: Evaluator,
    *,
    order: int = 6,
) -> ParticleState:
    """One full P-E-C Hermite step at fixed dt."""
    xp, vp = predict(state, dt)
    ev = evaluator(xp, vp, state.mass)
    x1, v1, crackle = correct(state, ev, dt, order=order)
    return ParticleState(
        pos=x1, vel=v1,
        acc=ev.acc.astype(state.dtype),
        jerk=ev.jerk.astype(state.dtype),
        snap=ev.snap.astype(state.dtype),
        crackle=crackle,
        mass=state.mass,
        pot=ev.pot.astype(state.mass.dtype),
        time=state.time + dt,
    )


def initialize(state: ParticleState, evaluator: Evaluator) -> ParticleState:
    """Bootstrap derivatives at t=0 (crackle starts at zero)."""
    ev = evaluator(state.pos, state.vel, state.mass)
    return dataclasses.replace(
        state,
        acc=ev.acc.astype(state.dtype),
        jerk=ev.jerk.astype(state.dtype),
        snap=ev.snap.astype(state.dtype),
        crackle=jnp.zeros_like(state.pos),
        pot=ev.pot.astype(state.mass.dtype),
    )


def aarseth_dt_particles(state: ParticleState, *, eta: float = 0.02,
                         dt_max=0.0625, use_crackle: bool = False):
    """Per-particle Aarseth timestep criterion — the ``(N,)`` vector.

    ``use_crackle=False`` (default) drops the 5th-derivative term from the
    denominator: the crackle is *reconstructed* from differences of FP32
    accelerations divided by h^3 (see ``correct``), so at small h it is
    noise-dominated and feeding it back into the dt criterion causes a
    dt-collapse spiral under the paper's mixed-precision scheme.  The state
    itself is unaffected (crackle only enters prediction at O(h^5)/120).

    Particles with zero derivatives (``num == 0`` — e.g. zero-mass padding
    rows, whose evaluated derivatives the ensemble mask zeroes) fall back to
    ``dt_max``, so they never tighten a shared step nor deepen a block level.
    """
    tiny = jnp.asarray(1e-30, state.dtype)

    def norm(x):
        return jnp.sqrt(jnp.sum(x * x, axis=1))

    a, j, s = norm(state.acc), norm(state.jerk), norm(state.snap)
    num = a * s + j * j
    den = s * s
    if use_crackle:
        den = den + j * norm(state.crackle)
    dt_i = eta * jnp.sqrt(num / jnp.maximum(den, tiny))
    dt_i = jnp.where(num > 0, dt_i, dt_max)
    return jnp.minimum(dt_i, jnp.asarray(dt_max, state.dtype))


def aarseth_dt(state: ParticleState, *, eta: float = 0.02, dt_max=0.0625,
               use_crackle: bool = False):
    """Shared adaptive timestep (Aarseth criterion, min over particles)."""
    return jnp.min(aarseth_dt_particles(state, eta=eta, dt_max=dt_max,
                                        use_crackle=use_crackle))


def quantize_block_levels(dt_i, *, dt_max, n_levels: int):
    """Quantize per-particle timesteps onto the power-of-two block hierarchy.

    Level ``l`` steps at ``dt_max / 2**l``; a particle is assigned the
    *coarsest* level whose step does not exceed its Aarseth ``dt_i``
    (``l = ceil(log2(dt_max / dt_i))``), clipped to ``[0, n_levels - 1]`` —
    so the quantized step only ever rounds *down* (never looser than the
    criterion) except at the finest level, which floors the hierarchy the way
    ``dt_min`` floors classic block-timestep codes.
    """
    dt_i = jnp.maximum(dt_i, jnp.asarray(jnp.finfo(dt_i.dtype).tiny,
                                         dt_i.dtype))
    lev = jnp.ceil(jnp.log2(dt_max / dt_i))
    return jnp.clip(lev, 0, n_levels - 1).astype(jnp.int32)


def block_level_dt(levels, dt_max, dtype=None):
    """The step size ``dt_max / 2**level`` of each particle's block level.

    The result dtype is pinned to ``dt_max``'s dtype (or an explicit
    ``dtype``), not ``jnp.result_type(float)``: the latter follows the
    ``jax_enable_x64`` flag, so an fp32 simulation state would silently get
    fp64 level steps whenever the golden-reference flag is on — the
    reconstructed dt then disagrees bitwise with the engine's own
    ``state.dtype`` arithmetic.
    """
    dt_max = jnp.asarray(dt_max, dtype)
    return dt_max * jnp.exp2(-levels.astype(dt_max.dtype))


def block_level_occupancy(levels, *, n_levels: int, mask=None):
    """Per-level occupancy bound: entry ``t`` counts particles at levels >= t.

    A tick of the block schedule activates a particle iff its period divides
    the tick, i.e. iff its level is at least the tick's threshold level
    ``n_levels - 1 - trailing_zeros(tick)`` (t_last is always a multiple of
    the particle's period — promotion is commensurate, demotion lands on
    doubled-period ticks).  Entry ``t`` of the returned ``(n_levels,)`` vector
    is therefore the *largest active set any tick with threshold ``t`` can
    see* — the analytic a-priori bound on the compaction layer's capacity
    buckets (the engine itself sizes each event's bucket from the tighter
    *measured* active count; this bound is what a host-side tile scheduler
    could use before the levels are known on-device, and the property suite
    asserts it dominates every tick of the schedule).  Entry 0 (every
    particle) is the macro-boundary synchronization.

    ``mask`` (optional bool ``(N,)``) restricts the count to real particles,
    excluding zero-mass padding rows.
    """
    lev = levels[None, :] >= jnp.arange(n_levels, dtype=levels.dtype)[:, None]
    if mask is not None:
        lev = lev & mask[None, :]
    return jnp.sum(lev, axis=1).astype(jnp.int32)


def tick_threshold_level(tick, *, n_levels: int):
    """Threshold level of a block-schedule tick:
    ``n_levels - 1 - trailing_zeros(tick)``.

    A particle is active at ``tick`` iff its level is at least this value
    (its period ``2**(n_levels - 1 - level)`` divides the tick), so
    ``block_level_occupancy(levels)[tick_threshold_level(t)]`` is the
    analytic active-count bound the strategy engine sizes its capacity
    buckets from — host-side tile scheduling without a runtime gather of
    the activity mask.  Trace-safe: trailing zeros are counted by modulo
    tests against the static power-of-two periods (no bit intrinsics), and
    the macro-boundary tick ``2**(n_levels - 1)`` maps to threshold 0
    (every particle synchronizes).
    """
    t = jnp.asarray(tick, jnp.int32)
    pows = jnp.asarray([2 ** k for k in range(1, n_levels)], jnp.int32)
    tz = jnp.sum((t % pows) == 0).astype(jnp.int32)
    return jnp.asarray(n_levels - 1, jnp.int32) - tz


def auto_n_levels(dt_i, *, dt_max, max_levels: int = 8):
    """Hierarchy depth that resolves the tightest of the given Aarseth
    timesteps, clamped to ``[1, max_levels]``.

    ``--levels auto`` sizes each ensemble member's hierarchy from its
    *initial* dt distribution instead of a fixed CLI value: the finest level
    needed is ``ceil(log2(dt_max / min_i dt_i))``, so the returned depth is
    that level plus one.  Zero-derivative padding rows report ``dt_i =
    dt_max`` (see :func:`aarseth_dt_particles`) and never deepen the
    hierarchy.
    """
    lev = quantize_block_levels(dt_i, dt_max=dt_max, n_levels=max_levels)
    return jnp.max(lev) + 1


def block_active_mask(levels, k, *, n_levels: int):
    """Active set at fine-substep ``k`` (1-based) of one ``dt_max`` macro-step.

    A macro-step is ``2**(n_levels-1)`` substeps of the finest dt; a particle
    at level ``l`` completes one of its own steps every ``2**(n_levels-1-l)``
    substeps, i.e. it is predicted-evaluated-corrected exactly when ``k`` is
    a multiple of its period.  At ``k = 2**(n_levels-1)`` every period
    divides ``k``: the whole system synchronizes at the macro boundary.
    """
    period = jnp.asarray(2 ** (n_levels - 1), jnp.int32) >> levels
    return (jnp.asarray(k, jnp.int32) % period) == 0


def evolve(
    state: ParticleState,
    evaluator: Evaluator,
    *,
    t_end: float,
    dt: Optional[float] = None,
    eta: float = 0.02,
    order: int = 6,
    max_steps: int = 100_000,
) -> ParticleState:
    """Evolve to ``t_end`` with fixed (``dt``) or shared-adaptive timestep.

    Python-level loop (host drives the device kernel each step, exactly the
    paper's host/accelerator split); use ``evolve_scan`` for a fully traced
    fixed-dt loop.
    """
    state = initialize(state, evaluator)
    steps = 0
    h_prev = None
    while float(state.time) < t_end and steps < max_steps:
        if dt is not None:
            h = dt
        else:
            h = float(aarseth_dt(state, eta=eta))
            if h_prev is not None:
                # rate-limit dt changes (noise robustness, standard practice)
                h = min(max(h, 0.5 * h_prev), 2.0 * h_prev)
            h_prev = h
        h = min(h, t_end - float(state.time))
        state = step(state, jnp.asarray(h, state.dtype), evaluator, order=order)
        steps += 1
    return state


def evolve_scan(
    state: ParticleState,
    evaluator: Evaluator,
    *,
    n_steps: int,
    dt: float,
    order: int = 6,
) -> ParticleState:
    """Fixed-dt evolution as a single traced ``lax.scan`` (for jit/pjit)."""
    state = initialize(state, evaluator)
    h = jnp.asarray(dt, state.dtype)

    def body(s, _):
        return step(s, h, evaluator, order=order), None

    out, _ = jax.lax.scan(body, state, None, length=n_steps)
    return out
