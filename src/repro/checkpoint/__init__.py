from repro.checkpoint.store import save, restore, restore_latest, available_steps  # noqa: F401
