"""Fault-tolerant checkpoint store.

Layout: ``<dir>/step_<N>/`` containing one ``.npy`` per pytree leaf (named by
its tree path) plus ``manifest.json`` (step, tree structure, shapes, dtypes).
Writes are atomic: a ``.tmp-`` staging directory is renamed into place only
after every leaf and the manifest have been flushed, so a crash mid-save can
never corrupt the latest checkpoint.  ``restore_latest`` scans for the newest
complete step.

Elastic restore: leaves are loaded host-side and ``device_put`` against
whatever shardings the *current* mesh prescribes, so a run saved on 512
devices restores cleanly on 256 (or 1 — the CPU test path) as long as the
logical model is unchanged.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomic checkpoint write; prunes to the newest ``keep`` steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    steps = sorted(available_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def available_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(name[5:]))
    return sorted(out)


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Optional[Any] = None) -> Any:
    """Restore into the structure of ``like`` (shapes validated).

    ``shardings``: optional matching pytree of NamedShardings for elastic
    mesh-resharded placement."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, ref in flat_like.items():
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = np.load(os.path.join(path, meta["file"]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != {ref.shape}")
        ref_dtype = np.dtype(getattr(ref, "dtype", np.asarray(ref).dtype))
        if arr.dtype != ref_dtype:
            # a silent .astype here once swallowed precision (e.g. float64
            # block-carry tile counters restored against a float32 template
            # lose exact integer adds past 2**24) — mismatches are a caller
            # bug, so they fail loudly on both placement paths
            raise ValueError(
                f"leaf {key!r}: checkpoint dtype {arr.dtype} != template "
                f"dtype {ref_dtype} (restore never casts; fix the template "
                "or re-save)")
        sh = flat_sh.get(key)
        loaded[key] = (jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(
        str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
        for k in path) for path, _ in leaves_paths]
    return jax.tree_util.tree_unflatten(treedef, [loaded[k] for k in keys])


def restore_latest(ckpt_dir: str, like: Any, *,
                   shardings: Optional[Any] = None):
    """(step, tree) from the newest complete checkpoint, or (None, None)."""
    steps = available_steps(ckpt_dir)
    if not steps:
        return None, None
    return steps[-1], restore(ckpt_dir, steps[-1], like, shardings=shardings)
