"""Pure-JAX AdamW with global-norm clipping and warmup-cosine schedule.

Optax-style interface (``init`` / ``update``) without the dependency; the
optimizer state is a pytree shaped like the parameters, so it inherits the
parameter shardings (ZeRO: FSDP-sharded params => FSDP-sharded m/v).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array       # int32 scalar
    m: dict                # first moment, like params
    v: dict                # second moment, like params


def warmup_cosine(peak_lr: float, *, warmup: int = 100,
                  total: int = 10_000, floor: float = 0.1) -> Callable:
    """lr(step): linear warmup to ``peak_lr`` then cosine to ``floor*peak``."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak_lr + (1 - floor) * peak_lr * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda: jax.tree.map(  # noqa: E731
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())

    def _lr(self, count):
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: AdamWState, params):
        """Returns (updates, new_state, metrics). ``params + updates`` is the
        new parameter value (updates include the weight-decay term)."""
        count = state.count + 1
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
        v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        c = count.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1 ** c)
        vhat_scale = 1.0 / (1 - b2 ** c)
        lr = self._lr(count)

        def upd(p, mu, nu):
            step = mu * mhat_scale / (jnp.sqrt(nu * vhat_scale) + self.eps)
            # decay only matrices (norm vectors/bias-like 1-D params exempt)
            wd = self.weight_decay if p.ndim >= 2 else 0.0
            return (-(lr * (step + wd * p.astype(jnp.float32)))).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, AdamWState(count=count, m=m, v=v), {
            "gnorm": gnorm, "lr": lr}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def abstract_state(params_abstract) -> AdamWState:
    """ShapeDtypeStruct state tree matching ``abstract_params`` (dry-run)."""

    def mk(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding)

    return AdamWState(
        count=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(mk, params_abstract),
        v=jax.tree.map(mk, params_abstract),
    )
