from repro.optim.adamw import AdamW, AdamWState, apply_updates, warmup_cosine, global_norm, abstract_state  # noqa: F401
