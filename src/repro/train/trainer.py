"""Training loop with checkpoint/restart, straggler monitoring and
deterministic data skip-ahead.

Fault-tolerance model (DESIGN.md §6):

* **checkpoint/restart** — atomic sharded checkpoints every
  ``ckpt_every`` steps (params + optimizer state + step counter); on start
  the trainer resumes from the newest complete checkpoint and the
  counter-based data pipeline skips ahead in O(1).
* **straggler mitigation** — per-step wall time is tracked with an EWMA of
  mean and variance; a step slower than ``mean + k*sigma`` is flagged (on a
  real cluster the flag feeds the job controller to drain/replace the slow
  host; here it is surfaced in metrics and the log so the policy is
  testable).
* **elastic scaling** — checkpoints are mesh-agnostic (host numpy +
  device_put against the *current* shardings), so restarts may change the
  device count.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.distributed.shardings import MeshRules
from repro.models import params as P
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamW
from repro.train.step import make_train_step


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time monitor; flags outliers > mean + k*sigma."""

    alpha: float = 0.1
    k: float = 3.0
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    count: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            # prime the statistics without flagging (first steps compile)
            self.mean = dt if self.count == 1 else (
                self.mean + (dt - self.mean) / self.count)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        slow = dt > self.mean + self.k * max(self.var, 1e-12) ** 0.5
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        if slow:
            self.flagged += 1
        return slow


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    accum: int = 1
    log_every: int = 10
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, rules: MeshRules, opt: AdamW,
                 data: Callable[[int], dict], tcfg: TrainerConfig,
                 *, batch_shardings: Optional[dict] = None,
                 log: Callable[[str], None] = print):
        self.cfg, self.rules, self.opt = cfg, rules, opt
        self.data, self.tcfg, self.log = data, tcfg, log
        self.batch_shardings = batch_shardings
        self.monitor = StragglerMonitor()
        self._step_fn = jax.jit(
            make_train_step(cfg, rules, opt, accum=tcfg.accum),
            donate_argnums=(0, 1))

    # ---------------- state ----------------
    def init_state(self):
        params = P.init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        if self.rules.mesh is not None:
            shardings = P.param_shardings(self.cfg, self.rules)
            params = jax.tree.map(jax.device_put, params, shardings)
        return params, self.opt.init(params)

    def restore_or_init(self):
        params, opt_state = self.init_state()
        if self.tcfg.ckpt_dir:
            step, tree = store.restore_latest(
                self.tcfg.ckpt_dir, {"params": params, "opt": opt_state})
            if step is not None:
                if self.rules.mesh is not None:   # elastic mesh-resharding
                    shardings = P.param_shardings(self.cfg, self.rules)
                    tree["params"] = jax.tree.map(
                        jax.device_put, tree["params"], shardings)
                self.log(f"[trainer] restored checkpoint at step {step}")
                return step, tree["params"], tree["opt"]
        return 0, params, opt_state

    # ---------------- loop ----------------
    def run(self, *, start_params=None, start_opt=None, start_step=0):
        if start_params is None:
            start_step, params, opt_state = self.restore_or_init()
        else:
            params, opt_state = start_params, start_opt
        history = []
        for step in range(start_step, self.tcfg.steps):
            batch = self.data(step)
            batch = {k: jax.device_put(
                v, (self.batch_shardings or {}).get(k))
                for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = self._step_fn(
                params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.monitor.observe(dt)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics.update(step=step, step_time=dt, straggler=bool(slow))
            history.append(metrics)
            if slow:
                self.log(f"[straggler] step {step} took {dt*1e3:.1f} ms "
                         f"(mean {self.monitor.mean*1e3:.1f} ms)")
            if step % self.tcfg.log_every == 0:
                self.log(f"[train] step {step} loss {metrics['loss']:.4f} "
                         f"({dt*1e3:.1f} ms)")
            if (self.tcfg.ckpt_dir and (step + 1) % self.tcfg.ckpt_every == 0):
                store.save(self.tcfg.ckpt_dir, step + 1,
                           {"params": params, "opt": opt_state},
                           keep=self.tcfg.ckpt_keep)
        if self.tcfg.ckpt_dir:
            store.save(self.tcfg.ckpt_dir, self.tcfg.steps,
                       {"params": params, "opt": opt_state},
                       keep=self.tcfg.ckpt_keep)
        return params, opt_state, history
