from repro.train.step import make_train_step, jit_train_step  # noqa: F401
from repro.train.trainer import Trainer, TrainerConfig, StragglerMonitor  # noqa: F401
