"""Train step: loss + grad (+ microbatch accumulation) + AdamW update.

The step is a single jit-able function over (params, opt_state, batch);
activation memory is bounded by ``cfg.remat`` (checkpointed scan bodies in
the model) and by gradient accumulation (``accum > 1`` splits the global
batch into microbatches consumed by a ``lax.scan`` — the standard
activation-memory / throughput trade).

``grad_compression="int8"`` applies stochastic int8 quantization with error
feedback to the gradients *before* the optimizer (the distributed-optimization
trick from DESIGN.md §6: on a real mesh the quantized tensor is what crosses
the DP axis, cutting gradient all-reduce bytes 4x; the error-feedback buffer
keeps the optimizer unbiased over time).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import compression
from repro.distributed.shardings import MeshRules
from repro.models import model
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamW, apply_updates


def make_train_step(cfg: ArchConfig, rules: MeshRules, opt: AdamW, *,
                    accum: int = 1, grad_compression: str = "none",
                    accum_dtype=jnp.float32):
    """Returns train_step(params, opt_state, batch[, err]) -> (params,
    opt_state, metrics[, err]).

    ``accum_dtype=bfloat16`` halves the gradient-accumulation buffer (a
    memory lever for the largest archs; each microbatch gradient is still
    produced in fp32 and rounded once on add — stochastic-rounding-free but
    bounded by accum * eps_bf16 relative error)."""

    def loss_wrap(params, microbatch):
        return model.loss_fn(cfg, rules, params, microbatch)

    grad_fn = jax.value_and_grad(loss_wrap, has_aux=True)

    def compute_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        mb = jax.tree.map(
            lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
            batch)

        def body(carry, micro):
            gsum, lsum = carry
            (l, met), g = grad_fn(params, micro)
            gsum = jax.tree.map(
                lambda s, x: s + x.astype(accum_dtype), gsum, g)
            return (gsum, lsum + l), met

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (gsum, lsum), mets = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) / accum, gsum)
        metrics = jax.tree.map(lambda a: a.mean(), mets)
        return lsum / accum, metrics, grads

    if grad_compression == "int8":

        def train_step(params, opt_state, batch, err):
            loss, metrics, grads = compute_grads(params, batch)
            grads, err = compression.compress_tree(grads, err)
            updates, opt_state, om = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, dict(metrics, loss=loss, **om), err

        return train_step

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        updates, opt_state, om = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, dict(metrics, loss=loss, **om)

    return train_step


def jit_train_step(cfg, rules, opt, *, accum: int = 1, donate: bool = True):
    """jit with param/opt donation (in-place update on device)."""
    step = make_train_step(cfg, rules, opt, accum=accum)
    return jax.jit(step, donate_argnums=(0, 1) if donate else ())
