from repro.data.pipeline import BatchSpec, SyntheticLM, MemmapCorpus, batch_spec_for, global_batch  # noqa: F401
