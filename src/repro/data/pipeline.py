"""Deterministic sharded data pipeline.

Two sources behind one interface:

* ``SyntheticLM`` — seeded counter-based token stream (threefry on
  (seed, step, shard)); fully deterministic, O(1) skip-ahead to any step —
  the property the trainer's restart path relies on.
* ``MemmapCorpus`` — flat binary token file (np.memmap) sampled with the
  same counter-based indexing, for "real data" runs.

Batches are built *per data shard*: each host materializes only its local
slice and the trainer device_puts it against the global sharding — no
full-batch materialization on any single host (multi-host pattern; on one
host it degenerates gracefully).

Stub frontends (audio frames / vision patches) synthesize deterministic
embeddings the same way, matching DESIGN.md §5 (the modality encoder is out
of scope; its *output* is the model input).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class BatchSpec:
    batch: int                  # global batch size
    seq: int                    # token sequence length
    enc_len: int = 0            # audio: encoder frame count
    patch_len: int = 0          # vlm: patch count


def batch_spec_for(cfg: ArchConfig, batch: int, seq: int) -> BatchSpec:
    if cfg.family == "audio":
        return BatchSpec(batch, seq, enc_len=seq)
    if cfg.family == "vlm":
        f = min(cfg.frontend_len, seq // 2)
        return BatchSpec(batch, seq - f, patch_len=f)
    return BatchSpec(batch, seq)


class SyntheticLM:
    """Deterministic synthetic LM batches; ``shard``/``num_shards`` select the
    local slice of the global batch."""

    def __init__(self, cfg: ArchConfig, spec: BatchSpec, *, seed: int = 0,
                 shard: int = 0, num_shards: int = 1):
        assert spec.batch % num_shards == 0, (spec.batch, num_shards)
        self.cfg, self.spec, self.seed = cfg, spec, seed
        self.shard, self.num_shards = shard, num_shards
        self.local_batch = spec.batch // num_shards

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))

    def __call__(self, step: int) -> dict:
        """Local batch for ``step`` (O(1) in step: restart skip-ahead)."""
        rng = self._rng(step)
        cfg, spec = self.cfg, self.spec
        b, s = self.local_batch, spec.seq
        toks = rng.integers(0, cfg.vocab_size, size=(b, s + 1),
                            dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if spec.enc_len:
            out["frames"] = rng.standard_normal(
                (b, spec.enc_len, cfg.d_model)).astype(np.float32)
        if spec.patch_len:
            out["patches"] = rng.standard_normal(
                (b, spec.patch_len, cfg.d_model)).astype(np.float32)
        return out


class MemmapCorpus:
    """Flat token-id binary file; deterministic random crops per step."""

    def __init__(self, cfg: ArchConfig, spec: BatchSpec, path: str, *,
                 dtype=np.int32, seed: int = 0, shard: int = 0,
                 num_shards: int = 1):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        assert self.data.size > spec.seq + 1, "corpus shorter than seq_len"
        self.cfg, self.spec, self.seed = cfg, spec, seed
        self.shard, self.num_shards = shard, num_shards
        self.local_batch = spec.batch // num_shards

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        s = self.spec.seq
        starts = rng.integers(0, self.data.size - s - 1,
                              size=self.local_batch)
        rows = np.stack([np.asarray(self.data[a : a + s + 1]) for a in starts])
        rows = rows.astype(np.int32) % self.cfg.vocab_size
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def global_batch(source, step: int, *, shardings: Optional[dict] = None) -> dict:
    """Assemble the (local) numpy batch and place it on device(s).

    ``shardings``: optional per-key NamedSharding dict (missing keys are
    placed unsharded)."""
    local = source(step)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, local)
    return {k: jax.device_put(v, shardings.get(k)) for k, v in local.items()}
