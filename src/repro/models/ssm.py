"""Sequence-mixing cells for the SSM/hybrid architectures.

* **Mamba2 SSD** (zamba2-7b) — chunked state-space-duality algorithm: the
  sequence is split into ``chunk``-length blocks; within-block interactions are
  a masked (decay-weighted) matmul, cross-block interactions flow through a
  recurrent (H, N, P) state carried by a ``lax.scan`` over blocks.  This is the
  same "resident targets x streamed sources, accumulate along the stream"
  shape as the paper's tiled N-body sweep (DESIGN.md §5).
* **mLSTM** (xlstm-1.3b) — chunkwise-parallel matrix-LSTM with exponential
  input gating and log-space (m) stabilization; carries (C, n, m) per head.
* **sLSTM** (xlstm-1.3b) — post-up-projection scalar LSTM with per-head
  recurrent block-diagonal R and exponential gating; a true time recurrence
  (``lax.scan`` over steps).

All recurrences/statistics run in fp32 regardless of the activation dtype;
each cell has a single-token ``*_step`` form used by the decode path, and the
parallel and step forms agree numerically (tested).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


# ===========================================================================
# Mamba2 SSD
# ===========================================================================
def ssd_chunked(x, dt, a_neg, b_mat, c_mat, *, chunk: int, state0=None):
    """Chunked SSD scan.

    Args:
        x:      (B, S, H, P) fp32 inputs (heads x head_dim).
        dt:     (B, S, H) fp32 positive step sizes (already softplus'd).
        a_neg:  (H,) fp32 negative continuous-time decay (−exp(a_log)).
        b_mat:  (B, S, N) fp32 input->state projection (shared across heads).
        c_mat:  (B, S, N) fp32 state->output projection.
        chunk:  block length L (S % L == 0).
        state0: optional (B, H, N, P) initial state.

    Returns:
        y: (B, S, H, P) fp32, state: (B, H, N, P) final state.
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    l = chunk

    xr = x.reshape(bsz, nc, l, h, p)
    dtr = dt.reshape(bsz, nc, l, h)
    br = b_mat.reshape(bsz, nc, l, n)
    cr = c_mat.reshape(bsz, nc, l, n)

    g = dtr * a_neg                      # (B, nc, L, H) per-step log decay (<0)
    big_g = jnp.cumsum(g, axis=2)        # inclusive cumulative log decay

    # ---- within-chunk (intra) term, per chunk, inside the scan body ----
    mask = jnp.tril(jnp.ones((l, l), bool))                   # t >= s

    def chunk_body(state, inp):
        xc, dtc, bc, cc, gc = inp        # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N) (B,L,H)
        # intra: w[t, s, h] = exp(G_t - G_s) * dt_s   for t >= s
        dec = jnp.exp(jnp.clip(gc[:, :, None, :] - gc[:, None, :, :], -60.0, 0.0))
        w = jnp.where(mask[None, :, :, None], dec * dtc[:, None, :, :], 0.0)
        scores = jnp.einsum("bln,bmn->blm", cc, bc)           # C_t . B_s
        y_intra = jnp.einsum("blm,blmh,bmhp->blhp", scores, w, xc)
        # inter: contribution of the carried state
        eg = jnp.exp(jnp.clip(gc, -60.0, None))               # (B,L,H)
        y_inter = jnp.einsum("bln,bhnp,blh->blhp", cc, state, eg)
        # state update: S' = exp(G_L) S + sum_s exp(G_L - G_s) dt_s B_s x_s^T
        g_last = gc[:, -1:, :]                                # (B,1,H)
        a_term = jnp.exp(jnp.clip(g_last - gc, -60.0, 0.0)) * dtc  # (B,L,H)
        st = jnp.einsum("blh,bln,blhp->bhnp", a_term, bc, xc)
        state = state * jnp.exp(jnp.clip(g_last[:, 0, :], -60.0, 0.0))[:, :, None, None] + st
        return state, y_intra + y_inter

    state0 = state0 if state0 is not None else jnp.zeros((bsz, h, n, p), F32)
    xs = (
        jnp.moveaxis(xr, 1, 0), jnp.moveaxis(dtr, 1, 0),
        jnp.moveaxis(br, 1, 0), jnp.moveaxis(cr, 1, 0),
        jnp.moveaxis(big_g, 1, 0),
    )
    state, ys = jax.lax.scan(chunk_body, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    return y, state


def ssd_step(x, dt, a_neg, b_mat, c_mat, state):
    """Single-token SSD update.

    x: (B, H, P), dt: (B, H), b_mat/c_mat: (B, N), state: (B, H, N, P).
    Returns (y: (B, H, P), new_state).
    """
    g = jnp.exp(jnp.clip(dt * a_neg, -60.0, 0.0))             # (B, H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, b_mat, x)
    state = state * g[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", c_mat, state)
    return y, state


def causal_conv(x, w, *, cache=None):
    """Depthwise causal 1-D conv.  x: (B, S, D), w: (W, D).

    With ``cache`` ((B, W-1, D) trailing context) performs the streaming form
    and returns (y, new_cache); otherwise zero-pads on the left.
    """
    width = w.shape[0]
    if cache is not None:
        ctx = jnp.concatenate([cache, x], axis=1)             # (B, W-1+S, D)
        new_cache = ctx[:, -(width - 1):, :] if width > 1 else cache
    else:
        ctx = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
        new_cache = None
    s = x.shape[1]
    y = jnp.zeros_like(x)
    for k in range(width):
        y = y + ctx[:, k : k + s, :] * w[k]
    return (y, new_cache) if cache is not None else y


# ===========================================================================
# mLSTM (xLSTM matrix cell)
# ===========================================================================
def mlstm_chunked(q, k, v, gi, gf, *, chunk: int, carry0=None):
    """Chunkwise-parallel mLSTM with log-space stabilization.

    Args:
        q, k, v: (B, S, H, K) fp32 (K = key = value dim here).
        gi, gf:  (B, S, H) fp32 raw input/forget gate pre-activations.
        chunk:   block length L.
        carry0:  optional (C, n, m) with C (B,H,K,K), n (B,H,K), m (B,H).

    Returns:
        h: (B, S, H, K), carry: (C, n, m).
    """
    bsz, s, h, kk = q.shape
    l = chunk
    nc = s // l
    scale = kk ** -0.5

    lf = _logsigmoid(gf)                                      # (B,S,H)
    qr = q.reshape(bsz, nc, l, h, kk) * scale
    kr = k.reshape(bsz, nc, l, h, kk)
    vr = v.reshape(bsz, nc, l, h, kk)
    lir = gi.reshape(bsz, nc, l, h)
    lfr = lf.reshape(bsz, nc, l, h)

    mask = jnp.tril(jnp.ones((l, l), bool))
    neg = jnp.asarray(-1e30, F32)

    def chunk_body(carry, inp):
        big_c, nvec, m_in = carry
        qc, kc, vc, lic, lfc = inp
        f_cum = jnp.cumsum(lfc, axis=1)                       # (B,L,H) inclusive
        # intra log-weights  w[t,s] = F_t - F_s + i_s  (t >= s)
        wlog = f_cum[:, :, None, :] - f_cum[:, None, :, :] + lic[:, None, :, :]
        wlog = jnp.where(mask[None, :, :, None], wlog, neg)
        m_intra = wlog.max(axis=2)                            # (B,L,H)
        m_t = jnp.maximum(m_in[:, None, :] + f_cum, m_intra)  # (B,L,H)
        d = jnp.exp(wlog - m_t[:, :, None, :])                # (B,L,L,H)
        scores = jnp.einsum("blhk,bmhk->blmh", qc, kc) * d
        num = jnp.einsum("blmh,bmhk->blhk", scores, vc)
        # inter-chunk via carried state
        inter_w = jnp.exp(m_in[:, None, :] + f_cum - m_t)     # (B,L,H)
        num = num + jnp.einsum("blhk,bhkv,blh->blhv", qc, big_c, inter_w)
        # denominator: |TOTAL normalizer| (intra + carried summed BEFORE abs)
        den_raw = scores.sum(axis=2) \
            + jnp.einsum("blhk,bhk->blh", qc, nvec) * inter_w
        hc = num / jnp.maximum(jnp.abs(den_raw),
                               jnp.exp(-m_t))[..., None]
        # carry update to the chunk end
        f_tot = f_cum[:, -1:, :]                              # (B,1,H)
        a_log = f_tot - f_cum + lic                           # (B,L,H)
        m_out = jnp.maximum(m_in + f_tot[:, 0], a_log.max(axis=1))
        cw = jnp.exp(a_log - m_out[:, None, :])               # (B,L,H)
        decay = jnp.exp(m_in + f_tot[:, 0] - m_out)           # (B,H)
        big_c = big_c * decay[..., None, None] + jnp.einsum(
            "blh,blhk,blhv->bhkv", cw, kc, vc)
        nvec = nvec * decay[..., None] + jnp.einsum("blh,blhk->bhk", cw, kc)
        return (big_c, nvec, m_out), hc

    if carry0 is None:
        carry0 = (
            jnp.zeros((bsz, h, kk, kk), F32),
            jnp.zeros((bsz, h, kk), F32),
            jnp.zeros((bsz, h), F32),
        )
    xs = tuple(jnp.moveaxis(a, 1, 0)
               for a in (qr, kr, vr, lir, lfr))
    carry, ys = jax.lax.scan(chunk_body, carry0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, kk), carry


def mlstm_step(q, k, v, gi, gf, carry):
    """Single-token mLSTM update.  q/k/v: (B,H,K), gi/gf: (B,H)."""
    big_c, nvec, m = carry
    kk = q.shape[-1]
    lf = _logsigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    f_eff = jnp.exp(lf + m - m_new)[..., None]
    i_eff = jnp.exp(gi - m_new)[..., None]
    big_c = big_c * f_eff[..., None] + i_eff[..., None] * (
        k[..., :, None] * v[..., None, :])
    nvec = nvec * f_eff + i_eff * k
    qs = q * (kk ** -0.5)
    num = jnp.einsum("bhk,bhkv->bhv", qs, big_c)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qs, nvec))
    hvec = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return hvec, (big_c, nvec, m_new)


# ===========================================================================
# sLSTM (xLSTM scalar cell, per-head recurrent R)
# ===========================================================================
def slstm_scan(gx, r, *, n_heads: int, carry0=None):
    """Sequential sLSTM over a sequence.

    Args:
        gx: (B, S, H, 4, hd) fp32 input-gate pre-activations (i, f, z, o).
        r:  (H, hd, 4*hd) recurrent weights (block-diagonal per head).
        carry0: optional (c, n, hvec, m), each (B, H, hd).

    Returns:
        h: (B, S, H, hd), carry.
    """
    bsz, s, h, _, hd = gx.shape
    if carry0 is None:
        z = jnp.zeros((bsz, h, hd), F32)
        carry0 = (z, z, z, z)

    def body(carry, g_t):
        c, n, hv, m = carry
        rec = jnp.einsum("bhk,hkl->bhl", hv, r).reshape(bsz, h, 4, hd)
        gi, gf, gz, go = [g_t[:, :, i] + rec[:, :, i] for i in range(4)]
        m_new = jnp.maximum(gf + m, gi)
        i_eff = jnp.exp(gi - m_new)
        f_eff = jnp.exp(gf + m - m_new)
        c = f_eff * c + i_eff * jnp.tanh(gz)
        n = f_eff * n + i_eff
        hv = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return (c, n, hv, m_new), hv

    carry, ys = jax.lax.scan(body, carry0, jnp.moveaxis(gx, 1, 0))
    return jnp.moveaxis(ys, 0, 1), carry


def slstm_step(g_t, r, carry):
    """One sLSTM step; g_t: (B, H, 4, hd)."""
    (c, n, hv, m) = carry
    bsz, h, _, hd = g_t.shape
    rec = jnp.einsum("bhk,hkl->bhl", hv, r).reshape(bsz, h, 4, hd)
    gi, gf, gz, go = [g_t[:, :, i] + rec[:, :, i] for i in range(4)]
    m_new = jnp.maximum(gf + m, gi)
    i_eff = jnp.exp(gi - m_new)
    f_eff = jnp.exp(gf + m - m_new)
    c = f_eff * c + i_eff * jnp.tanh(gz)
    n = f_eff * n + i_eff
    hv = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
    return hv, (c, n, hv, m_new)
