from repro.models import config, layers, model, params, ssm  # noqa: F401
