"""Architecture configuration for the assigned model pool.

One frozen dataclass describes every supported family (dense / moe / hybrid /
ssm / audio enc-dec / vlm); per-architecture instances live in
``repro.configs.<arch>``. The N-body system has its own config in
``repro.configs.nbody``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None    # default: d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    first_k_dense: int = 0            # leading dense layers (deepseek-v2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0            # decoupled rope dims per head
    v_head_dim: int = 0

    # --- SSM / hybrid / xLSTM ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256             # SSD / mLSTM chunk length
    attn_every: int = 0               # zamba2: shared attn block period
    slstm_every: int = 0              # xlstm: sLSTM block period (else mLSTM)

    # --- enc-dec / frontends ---
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    frontend: str = "none"            # none | audio_frames | vision_patches
    frontend_len: int = 0             # stub frontend sequence length
    mrope: bool = False
    mrope_sections: Tuple[int, ...] = ()

    # --- numerics / perf knobs ---
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"
    remat: str = "full"               # none | full | dots
    scan_layers: bool = True
    attn_chunk: int = 1024            # query-block size for chunked attention
    attn_chunked_above: int = 8192    # use chunked attention for S >= this
    attn_impl: str = "xla"            # xla | flash (Pallas kernel on TPU;
    #                                   VMEM-marked region on the CPU dry-run)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "moe" and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.kv_lora_rank and not self.v_head_dim:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    # ---------------- derived quantities ----------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the 'model' mesh axis always divides it."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def uses_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def block_kind(self, i: int) -> str:
        """Block type at depth i (mixed-family archs)."""
        if self.family == "hybrid":
            return "mamba"            # shared attn handled inside the scan
        if self.family == "ssm" and self.slstm_every:
            return "slstm" if (i % self.slstm_every == self.slstm_every - 1) \
                else "mlstm"
        return "attn"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        kv = self.n_kv_heads
        per_layer = 0
        if self.family in ("dense", "moe", "audio", "vlm", "hybrid"):
            if self.uses_mla:
                qd = self.q_lora_rank or d
                attn = (d * self.q_lora_rank if self.q_lora_rank else 0)
                attn += qd * self.n_heads * (hd + self.rope_head_dim)
                attn += d * (self.kv_lora_rank + self.rope_head_dim)
                attn += self.kv_lora_rank * self.n_heads * (hd + self.v_head_dim)
                attn += self.n_heads * self.v_head_dim * d
            else:
                attn = d * self.n_heads * hd + 2 * d * kv * hd \
                    + self.n_heads * hd * d
        if self.family == "moe":
            dense_ff = 3 * d * self.d_ff if not self.first_k_dense else 0
            expert_ff = 3 * d * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            router = d * self.n_experts
            per_layer = attn + expert_ff + router
            total_layers = per_layer * self.n_layers
            if self.first_k_dense:
                # first k layers use a dense FFN of width ~= top_k * moe_d_ff * 4
                total_layers += self.first_k_dense * 3 * d * (self.moe_d_ff * 8)
            return n + total_layers + 2 * d
        if self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            nh = di // self.ssm_head_dim
            mamba = d * (2 * di + 2 * ns + nh) + di * d + di * self.conv_width
            shared_attn = attn  # one shared block, counted once below
            return n + mamba * self.n_layers + shared_attn + 2 * d
        if self.family == "ssm":
            # mLSTM: qkv + gates + up/down proj (factor-2 inner)
            di = 2 * d
            mlstm = d * di * 2 + di * 3 * di // 1 + di * d  # coarse
            return n + mlstm * self.n_layers + 2 * d
        ffn = 3 * d * self.d_ff
        layers = self.n_layers + self.encoder_layers
        total = n + (attn + ffn) * layers + 2 * d
        if self.is_encoder_decoder:
            total += self.n_layers * attn  # cross-attention
        return total


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import importlib

    for mod in (
        "stablelm_3b", "deepseek_67b", "qwen3_0_6b", "stablelm_12b",
        "zamba2_7b", "seamless_m4t_medium", "xlstm_1_3b", "phi35_moe",
        "deepseek_v2_236b", "qwen2_vl_2b",
    ):
        importlib.import_module(f"repro.configs.{mod}")
