"""Parameter definitions: one tree of ``ParamDef`` per architecture.

Every parameter is declared once with its shape, logical sharding axes and
initializer; the same tree then yields
  * concrete initialized params        (``init_params``)
  * abstract ShapeDtypeStructs         (``abstract_params``, for the dry-run)
  * NamedShardings / PartitionSpecs    (``param_shardings``)
  * exact parameter counts             (``count_params`` / ``count_active``)

Per-layer blocks are stacked along a leading "layers" axis and consumed with
``lax.scan`` (compile-time is O(1) in depth — essential for the 95-layer
archs on the 512-device dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | a_log | dt_bias
    scale: float = 0.02

    def stacked(self, n: int) -> "ParamDef":
        return ParamDef(
            shape=(n,) + self.shape,
            logical=("layers",) + self.logical,
            init=self.init,
            scale=self.scale,
        )


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


# --------------------------------------------------------------------------
# per-block definition builders
# --------------------------------------------------------------------------
def _attn_defs(cfg: ArchConfig, *, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    pre = "x" if cross else ""
    out = {
        f"{pre}q": ParamDef((d, h * hd), ("fsdp_d_model", "heads")),
        f"{pre}k": ParamDef((d, kv * hd), ("fsdp_d_model", "kv_heads")),
        f"{pre}v": ParamDef((d, kv * hd), ("fsdp_d_model", "kv_heads")),
        f"{pre}o": ParamDef((h * hd, d), ("heads", "fsdp_d_model")),
    }
    if cfg.qk_norm and not cross:
        out["qn"] = ParamDef((hd,), ("head_dim",), "ones")
        out["kn"] = ParamDef((hd,), ("head_dim",), "ones")
    return out


def _mla_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h, hd, vhd, rhd = cfg.n_heads, cfg.head_dim, cfg.v_head_dim, cfg.rope_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "q_a": ParamDef((d, qlr), ("fsdp_d_model", None)),
        "q_norm": ParamDef((qlr,), (None,), "ones"),
        "q_b": ParamDef((qlr, h * (hd + rhd)), (None, "heads")),
        "kv_a": ParamDef((d, kvlr + rhd), ("fsdp_d_model", None)),
        "kv_norm": ParamDef((kvlr,), (None,), "ones"),
        "kv_b": ParamDef((kvlr, h * (hd + vhd)), (None, "heads")),
        "o": ParamDef((h * vhd, d), ("heads", "fsdp_d_model")),
    }


def _ffn_defs(d: int, f: int) -> dict:
    return {
        "wg": ParamDef((d, f), ("fsdp_d_model", "d_ff")),
        "wu": ParamDef((d, f), ("fsdp_d_model", "d_ff")),
        "wd": ParamDef((f, d), ("d_ff", "fsdp_d_model")),
    }


def _moe_defs(cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    out = {
        "router": ParamDef((d, e), ("fsdp_d_model", None)),
        "we_g": ParamDef((e, d, f), ("experts", "fsdp_d_model", None)),
        "we_u": ParamDef((e, d, f), ("experts", "fsdp_d_model", None)),
        "we_d": ParamDef((e, f, d), ("experts", None, "fsdp_d_model")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        out.update({f"ws_{k[-1]}": v for k, v in _ffn_defs(d, fs).items()})
    return out


def _mamba_defs(cfg: ArchConfig) -> dict:
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    return {
        "ln": ParamDef((d,), ("d_model",), "ones"),
        "wz": ParamDef((d, di), ("fsdp_d_model", "d_ff")),
        "wx": ParamDef((d, di), ("fsdp_d_model", "d_ff")),
        "wB": ParamDef((d, ns), ("fsdp_d_model", None)),
        "wC": ParamDef((d, ns), ("fsdp_d_model", None)),
        "wdt": ParamDef((d, nh), ("fsdp_d_model", "heads")),
        "conv": ParamDef((cfg.conv_width, di), (None, "d_ff")),
        "a_log": ParamDef((nh,), ("heads",), "a_log"),
        "d_skip": ParamDef((nh,), ("heads",), "ones"),
        "dt_bias": ParamDef((nh,), ("heads",), "dt_bias"),
        "gnorm": ParamDef((di,), ("d_ff",), "ones"),
        "wo": ParamDef((di, d), ("d_ff", "fsdp_d_model")),
    }


def _mlstm_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    dk = di // nh
    return {
        "ln": ParamDef((d,), ("d_model",), "ones"),
        "w_up": ParamDef((d, 2 * di), ("fsdp_d_model", "d_ff")),
        # q/k/v are block-diagonal per head (official mLSTM cell layout)
        "wq": ParamDef((nh, dk, dk), ("heads", None, None)),
        "wk": ParamDef((nh, dk, dk), ("heads", None, None)),
        "wv": ParamDef((nh, dk, dk), ("heads", None, None)),
        "w_if": ParamDef((di, 2 * nh), ("fsdp_d_model", None)),
        "onorm": ParamDef((di,), ("d_ff",), "ones"),
        "w_down": ParamDef((di, d), ("d_ff", "fsdp_d_model")),
    }


def _slstm_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    return {
        "ln": ParamDef((d,), ("d_model",), "ones"),
        "w_in": ParamDef((d, 4 * d), ("fsdp_d_model", "d_ff")),
        "r": ParamDef((nh, hd, 4 * hd), ("heads", None, None)),
        "b": ParamDef((4 * d,), ("d_ff",), "zeros"),
        "onorm": ParamDef((d,), ("d_model",), "ones"),
        "w_down": ParamDef((d, d), ("fsdp_d_model", "d_model")),
    }


def _block_defs(cfg: ArchConfig, kind: str, *, layer_idx: int = 0) -> dict:
    d = cfg.d_model
    out = {"ln1": ParamDef((d,), ("d_model",), "ones")}
    if kind == "mamba":
        return _mamba_defs(cfg)
    if kind == "mlstm":
        return _mlstm_defs(cfg)
    if kind == "slstm":
        return _slstm_defs(cfg)
    if cfg.uses_mla:
        out.update(_mla_defs(cfg))
    else:
        out.update(_attn_defs(cfg))
    out["ln2"] = ParamDef((d,), ("d_model",), "ones")
    if kind == "moe":
        out.update(_moe_defs(cfg))
    elif kind == "cross_attn":
        out.update(_attn_defs(cfg, cross=True))
        out["lnx"] = ParamDef((d,), ("d_model",), "ones")
        out.update(_ffn_defs(d, cfg.d_ff))
    else:
        out.update(_ffn_defs(d, cfg.d_ff))
    return out


def _stack(defs: dict, n: int) -> dict:
    return jax.tree.map(lambda p: p.stacked(n), defs, is_leaf=is_def)


# --------------------------------------------------------------------------
# full-model definition tree
# --------------------------------------------------------------------------
def param_defs(cfg: ArchConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    tree: dict = {
        "embed": ParamDef((v, d), ("vocab", "fsdp_d_model")),
        "final_norm": ParamDef((d,), ("d_model",), "ones"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamDef((d, v), ("fsdp_d_model", "vocab"))

    if cfg.family in ("dense", "vlm"):
        tree["blocks"] = _stack(_block_defs(cfg, "attn"), cfg.n_layers)
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - cfg.first_k_dense
        tree["blocks"] = _stack(_block_defs(cfg, "moe"), n_moe)
        if cfg.first_k_dense:
            tree["dense_blocks"] = _stack(
                _block_defs(cfg, "attn"), cfg.first_k_dense
            )
    elif cfg.family == "hybrid":
        tree["blocks"] = _stack(_block_defs(cfg, "mamba"), cfg.n_layers)
        tree["shared_attn"] = _block_defs(cfg, "attn")  # ONE shared block
    elif cfg.family == "ssm":
        n_s = cfg.n_layers // cfg.slstm_every
        n_m = cfg.n_layers - n_s
        tree["blocks"] = _stack(_block_defs(cfg, "mlstm"), n_m)
        tree["slstm_blocks"] = _stack(_block_defs(cfg, "slstm"), n_s)
    elif cfg.family == "audio":
        tree["enc_blocks"] = _stack(_block_defs(cfg, "attn"), cfg.encoder_layers)
        tree["dec_blocks"] = _stack(_block_defs(cfg, "cross_attn"), cfg.n_layers)
    else:
        raise ValueError(cfg.family)
    return tree


# --------------------------------------------------------------------------
# materialization
# --------------------------------------------------------------------------
def _init_one(p: ParamDef, key, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "a_log":
        nh = p.shape[-1]
        base = jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32))
        return jnp.broadcast_to(base, p.shape).astype(dtype)
    if p.init == "dt_bias":
        # softplus^-1 of dt in [1e-3, 1e-1], log-spaced
        nh = p.shape[-1]
        dt = jnp.exp(jnp.linspace(np.log(1e-3), np.log(1e-1), nh,
                                  dtype=jnp.float32))
        inv = jnp.log(jnp.expm1(dt))
        return jnp.broadcast_to(inv, p.shape).astype(dtype)
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    std = min(p.scale, fan_in ** -0.5)
    return (jax.random.normal(key, p.shape, jnp.float32) * std).astype(dtype)


def init_params(cfg: ArchConfig, key) -> dict:
    defs = param_defs(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ArchConfig, rules=None, dtype=None) -> dict:
    """ShapeDtypeStruct tree (optionally with shardings) — no allocation.

    ``dtype`` override: serving lowers against bf16 weights (the inference
    checkpoint cast), training against ``cfg.param_dtype`` masters."""
    defs = param_defs(cfg)
    dtype = jnp.dtype(dtype or cfg.param_dtype)

    def mk(p: ParamDef):
        sh = rules.sharding(p.shape, p.logical) if rules is not None else None
        return jax.ShapeDtypeStruct(p.shape, dtype, sharding=sh)

    return jax.tree.map(mk, defs, is_leaf=is_def)


def param_shardings(cfg: ArchConfig, rules) -> dict:
    defs = param_defs(cfg)
    return jax.tree.map(
        lambda p: rules.sharding(p.shape, p.logical), defs, is_leaf=is_def
    )


def param_specs(cfg: ArchConfig, rules) -> dict:
    defs = param_defs(cfg)
    return jax.tree.map(
        lambda p: rules.spec(p.shape, p.logical), defs, is_leaf=is_def
    )


def count_params(cfg: ArchConfig) -> int:
    defs = param_defs(cfg)
    return sum(int(np.prod(p.shape))
               for p in jax.tree.leaves(defs, is_leaf=is_def))


def count_active(cfg: ArchConfig) -> int:
    """Active (per-token) parameters — MoE counts top_k + shared experts,
    embeddings/lm_head excluded (the 6ND convention)."""
    defs = param_defs(cfg)
    total = 0
    # jax.tree.flatten_with_path only exists on newer jax; tree_util's
    # spelling works across the 0.4.x line too
    for path, p in jax.tree_util.tree_flatten_with_path(
            defs, is_leaf=is_def)[0]:
        keys = [getattr(k, "key", str(k)) for k in path]
        name = keys[-1]
        if keys[0] in ("embed", "lm_head"):
            continue
        n = int(np.prod(p.shape))
        if name.startswith("we_"):  # routed experts: only top_k of E active
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total
