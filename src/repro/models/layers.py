"""Model layers: norms, RoPE/M-RoPE, attention (GQA / MLA / chunked),
MoE dispatch, Mamba2 SSD and xLSTM cells.

Numerics: activations in ``cfg.dtype`` (bf16 default); softmax, router
probabilities, norm statistics and SSM/state recurrences in fp32.

The chunked attention (``_attn_streamed``) streams KV blocks against resident
query blocks with a running softmax — structurally the paper's
target-sharded / source-streamed N-body pattern (DESIGN.md §5), and the
memory-enabler for the 32k prefill shapes.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.shardings import MeshRules
from repro.models.config import ArchConfig

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms / embeddings
# --------------------------------------------------------------------------
def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def embed(tokens, table, dtype):
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(x, table_or_head, *, tied: bool):
    w = table_or_head.astype(x.dtype)
    if tied:
        return jnp.einsum("...d,vd->...v", x, w)
    return jnp.einsum("...d,dv->...v", x, w)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float):
    """M-RoPE (qwen2-vl): positions3 (3, ..., S) = (t, h, w) streams;
    ``sections`` split the hd/2 frequency bands across the three streams."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)
    # band i uses position stream sec_id[i]
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)
    onehot = jax.nn.one_hot(sec_id, 3, dtype=jnp.float32)   # (half, 3)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs  # (3,...,S,half)
    ang = jnp.einsum("p...h,hp->...h", ang_all, onehot)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(positions):
    """Text-only M-RoPE: all three streams equal the 1-D positions."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


def vlm_mrope_positions(batch: int, n_patches: int, n_text: int, grid: int):
    """(t, h, w) streams for [image patches | text] sequences (stub frontend:
    one image of ``grid``-wide raster-ordered patches at t=0, then text)."""
    idx = jnp.arange(n_patches, dtype=jnp.int32)
    hh, ww = idx // grid, idx % grid
    t_img = jnp.zeros((n_patches,), jnp.int32)
    t_txt = jnp.arange(1, n_text + 1, dtype=jnp.int32)
    t = jnp.concatenate([t_img, t_txt])
    h = jnp.concatenate([hh, t_txt])
    w = jnp.concatenate([ww, t_txt])
    pos3 = jnp.stack([t, h, w])                          # (3, S)
    return jnp.broadcast_to(pos3[:, None, :], (3, batch, pos3.shape[-1]))


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------
def _attn_full(q, k, v, *, causal: bool, q_pos=None, kv_pos=None, kv_len=None):
    """Grouped-query einsum attention: q (B,Sq,H,hd), k/v (B,Sk,KV,hd).

    KV heads are NEVER materialized H/KV-fold (the classic ``repeat_kv`` is a
    pure memory/reshard pessimization on TPU): queries are reshaped to
    (KV, group) and contracted against the kv heads directly, which also
    keeps a seq- or head-sharded KV cache layout stable under SPMD.
    """
    b, sq, h, hd = q.shape
    kv, vd = k.shape[2], v.shape[-1]          # v head dim may differ (MLA)
    g = h // kv
    scale = hd ** -0.5
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32) * scale
    mask = None
    if causal:
        qp = q_pos if q_pos is not None else jnp.arange(sq)
        kp = kv_pos if kv_pos is not None else jnp.arange(k.shape[1])
        mask = qp[:, None] >= kp[None, :]
    if kv_len is not None:
        valid = jnp.arange(k.shape[1])[None, :] < kv_len
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, sq, h, vd)


def _attn_streamed(q, k, v, *, causal: bool, q_chunk: int):
    """Memory-efficient attention: resident query blocks, streamed KV blocks
    with running (m, l, o) softmax state.  Pure-XLA flash-style; grouped-query
    form (k/v carry KV heads, never repeated)."""
    b, sq, h, hd = q.shape
    sk, kv, vd = k.shape[1], k.shape[2], v.shape[-1]
    g = h // kv
    scale = hd ** -0.5
    nq = sq // q_chunk
    kv_chunk = min(sk, max(q_chunk, 512))
    nk = sk // kv_chunk

    q_blocks = q.reshape(b, nq, q_chunk, kv, g, hd)

    def per_qblock(qi, qb):
        q_off = qi * q_chunk

        def inner(carry, ki):
            m, l, o = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
            vb = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb).astype(jnp.float32)
            s = s * scale
            if causal:
                qp = q_off + jnp.arange(q_chunk)
                kp = ki * kv_chunk + jnp.arange(kv_chunk)
                s = jnp.where((qp[:, None] >= kp[None, :])[None, None, None],
                              s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(qb.dtype), vb
            ).astype(jnp.float32)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        o0 = jnp.zeros((b, kv, g, q_chunk, vd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(inner, (m0, l0, o0), jnp.arange(nk))
        out = o / jnp.maximum(l[..., None], 1e-30)
        out = jnp.moveaxis(out, 3, 1)                      # (b, qc, kv, g, vd)
        return out.reshape(b, q_chunk, h, vd).astype(q.dtype)

    outs = jax.lax.map(
        lambda args: per_qblock(*args),
        (jnp.arange(nq), jnp.moveaxis(q_blocks, 1, 0)),
    )                                                      # (nq, b, qc, h, vd)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, vd)


def _attn_dispatch(cfg: ArchConfig, q, k, v, *, causal: bool):
    """Route to the configured attention implementation.

    ``flash``: the Pallas grouped-query flash kernel on TPU; on other
    backends the same math runs inside a ``PALLAS_VMEM_REGION`` named scope
    so the dry-run's HLO analyzer applies VMEM-fusion (kernel) cost
    semantics (see launch/hlo_analysis.py).  The kernel itself is validated
    in interpret mode against the XLA path (tests/test_flash_attention.py).
    """
    if cfg.attn_impl == "flash":
        if jax.default_backend() == "tpu":
            from repro.kernels.flash_attention import flash_attention

            bq = min(512, q.shape[1])
            bk = min(512, k.shape[1])
            return flash_attention(q, k, v, causal=causal,
                                   block_q=bq, block_k=bk)
        with jax.named_scope("PALLAS_VMEM_REGION"):
            if q.shape[1] >= cfg.attn_chunked_above:
                return _attn_streamed(q, k, v, causal=causal,
                                      q_chunk=cfg.attn_chunk)
            return _attn_full(q, k, v, causal=causal)
    if q.shape[1] >= cfg.attn_chunked_above:
        return _attn_streamed(q, k, v, causal=causal, q_chunk=cfg.attn_chunk)
    return _attn_full(q, k, v, causal=causal)


def attention(
    cfg: ArchConfig,
    rules: MeshRules,
    p: dict,
    x,
    *,
    positions,
    causal: bool = True,
    memory=None,              # cross-attention memory (enc-dec)
    cache: Optional[dict] = None,
    prefix: str = "",
    prefill_len: Optional[int] = None,
):
    """GQA attention with optional qk-norm, M-RoPE, cross-attn and KV cache.

    ``prefill_len``: run normal (causal) attention but additionally return the
    post-RoPE k/v padded to that length — the prefill cache-fill path.

    Returns (out, new_cache_slice | None).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    src = memory if memory is not None else x

    q = jnp.einsum("bsd,dh->bsh", x, p[prefix + "q"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", src, p[prefix + "k"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", src, p[prefix + "v"].astype(dt))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, src.shape[1], kv, hd)
    v = v.reshape(b, src.shape[1], kv, hd)
    q = rules.shard(q, "batch", "seq_q", "heads", None)
    k = rules.shard(k, "batch", None, "kv_heads", None)
    v = rules.shard(v, "batch", None, "kv_heads", None)

    if cfg.qk_norm and not prefix:
        q = rms_norm(q, p["qn"], cfg.norm_eps)
        k = rms_norm(k, p["kn"], cfg.norm_eps)

    if memory is None:  # self-attention: rotary embedding
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and memory is None:
        # decode: write this step's k/v at cur_len, attend over the cache
        ck, cv, cur = cache["k"], cache["v"], cache["len"]
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), cur, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), cur, 1)
        new_cache = {"k": ck, "v": cv}
        # the query is the newest token: the kv_len mask IS the causal mask
        out = _attn_full(q, ck.astype(dt), cv.astype(dt), causal=False,
                         kv_len=cur + s)
    else:
        if prefill_len is not None and memory is None:
            pad = prefill_len - k.shape[1]
            new_cache = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            }
        out = _attn_dispatch(cfg, q, k, v, causal=causal)

    out = rules.shard(out, "batch", None, "heads", None)
    out = out.reshape(b, s, h * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p[prefix + "o"].astype(dt))
    return rules.shard(out, "batch", "seq", "d_model"), new_cache


# --------------------------------------------------------------------------
# MLA (deepseek-v2)
# --------------------------------------------------------------------------
def mla_attention(
    cfg: ArchConfig,
    rules: MeshRules,
    p: dict,
    x,
    *,
    positions,
    cache: Optional[dict] = None,
    prefill_len: Optional[int] = None,
):
    """Multi-head Latent Attention. Cache holds only (c_kv, k_rope) — the
    paper's KV-compression; decode uses the absorbed-projection form."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd, vhd, rhd = cfg.head_dim, cfg.v_head_dim, cfg.rope_head_dim
    kvlr = cfg.kv_lora_rank
    dt = x.dtype

    # --- queries ---
    cq = jnp.einsum("bsd,dr->bsr", x, p["q_a"].astype(dt))
    cq = rms_norm(cq, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", cq, p["q_b"].astype(dt))
    q = q.reshape(b, s, h, hd + rhd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    # --- latent kv ---
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["kv_a"].astype(dt))
    c_kv, k_rope = ckv_full[..., :kvlr], ckv_full[..., kvlr:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    wkv_b = p["kv_b"].astype(dt).reshape(kvlr, h, hd + vhd)
    w_uk, w_uv = wkv_b[..., :hd], wkv_b[..., hd:]

    scale = (hd + rhd) ** -0.5

    if cache is not None:
        cur = cache["len"]
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cur, 1)
        krope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
            cur, 1)
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c}
        # absorbed form: q_eff = q_nope @ W_uk  ->  scores in latent space
        q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
        s_lat = jnp.einsum("bshr,bkr->bhsk", q_eff, ckv_c.astype(dt))
        s_rope = jnp.einsum("bshd,bkd->bhsk", q_rope, krope_c.astype(dt))
        scores = (s_lat + s_rope).astype(jnp.float32) * scale
        valid = jnp.arange(ckv_c.shape[1])[None, :] < (cur + s)
        scores = jnp.where(valid[None, None], scores, NEG_INF)
        pr = jax.nn.softmax(scores, axis=-1).astype(dt)
        o_lat = jnp.einsum("bhsk,bkr->bshr", pr, ckv_c.astype(dt))
        out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)
    else:
        new_cache = None
        if prefill_len is not None:
            pad = prefill_len - s
            new_cache = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                "k_rope": jnp.pad(k_rope[:, :, 0, :],
                                  ((0, 0), (0, pad), (0, 0))),
            }
        k_nope = jnp.einsum("bkr,rhd->bkhd", c_kv, w_uk)
        v = jnp.einsum("bkr,rhd->bkhd", c_kv, w_uv)
        k_rope_b = jnp.broadcast_to(k_rope, (b, s, h, rhd))
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        kf = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        qf = rules.shard(qf, "batch", None, "heads", None)
        kf = rules.shard(kf, "batch", None, "heads", None)
        out = _attn_dispatch(cfg, qf, kf, v, causal=True)

    out = out.reshape(b, s, h * vhd)
    out = jnp.einsum("bsh,hd->bsd", out, p["o"].astype(dt))
    return rules.shard(out, "batch", "seq", "d_model"), new_cache


# --------------------------------------------------------------------------
# FFN / MoE
# --------------------------------------------------------------------------
def ffn(cfg: ArchConfig, rules: MeshRules, p: dict, x, *, keys=("wg", "wu", "wd")):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p[keys[0]].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p[keys[1]].astype(dt))
    h = jax.nn.silu(g) * u
    h = rules.shard(h, "batch", "seq", "d_ff")
    out = jnp.einsum("bsf,fd->bsd", h, p[keys[2]].astype(dt))
    return rules.shard(out, "batch", "seq", "d_model")


def moe_ffn(cfg: ArchConfig, rules: MeshRules, p: dict, x):
    """Top-k MoE with sort-based capacity dispatch (DESIGN.md §6).

    Each sequence is a dispatch group: tokens are argsorted by expert id into
    contiguous (E, C) slots, experts run as one batched matmul sharded over
    the 'model' axis, and outputs scatter back via segment-sum.  Tokens over
    capacity are dropped (standard GShard semantics).  For single-token
    decode the exact dense-combine path is used instead (no drops).

    Returns (out, aux_loss).
    """
    b, s, d = x.shape
    e, k, f = cfg.n_experts, cfg.top_k, cfg.moe_d_ff
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (b, s, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style: f_i * P_i)
    me = probs.mean(axis=(0, 1))                            # (e,)
    ce = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_coef

    if s == 1:
        # exact dense combine for decode (weights for non-selected = 0)
        bi = jnp.arange(b)[:, None, None]
        si = jnp.arange(s)[None, :, None]
        w_full = jnp.zeros((b, s, e), jnp.float32).at[bi, si, top_i].add(top_p)
        hx = jnp.einsum("bsd,edf->besf", x, p["we_g"].astype(dt))
        ux = jnp.einsum("bsd,edf->besf", x, p["we_u"].astype(dt))
        yx = jnp.einsum("besf,efd->besd", jax.nn.silu(hx) * ux,
                        p["we_d"].astype(dt))
        out = jnp.einsum("besd,bse->bsd", yx, w_full.astype(dt))
    else:
        cap = max(8, int(math.ceil(s * k / e * cfg.capacity_factor)))

        def dispatch_one(xg, ig, pg):
            """xg: (s, d); ig/pg: (s, k) -> (out_g: (s, d))."""
            flat_i = ig.reshape(-1)                          # (s*k,)
            order = jnp.argsort(flat_i)
            sorted_e = flat_i[order]
            tok = order // k                                 # token of slot
            counts = jnp.bincount(sorted_e, length=e)
            starts = jnp.cumsum(counts) - counts
            pos = jnp.arange(s * k) - starts[sorted_e]
            ok = pos < cap
            # over-capacity entries get an out-of-bounds slot -> dropped
            slot = jnp.where(ok, sorted_e * cap + pos, e * cap)
            # (e*cap,) token index per slot; empty slots -> token s (pad row)
            slot_tok = jnp.full((e * cap,), s, jnp.int32).at[slot].set(
                tok.astype(jnp.int32), mode="drop")
            xg_pad = jnp.concatenate([xg, jnp.zeros((1, d), dt)], 0)
            xe = xg_pad[slot_tok].reshape(e, cap, d)
            h = jnp.einsum("ecd,edf->ecf", xe, p["we_g"].astype(dt))
            u = jnp.einsum("ecd,edf->ecf", xe, p["we_u"].astype(dt))
            ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                            p["we_d"].astype(dt))
            # combine: weight per slot, scatter-add back to tokens
            wslot = jnp.zeros((e * cap,), jnp.float32).at[slot].set(
                pg.reshape(-1)[order], mode="drop")
            contrib = ye.reshape(e * cap, d) * wslot[:, None].astype(dt)
            out_g = jax.ops.segment_sum(contrib, slot_tok, num_segments=s + 1)
            return out_g[:s]

        out = jax.vmap(dispatch_one)(x, top_i, top_p)
        out = rules.shard(out, "batch", "seq", "d_model")

    if cfg.n_shared_experts:
        out = out + ffn(cfg, rules, p, x, keys=("ws_g", "ws_u", "ws_d"))
    return out, aux
