"""Model assembly: train forward + loss, prefill, and single-token decode for
every assigned architecture family.

Families (DESIGN.md §5):
  dense / vlm   — pre-norm decoder, GQA (+ M-RoPE and patch-stub for vlm)
  moe           — as dense but MoE FFN (+ MLA + leading dense layers for
                  deepseek-v2)
  hybrid        — Mamba2 (SSD) backbone with ONE shared-weight attention+FFN
                  block applied every ``attn_every`` layers (zamba2)
  ssm           — xLSTM: groups of (slstm_every-1) mLSTM blocks + 1 sLSTM
  audio         — encoder-decoder; the speech frontend is a stub (precomputed
                  frame embeddings arrive in the batch)

Per-layer parameters are stacked and consumed with ``lax.scan`` (compile time
O(1) in depth); the scan body is rematerialized (``jax.checkpoint``) for
training when ``cfg.remat != "none"``.

Decode caches are pytrees of stacked per-layer arrays plus a scalar ``len``;
``cache_spec`` builds the matching ShapeDtypeStruct tree for the dry-run.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.shardings import MeshRules
from repro.models import layers, ssm
from repro.models.config import ArchConfig
from repro.models.params import param_defs  # noqa: F401  (re-export site)

F32 = jnp.float32


def _adt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _maybe_remat(cfg: ArchConfig, fn, *, train: bool):
    if not train or cfg.remat == "none":
        return fn
    policy = None
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint(fn, policy=policy)


# ===========================================================================
# block forwards
# ===========================================================================
def transformer_block(cfg, rules, p, x, *, positions, causal=True,
                      memory=None, cache=None, prefill_len=None):
    """Pre-norm attention (+cross) + FFN/MoE block.

    Returns (x, new_kv_cache_or_None, aux_loss).
    """
    xa = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.uses_mla:
        out, kv = layers.mla_attention(
            cfg, rules, p, xa, positions=positions, cache=cache,
            prefill_len=prefill_len)
    else:
        out, kv = layers.attention(
            cfg, rules, p, xa, positions=positions, causal=causal,
            cache=cache, prefill_len=prefill_len)
    x = x + out

    if "xq" in p:  # encoder-decoder cross-attention
        xc = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        out, _ = layers.attention(
            cfg, rules, p, xc, positions=positions, causal=False,
            memory=memory, prefix="x")
        x = x + out
        xf = layers.rms_norm(x, p["lnx"], cfg.norm_eps)
    else:
        xf = layers.rms_norm(x, p["ln2"], cfg.norm_eps)

    aux = jnp.zeros((), F32)
    if "router" in p:
        out, aux = layers.moe_ffn(cfg, rules, p, xf)
        x = x + out
    else:
        x = x + layers.ffn(cfg, rules, p, xf)
    return x, kv, aux


def mamba_block(cfg, rules, p, x, *, state=None, conv_cache=None):
    """Mamba2 block (SSD mixer).  Returns (x, state, conv_cache)."""
    b, s, d = x.shape
    dt_act = x.dtype
    di, ns = cfg.d_inner, cfg.ssm_state
    nh, hp = di // cfg.ssm_head_dim, cfg.ssm_head_dim

    xn = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    xi = jnp.einsum("bsd,df->bsf", xn, p["wx"].astype(dt_act))
    xi = rules.shard(xi, "batch", "seq", "d_ff")
    if conv_cache is not None:
        xi, conv_cache = ssm.causal_conv(xi, p["conv"].astype(dt_act),
                                         cache=conv_cache)
    else:
        xi = ssm.causal_conv(xi, p["conv"].astype(dt_act))
    xi = jax.nn.silu(xi)

    b_mat = jnp.einsum("bsd,dn->bsn", xn, p["wB"].astype(dt_act)).astype(F32)
    c_mat = jnp.einsum("bsd,dn->bsn", xn, p["wC"].astype(dt_act)).astype(F32)
    dtv = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xn, p["wdt"].astype(dt_act)).astype(F32)
        + p["dt_bias"].astype(F32))
    a_neg = -jnp.exp(p["a_log"].astype(F32))
    xh = xi.reshape(b, s, nh, hp).astype(F32)

    if s == 1 and state is not None:
        y, state = ssm.ssd_step(xh[:, 0], dtv[:, 0], a_neg,
                                b_mat[:, 0], c_mat[:, 0], state)
        y = y[:, None]
    else:
        y, state = ssm.ssd_chunked(xh, dtv, a_neg, b_mat, c_mat,
                                   chunk=min(cfg.chunk_size, s), state0=state)
    y = y + p["d_skip"].astype(F32)[:, None] * xh
    y = y.reshape(b, s, di).astype(dt_act)
    gate = jax.nn.silu(jnp.einsum("bsd,df->bsf", xn, p["wz"].astype(dt_act)))
    y = layers.rms_norm(y * gate, p["gnorm"], cfg.norm_eps)
    out = jnp.einsum("bsf,fd->bsd", y, p["wo"].astype(dt_act))
    return x + rules.shard(out, "batch", "seq", "d_model"), state, conv_cache


def mlstm_block(cfg, rules, p, x, *, carry=None):
    """xLSTM mLSTM block (factor-2 up-projection, per-head cell)."""
    b, s, d = x.shape
    dt_act = x.dtype
    di = 2 * d
    nh = cfg.n_heads
    dk = di // nh

    xn = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", xn, p["w_up"].astype(dt_act))
    up = rules.shard(up, "batch", "seq", "d_ff")
    xm, zg = jnp.split(up, 2, axis=-1)

    xh = xm.reshape(b, s, nh, dk).astype(F32)
    q = jnp.einsum("bshk,hkl->bshl", xh, p["wq"].astype(F32))
    k = jnp.einsum("bshk,hkl->bshl", xh, p["wk"].astype(F32))
    v = jnp.einsum("bshk,hkl->bshl", xh, p["wv"].astype(F32))
    gates = jnp.einsum("bsf,fg->bsg", xm, p["w_if"].astype(dt_act)).astype(F32)
    gi, gf = gates[..., :nh], gates[..., nh:]

    if s == 1 and carry is not None:
        h, carry = ssm.mlstm_step(q[:, 0], k[:, 0], v[:, 0],
                                  gi[:, 0], gf[:, 0], carry)
        h = h[:, None]
    else:
        h, carry = ssm.mlstm_chunked(q, k, v, gi, gf,
                                     chunk=min(cfg.chunk_size, s),
                                     carry0=carry)
    h = h.reshape(b, s, di).astype(dt_act)
    h = layers.rms_norm(h, p["onorm"], cfg.norm_eps) * jax.nn.silu(zg)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt_act))
    return x + rules.shard(out, "batch", "seq", "d_model"), carry


def slstm_block(cfg, rules, p, x, *, carry=None):
    """xLSTM sLSTM block (true time recurrence)."""
    b, s, d = x.shape
    dt_act = x.dtype
    nh = cfg.n_heads
    hd = d // nh

    xn = layers.rms_norm(x, p["ln"], cfg.norm_eps)
    gx = (jnp.einsum("bsd,dg->bsg", xn, p["w_in"].astype(dt_act))
          + p["b"].astype(dt_act)).astype(F32)
    gx = gx.reshape(b, s, nh, 4, hd)

    if s == 1 and carry is not None:
        h, carry = ssm.slstm_step(gx[:, 0], p["r"].astype(F32), carry)
        h = h[:, None]
    else:
        h, carry = ssm.slstm_scan(gx, p["r"].astype(F32), n_heads=nh,
                                  carry0=carry)
    h = h.reshape(b, s, d).astype(dt_act)
    h = layers.rms_norm(h, p["onorm"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h, p["w_down"].astype(dt_act))
    return x + rules.shard(out, "batch", "seq", "d_model"), carry


# ===========================================================================
# positions
# ===========================================================================
def _positions(cfg: ArchConfig, batch: dict, s: int, b: int):
    if cfg.mrope:
        if "patches" in batch:
            f = batch["patches"].shape[1]
            grid = max(1, int(round(f ** 0.5)))
            return layers.vlm_mrope_positions(b, f, s - f, grid)
        return layers.text_mrope_positions(
            jnp.broadcast_to(jnp.arange(s), (b, s)))
    return jnp.arange(s)


def _decode_positions(cfg: ArchConfig, cur, b: int, offset=0):
    """Positions for the single new token at index ``cur``; ``offset`` is the
    frontend (patch) span recorded in the cache at prefill time."""
    if cfg.mrope:
        t = jnp.maximum(cur - offset, 0) + 1
        pos = jnp.broadcast_to(t, (b, 1)).astype(jnp.int32)
        return jnp.stack([pos, pos, pos])          # text stream: t == h == w
    return jnp.broadcast_to(cur, (1, 1)).astype(jnp.int32)


# ===========================================================================
# forward (training / no-cache)
# ===========================================================================
def forward(cfg: ArchConfig, rules: MeshRules, params: dict, batch: dict,
            *, train: bool = True):
    """Returns (logits, aux_loss).  ``batch`` carries tokens (+stub frontends)."""
    dt_act = _adt(cfg)
    tokens = batch["tokens"]
    b = tokens.shape[0]
    x = layers.embed(tokens, params["embed"], dt_act)

    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dt_act), x], axis=1)
    x = rules.shard(x, "batch", "seq", "d_model")
    s = x.shape[1]
    positions = _positions(cfg, batch, s, b)

    aux = jnp.zeros((), F32)

    if cfg.family in ("dense", "vlm"):
        x, aux = _scan_attn_blocks(cfg, rules, params["blocks"], x,
                                   positions, train)
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            x, a0 = _scan_attn_blocks(cfg, rules, params["dense_blocks"], x,
                                      positions, train)
            aux = aux + a0
        x, a1 = _scan_attn_blocks(cfg, rules, params["blocks"], x,
                                  positions, train)
        aux = aux + a1
    elif cfg.family == "hybrid":
        x = _hybrid_forward(cfg, rules, params, x, positions, train)
    elif cfg.family == "ssm":
        x = _xlstm_forward(cfg, rules, params, x, train)
    elif cfg.family == "audio":
        memory = _audio_encoder(cfg, rules, params, batch["frames"], train)
        x, aux = _scan_attn_blocks(cfg, rules, params["dec_blocks"], x,
                                   positions, train, memory=memory)
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]       # logits over text span only
    logits = layers.unembed(
        x, params["embed"] if cfg.tie_embeddings else params["lm_head"],
        tied=cfg.tie_embeddings)
    return rules.shard(logits, "batch", "seq", "vocab"), aux


def _scan_attn_blocks(cfg, rules, stacked, x, positions, train, *,
                      memory=None, causal=True):
    def body(carry, pl):
        x, aux = carry
        x, _, a = transformer_block(cfg, rules, pl, x, positions=positions,
                                    causal=causal, memory=memory)
        return (x, aux + a), None

    body = _maybe_remat(cfg, body, train=train)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), F32)), stacked)
    return x, aux


def _audio_encoder(cfg, rules, params, frames, train):
    x = frames.astype(_adt(cfg))
    x = rules.shard(x, "batch", "seq", "d_model")
    pos = jnp.arange(x.shape[1])
    x, _ = _scan_attn_blocks(cfg, rules, params["enc_blocks"], x, pos, train,
                             causal=False)
    return x


def _hybrid_split(cfg, blocks):
    """Split the stacked Mamba blocks into (n_groups, every, ...) + tail."""
    every = cfg.attn_every
    n_g, tail = cfg.n_layers // every, cfg.n_layers % every
    head = jax.tree.map(
        lambda a: a[: n_g * every].reshape((n_g, every) + a.shape[1:]),
        blocks)
    tailp = jax.tree.map(lambda a: a[n_g * every:], blocks) if tail else None
    return head, tailp, n_g, tail


def _hybrid_forward(cfg, rules, params, x, positions, train):
    """zamba2: groups of ``attn_every`` Mamba2 layers, each followed by the
    ONE shared-weight attention+FFN block (branch-free scan-of-scans)."""
    shared = params["shared_attn"]
    head, tailp, n_g, tail = _hybrid_split(cfg, params["blocks"])

    def m_scan(x, stacked):
        def body(c, pl):
            y, _, _ = mamba_block(cfg, rules, pl, c)
            return y, None

        x, _ = jax.lax.scan(body, x, stacked)
        return x

    def group(carry, gp):
        x, = carry
        x = m_scan(x, gp)
        x, _, _ = transformer_block(cfg, rules, shared, x,
                                    positions=positions)
        return (x,), None

    group = _maybe_remat(cfg, group, train=train)
    (x,), _ = jax.lax.scan(group, (x,), head)
    if tail:
        x = m_scan(x, tailp)
    return x


def _xlstm_forward(cfg, rules, params, x, train):
    """xLSTM: groups of (slstm_every - 1) mLSTM blocks + 1 sLSTM block."""
    k = cfg.slstm_every
    n_groups = cfg.n_layers // k
    m_per = k - 1
    mparams = jax.tree.map(
        lambda a: a.reshape((n_groups, m_per) + a.shape[1:]),
        params["blocks"])

    def group(carry, inp):
        x, = carry
        mp, sp = inp

        def m_body(c, pl):
            y, _ = mlstm_block(cfg, rules, pl, c[0])
            return (y,), None

        (x,), _ = jax.lax.scan(m_body, (x,), mp)
        x, _ = slstm_block(cfg, rules, sp, x)
        return (x,), None

    group = _maybe_remat(cfg, group, train=train)
    (x,), _ = jax.lax.scan(group, (x,), (mparams, params["slstm_blocks"]))
    return x


# ===========================================================================
# loss
# ===========================================================================
def loss_fn(cfg: ArchConfig, rules: MeshRules, params: dict, batch: dict,
            *, z_coef: float = 1e-4):
    """Masked CE (fp32) + router aux + z-loss.  labels < 0 are masked out."""
    logits, aux = forward(cfg, rules, params, batch, train=True)
    labels = batch["labels"]
    lg = logits.astype(F32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(
        lg, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels >= 0).astype(F32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    zl = z_coef * ((lse * mask) ** 2).sum() / denom
    return ce + zl + aux, {"ce": ce, "aux": aux, "z": zl,
                           "tokens": mask.sum()}


# ===========================================================================
# caches
# ===========================================================================
def _kv_entry(cfg, b, max_len, dtype):
    if cfg.uses_mla:
        return {
            "c_kv": ((b, max_len, cfg.kv_lora_rank), dtype,
                     ("cache_batch", "cache_seq", None)),
            "k_rope": ((b, max_len, cfg.rope_head_dim), dtype,
                       ("cache_batch", "cache_seq", None)),
        }
    return {
        "k": ((b, max_len, cfg.n_kv_heads, cfg.head_dim), dtype,
              ("cache_batch", "cache_seq", "kv_heads", None)),
        "v": ((b, max_len, cfg.n_kv_heads, cfg.head_dim), dtype,
              ("cache_batch", "cache_seq", "kv_heads", None)),
    }


def _stack_entry(tree, n):
    return jax.tree.map(
        lambda e: ((n,) + e[0], e[1], (None,) + e[2]),
        tree, is_leaf=lambda v: isinstance(v, tuple) and isinstance(v[0], tuple))


def cache_layout(cfg: ArchConfig, b: int, max_len: int, enc_len: int = 0):
    """(shape, dtype, logical_axes) tree describing the decode cache."""
    kv_dt = jnp.dtype(cfg.dtype)
    di, ns = cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim if cfg.ssm_head_dim else 0

    if cfg.family in ("dense", "vlm"):
        lay = {"layers": _stack_entry(_kv_entry(cfg, b, max_len, kv_dt),
                                      cfg.n_layers)}
    elif cfg.family == "moe":
        lay = {"layers": _stack_entry(
            _kv_entry(cfg, b, max_len, kv_dt),
            cfg.n_layers - cfg.first_k_dense)}
        if cfg.first_k_dense:
            lay["dense_layers"] = _stack_entry(
                _kv_entry(cfg, b, max_len, kv_dt), cfg.first_k_dense)
    elif cfg.family == "hybrid":
        n_app = cfg.n_layers // cfg.attn_every
        lay = {
            "ssm": ((cfg.n_layers, b, nh, ns, cfg.ssm_head_dim), F32,
                    (None, "cache_batch", "heads", None, None)),
            "conv": ((cfg.n_layers, b, cfg.conv_width - 1, di), kv_dt,
                     (None, "cache_batch", None, "d_ff")),
            "attn": _stack_entry(_kv_entry(cfg, b, max_len, kv_dt), n_app),
        }
    elif cfg.family == "ssm":
        k = cfg.slstm_every
        n_g, m_per = cfg.n_layers // k, k - 1
        dml = 2 * cfg.d_model
        dk = dml // cfg.n_heads
        hd = cfg.d_model // cfg.n_heads
        lay = {
            "mlstm_C": ((n_g, m_per, b, cfg.n_heads, dk, dk), F32,
                        (None, None, "cache_batch", "heads", None, None)),
            "mlstm_n": ((n_g, m_per, b, cfg.n_heads, dk), F32,
                        (None, None, "cache_batch", "heads", None)),
            "mlstm_m": ((n_g, m_per, b, cfg.n_heads), F32,
                        (None, None, "cache_batch", "heads")),
            "slstm": ((n_g, 4, b, cfg.n_heads, hd), F32,
                      (None, None, "cache_batch", "heads", None)),
        }
    elif cfg.family == "audio":
        lay = {
            "layers": _stack_entry(_kv_entry(cfg, b, max_len, kv_dt),
                                   cfg.n_layers),
            "memory": ((b, enc_len or max_len, cfg.d_model), kv_dt,
                       ("cache_batch", "cache_seq", "d_model")),
        }
    else:
        raise ValueError(cfg.family)
    lay["len"] = ((), jnp.int32, ())
    lay["offset"] = ((), jnp.int32, ())            # frontend (patch) span
    return lay


def _is_entry(v):
    return isinstance(v, tuple) and len(v) == 3 and isinstance(v[0], tuple)


def init_cache(cfg, b, max_len, enc_len: int = 0):
    lay = cache_layout(cfg, b, max_len, enc_len)
    return jax.tree.map(lambda e: jnp.zeros(e[0], e[1]), lay,
                        is_leaf=_is_entry)


def cache_spec(cfg, b, max_len, rules: Optional[MeshRules] = None,
               enc_len: int = 0):
    """ShapeDtypeStruct tree (with shardings when ``rules``) for the dry-run."""
    lay = cache_layout(cfg, b, max_len, enc_len)

    def mk(e):
        shape, dtype, logical = e
        sh = rules.sharding(shape, logical) if rules is not None else None
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

    return jax.tree.map(mk, lay, is_leaf=_is_entry)


# ===========================================================================
# prefill
# ===========================================================================
def prefill(cfg: ArchConfig, rules: MeshRules, params: dict, batch: dict,
            *, max_len: Optional[int] = None):
    """Run the full prompt, returning (last-token logits, filled cache)."""
    dt_act = _adt(cfg)
    tokens = batch["tokens"]
    b, s_tok = tokens.shape
    x = layers.embed(tokens, params["embed"], dt_act)
    if cfg.family == "vlm" and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(dt_act), x], axis=1)
    x = rules.shard(x, "batch", "seq", "d_model")
    s = x.shape[1]
    max_len = max_len or s
    positions = _positions(cfg, batch, s, b)
    enc_len = batch["frames"].shape[1] if "frames" in batch else 0
    cache = init_cache(cfg, b, max_len, enc_len)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        memory = None
        if cfg.family == "audio":
            memory = _audio_encoder(cfg, rules, params, batch["frames"], False)
            cache["memory"] = memory.astype(cache["memory"].dtype)

        def scan_fill(stacked, x):
            def body(x, pl):
                x, kv, _ = transformer_block(
                    cfg, rules, pl, x, positions=positions, memory=memory,
                    prefill_len=max_len)
                return x, kv

            return jax.lax.scan(body, x, stacked)

        if cfg.family == "moe" and cfg.first_k_dense:
            x, kv_d = scan_fill(params["dense_blocks"], x)
            cache["dense_layers"] = kv_d
        key = "dec_blocks" if cfg.family == "audio" else "blocks"
        x, kv = scan_fill(params[key], x)
        cache["layers"] = kv

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        head, tailp, n_g, tail = _hybrid_split(cfg, params["blocks"])
        conv0 = jnp.zeros((b, cfg.conv_width - 1, cfg.d_inner), dt_act)

        def m_fill(x, stacked):
            def body(x, pl):
                x, st, cc = mamba_block(cfg, rules, pl, x, state=None,
                                        conv_cache=conv0)
                return x, (st, cc)

            return jax.lax.scan(body, x, stacked)

        def group(x, gp):
            x, (st, cc) = m_fill(x, gp)
            x, kv, _ = transformer_block(cfg, rules, shared, x,
                                         positions=positions,
                                         prefill_len=max_len)
            return x, (st, cc, kv)

        x, (states, convs, attn_kv) = jax.lax.scan(group, x, head)
        states = jax.tree.map(
            lambda a: a.reshape((n_g * cfg.attn_every,) + a.shape[2:]),
            states)
        convs = jax.tree.map(
            lambda a: a.reshape((n_g * cfg.attn_every,) + a.shape[2:]),
            convs)
        if tail:
            x, (st_t, cc_t) = m_fill(x, tailp)
            states = jnp.concatenate([states, st_t], axis=0)
            convs = jnp.concatenate([convs, cc_t], axis=0)
        cache["ssm"] = states
        cache["conv"] = convs.astype(cache["conv"].dtype)
        cache["attn"] = attn_kv

    elif cfg.family == "ssm":
        k = cfg.slstm_every
        n_g, m_per = cfg.n_layers // k, k - 1
        mparams = jax.tree.map(
            lambda a: a.reshape((n_g, m_per) + a.shape[1:]), params["blocks"])

        def group(x, inp):
            mp, sp = inp

            def m_body(x, pl):
                x, carry = mlstm_block(cfg, rules, pl, x)
                return x, carry

            x, m_carry = jax.lax.scan(m_body, x, mp)
            x, s_carry = slstm_block(cfg, rules, sp, x)
            return x, (m_carry, s_carry)

        x, (mc, sc) = jax.lax.scan(group, x, (mparams, params["slstm_blocks"]))
        cache["mlstm_C"], cache["mlstm_n"], cache["mlstm_m"] = mc
        cache["slstm"] = jnp.stack(sc, axis=1)
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1:]
    logits = layers.unembed(
        last, params["embed"] if cfg.tie_embeddings else params["lm_head"],
        tied=cfg.tie_embeddings)
    cache["len"] = jnp.asarray(s, jnp.int32)
    cache["offset"] = jnp.asarray(s - s_tok, jnp.int32)
    return logits[:, 0], cache


# ===========================================================================
# decode
# ===========================================================================
def decode_step(cfg: ArchConfig, rules: MeshRules, params: dict, cache: dict,
                tokens):
    """One new token per sequence.  tokens: (B, 1) int32.

    Returns (logits (B, vocab), new_cache).
    """
    dt_act = _adt(cfg)
    b = tokens.shape[0]
    cur = cache["len"]
    x = layers.embed(tokens, params["embed"], dt_act)
    x = rules.shard(x, "batch", None, "d_model")
    positions = _decode_positions(cfg, cur, b, cache.get("offset", 0))

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        memory = cache.get("memory")
        if memory is not None:
            memory = memory.astype(dt_act)

        def scan_dec(stacked, kvs, x):
            # cache lives in the scan CARRY and is updated in place
            # (dynamic_update_index on a loop carry lowers to an aliased
            # buffer — one cache copy, not an xs/ys double buffer)
            n_l = jax.tree.leaves(stacked)[0].shape[0]

            def body(carry, inp):
                x, kvs = carry
                pl, idx = inp
                kv = jax.tree.map(
                    lambda full: jax.lax.dynamic_index_in_dim(
                        full, idx, 0, keepdims=False), kvs)
                x, new_kv, _ = transformer_block(
                    cfg, rules, pl, x, positions=positions, memory=memory,
                    cache=dict(kv, len=cur))
                kvs = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), idx, 0), kvs, new_kv)
                return (x, kvs), None

            (x, kvs), _ = jax.lax.scan(
                body, (x, kvs), (stacked, jnp.arange(n_l)))
            return x, kvs

        new_cache = dict(cache)
        if cfg.family == "moe" and cfg.first_k_dense:
            x, kv_d = scan_dec(params["dense_blocks"], cache["dense_layers"], x)
            new_cache["dense_layers"] = kv_d
        key = "dec_blocks" if cfg.family == "audio" else "blocks"
        x, kv = scan_dec(params[key], cache["layers"], x)
        new_cache["layers"] = kv

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        head, tailp, n_g, tail = _hybrid_split(cfg, params["blocks"])
        every = cfg.attn_every
        n_head = n_g * every
        gr = lambda a: a[:n_head].reshape((n_g, every) + a.shape[1:])  # noqa

        def m_step(x, stacked, sts, ccs):
            def body(x, inp):
                pl, st, cc = inp
                x, st, cc = mamba_block(cfg, rules, pl, x, state=st,
                                        conv_cache=cc.astype(dt_act))
                return x, (st, cc)

            return jax.lax.scan(body, x, (stacked, sts, ccs))

        def group(carry, inp):
            x, attn_kv = carry                   # attn kv carried in place
            gp, sts, ccs, gidx = inp
            x, (sts, ccs) = m_step(x, gp, sts, ccs)
            kv = jax.tree.map(
                lambda full: jax.lax.dynamic_index_in_dim(
                    full, gidx, 0, keepdims=False), attn_kv)
            x, new_kv, _ = transformer_block(
                cfg, rules, shared, x, positions=positions,
                cache=dict(kv, len=cur))
            attn_kv = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_index_in_dim(
                    full, new.astype(full.dtype), gidx, 0),
                attn_kv, new_kv)
            return (x, attn_kv), (sts, ccs)

        (x, attn_kv), (states, convs) = jax.lax.scan(
            group, (x, cache["attn"]),
            (head, gr(cache["ssm"]), gr(cache["conv"]), jnp.arange(n_g)))
        states = jax.tree.map(
            lambda a: a.reshape((n_head,) + a.shape[2:]), states)
        convs = jax.tree.map(
            lambda a: a.reshape((n_head,) + a.shape[2:]), convs)
        if tail:
            x, (st_t, cc_t) = m_step(x, tailp, cache["ssm"][n_head:],
                                     cache["conv"][n_head:])
            states = jnp.concatenate([states, st_t], axis=0)
            convs = jnp.concatenate([convs, cc_t], axis=0)
        new_cache = dict(cache, ssm=states,
                         conv=convs.astype(cache["conv"].dtype),
                         attn=attn_kv)

    elif cfg.family == "ssm":
        k = cfg.slstm_every
        n_g, m_per = cfg.n_layers // k, k - 1
        mparams = jax.tree.map(
            lambda a: a.reshape((n_g, m_per) + a.shape[1:]), params["blocks"])

        def group(x, inp):
            mp, sp, mC, mn, mm, sl = inp

            def m_body(x, minp):
                pl, C, nv, m = minp
                x, carry = mlstm_block(cfg, rules, pl, x, carry=(C, nv, m))
                return x, carry

            x, (mC, mn, mm) = jax.lax.scan(m_body, x, (mp, mC, mn, mm))
            x, s_carry = slstm_block(cfg, rules, sp, x,
                                     carry=tuple(sl[i] for i in range(4)))
            return x, (mC, mn, mm, jnp.stack(s_carry))

        x, (mC, mn, mm, sl) = jax.lax.scan(
            group, x,
            (mparams, params["slstm_blocks"], cache["mlstm_C"],
             cache["mlstm_n"], cache["mlstm_m"], cache["slstm"]))
        new_cache = dict(cache, mlstm_C=mC, mlstm_n=mn, mlstm_m=mm, slstm=sl)
    else:
        raise ValueError(cfg.family)

    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = layers.unembed(
        x, params["embed"] if cfg.tie_embeddings else params["lm_head"],
        tied=cfg.tie_embeddings)
    new_cache["len"] = cur + 1
    return logits[:, 0], new_cache
