from repro.distributed.shardings import MeshRules, DEFAULT_RULES  # noqa: F401
