"""Gradient compression: stochastic int8 quantization with error feedback.

On a production mesh the int8 tensor (+ one fp32 scale per bucket) is what
crosses the data-parallel axis, cutting gradient-collective bytes ~4x; the
error-feedback buffer accumulates the quantization residual so the optimizer
sees an unbiased gradient over time (Seide et al. 2014; Karimireddy et al.
2019).  ``compressed_psum`` is the explicit shard_map form used by the
pure-DP N-body/LM paths; ``compress_tree`` is the in-step form the trainer
applies before the optimizer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_LEVELS = 127.0


def quantize(x, key=None):
    """x (fp) -> (int8 q, fp32 scale). Stochastic rounding when ``key``."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / _LEVELS
    scale = jnp.maximum(scale, 1e-30)
    y = xf / scale
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_leaf(g, e):
    """One error-feedback round: returns (g_hat, new_err)."""
    corrected = g.astype(jnp.float32) + e
    q, s = quantize(corrected)
    g_hat = dequantize(q, s)
    return g_hat, corrected - g_hat


def compress_tree(grads, err):
    """Apply error-feedback int8 compression leaf-wise."""
    out = jax.tree.map(compress_leaf, grads, err)
    g_hat = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda v: isinstance(v, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda v: isinstance(v, tuple))
    return g_hat, new_err


def zeros_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x, axis_name: str):
    """int8-on-the-wire all-reduce for use inside ``shard_map``.

    Quantizes locally, all-reduces the int8 payload widened to int32 (exact —
    the per-device range is ±127, so up to ~16M devices fit in int32), then
    dequantizes with the max participating scale.  The wire cost of the
    int32 widening is an XLA artifact; on TPU the intended lowering is an
    int8 all-to-all + local reduction (documented trade-off).
    """
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)   # shared scale
    scale = jnp.maximum(amax / _LEVELS, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
