"""Logical-axis sharding rules with divisibility-checked fallback.

Model code annotates arrays with *logical* axis names ("batch", "heads", ...);
``MeshRules`` maps them to mesh axes and silently drops any mapping whose mesh
axes do not divide the corresponding dimension (e.g. kv_heads=2 on a 16-way
'model' axis -> replicated). A mesh axis is never used twice in one spec.

``MeshRules(None, ...)`` is the single-device no-op used by smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[str, Sequence[str], None]

# Baseline rule set for the production (pod, data, model) mesh.  'fsdp' axes
# shard parameters/optimizer state (ZeRO-3 style); activations use 'batch'.
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "seq": None,                    # sequence-parallel variant: "model"
    "seq_q": None,                  # attention query-seq parallelism: "model"
    #   (the sharding fix for archs whose (kv, group) head factorization is
    #    not expressible on the model axis — see EXPERIMENTS.md §Perf)
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "layers": None,
    "state": None,
    "conv": None,
    # parameter (FSDP) axes
    "fsdp_d_model": ("data", "pod"),
    "fsdp_d_ff": None,
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
}


def _axes_tuple(v: AxisVal):
    if v is None:
        return ()
    if isinstance(v, str):
        return (v,)
    return tuple(v)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    mesh: Optional[Mesh]
    rules: dict

    @classmethod
    def single_device(cls) -> "MeshRules":
        return cls(mesh=None, rules=dict(DEFAULT_RULES))

    @classmethod
    def for_mesh(cls, mesh: Mesh, overrides: Optional[dict] = None) -> "MeshRules":
        rules = dict(DEFAULT_RULES)
        if overrides:
            rules.update(overrides)
        return cls(mesh=mesh, rules=rules)

    def with_overrides(self, **overrides) -> "MeshRules":
        rules = dict(self.rules)
        rules.update(overrides)
        return MeshRules(mesh=self.mesh, rules=rules)

    # ---------------- spec construction ----------------
    def spec(self, shape: Sequence[int], logical: Sequence[Optional[str]]) -> P:
        """PartitionSpec for ``shape`` under the rules, with fallbacks."""
        if self.mesh is None:
            return P()
        assert len(shape) == len(logical), (shape, logical)
        used: set = set()
        out = []
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        for dim, name in zip(shape, logical):
            axes = _axes_tuple(self.rules.get(name)) if name else ()
            # drop axes already used or not dividing the dimension
            picked = []
            prod = 1
            for a in axes:
                if a in used or a not in sizes:
                    continue
                if dim % (prod * sizes[a]) == 0:
                    picked.append(a)
                    prod *= sizes[a]
            for a in picked:
                used.add(a)
            out.append(tuple(picked) if len(picked) > 1 else (picked[0] if picked else None))
        return P(*out)

    def sharding(self, shape, logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(shape, logical))

    def shard(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """with_sharding_constraint by logical axes (no-op without a mesh)."""
        if self.mesh is None:
            return x
        s = self.sharding(x.shape, logical)
        return jax.lax.with_sharding_constraint(x, s)

    def num_devices(self) -> int:
        return 1 if self.mesh is None else self.mesh.size
