"""Scenario/ensemble simulation launcher — the ``repro.sim`` front door.

    PYTHONPATH=src python -m repro.launch.sim_run \
        --scenario king --w0 6 --n 256 --t-end 0.1
    PYTHONPATH=src python -m repro.launch.sim_run \
        --scenario merger --ensemble 8 --devices 2 --strategy replicated
    PYTHONPATH=src python -m repro.launch.sim_run \
        --scenario king:256 merger:512 plummer:128 --pad auto --kernel pallas

``--scenario`` takes either one registry name (homogeneous runs; ``name:N``
is shorthand for ``--n N``) or several ``name:N`` tokens — a *mixed*
ensemble, packed into one rectangular batch with zero-mass padding up to
``--pad`` (``auto`` = largest member).  ``--kernel`` routes force evaluation through the reference
all-pairs op (``ref``) or the tiled Pallas kernel (``pallas``; interpreted
on CPU).  Mixed-run telemetry counts interactions with each run's
``n_active``, never the padded N.

``--stepper {fixed,adaptive,block}`` selects the timestep mode:
``fixed`` (``--dt``), ``adaptive`` (shared Aarseth lockstep, capped at
``--dt-max``), or ``block`` (hierarchical per-particle power-of-two levels,
``--dt-max`` x ``--levels``; ``--levels auto`` sizes the hierarchy from the
initial Aarseth dt distribution; see docs/ensembles.md).  Telemetry reports
the *measured* per-run force-evaluation counts in every mode — in block mode
only the active targets of each event are evaluated, so the count is far
below ``steps * N**2`` on scenarios with a wide timestep dynamic range.
``--compaction gather`` additionally gathers each event's active targets
into a dense block-aligned buffer so the kernel grid *shrinks* to the live
block instead of masking it — telemetry then shows ``grid_tiles`` falling
with the active set (``--block-i/--block-j`` tune the tile shape).
``--bucket-mode member`` (the default) dispatches a mixed batch's capacity
buckets per member group instead of batch-shared, and
``--strategy X --devices k --stepper block`` shards a single run's domain
so every device compacts its *local* active targets (the report then
carries ``grid_tiles_per_shard``).  ``--mesh BxP`` fuses both axes: one
shard_map advances B batch shards x P domain shards at once (B*P =
``--devices``), bit-identical to either 1-D layout.

Each invocation emits a one-line summary plus a JSON telemetry report
(wall time, steps/s, interactions/s, modeled energy/EDP, per-run energy
conservation) under ``experiments/sim/`` (override with ``--out``).

``--devices k`` (k > 1) needs host-platform placeholder devices; the
launcher sets XLA_FLAGS accordingly BEFORE importing jax, mirroring the
paper's tt-run process-per-card launch.
"""

from __future__ import annotations

import argparse
import os
import sys


def _parse_params(pairs):
    """--param k=v (repeatable) -> dict with int/float coercion."""
    out = {}
    for pair in pairs or ():
        if "=" not in pair:
            raise SystemExit(f"--param expects k=v, got {pair!r}")
        k, v = pair.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", nargs="+", default=["plummer"],
                    help="one registry name, or several name:N tokens for a "
                         "mixed padded ensemble (e.g. king:256 merger:512)")
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--pad", default=None,
                    help="mixed-ensemble padded size: 'auto' (largest member)"
                         " or an integer N_max")
    ap.add_argument("--kernel", default=None, choices=(None, "ref", "pallas"),
                    help="force kernel: 'ref' (all-pairs XLA op) or 'pallas' "
                         "(tiled kernel; interpret mode off-TPU)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ensemble", type=int, default=1,
                    help="batch B independent runs (seeds seed..seed+B-1)")
    ap.add_argument("--t-end", type=float, default=1.0)
    ap.add_argument("--dt", type=float, default=None,
                    help="fixed step (single-run default: shared adaptive)")
    ap.add_argument("--stepper", default=None,
                    choices=(None, "fixed", "adaptive", "block"),
                    help="timestep mode: fixed dt, shared-adaptive (Aarseth) "
                         "lockstep, or hierarchical per-particle block "
                         "timesteps (default: fixed when --dt is given, "
                         "else adaptive)")
    ap.add_argument("--dt-max", type=float, default=0.0625,
                    help="coarsest timestep (adaptive cap / block level 0)")
    ap.add_argument("--levels", default="8",
                    help="block-timestep hierarchy depth (finest step is "
                         "dt_max / 2**(levels-1)), or 'auto' to size each "
                         "member from its initial Aarseth dt distribution "
                         "(clamped to [1, 8])")
    ap.add_argument("--compaction", default="none",
                    choices=("none", "gather"),
                    help="block stepper only: gather each event's active "
                         "targets into a dense block-aligned buffer and "
                         "launch the kernels on the shrunk grid (bit-for-bit "
                         "the masked result, far fewer tiles enqueued); "
                         "with --strategy X --devices k every shard gathers "
                         "its own LOCAL active targets")
    ap.add_argument("--bucket-mode", default="member",
                    choices=("member", "shared"),
                    help="capacity-bucket dispatch under --compaction "
                         "gather: 'member' groups ensemble members by their "
                         "n_active ceiling (a mixed batch's quiescent "
                         "members stop paying the widest member's grid), "
                         "'shared' is the batch-shared-bucket baseline")
    ap.add_argument("--block-i", type=int, default=None,
                    help="kernel target-tile rows (block stepper; default: "
                         "kernel's own — small N wants a smaller tile so "
                         "compaction has tiles to drop)")
    ap.add_argument("--block-j", type=int, default=None,
                    help="kernel source-tile columns (block stepper)")
    ap.add_argument("--sources", default="full",
                    choices=("full", "neighbor"),
                    help="block stepper force sources: 'full' (all-pairs, "
                         "bit-identical to the historical path) or "
                         "'neighbor' (Ahmad-Cohen split: near force from "
                         "gathered per-block neighbor windows every event, "
                         "far field Taylor-predicted between refreshes)")
    ap.add_argument("--neighbor-radius", type=float, default=0.25,
                    help="neighbor window radius in simulation length units "
                         "(--sources neighbor; larger = more exact near "
                         "force, wider gathers)")
    ap.add_argument("--refresh-levels", type=int, default=2,
                    help="far-field refresh cadence: rebuild windows every "
                         "n_sub >> K ticks of the block hierarchy "
                         "(--sources neighbor; 0 = once per macro step)")
    ap.add_argument("--eta", type=float, default=0.02)
    ap.add_argument("--order", type=int, default=6, choices=(4, 6))
    ap.add_argument("--strategy", default="single",
                    choices=("single", "replicated", "two_level",
                             "mesh_sharded", "ring"))
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--mesh", default=None, metavar="BxP",
                    help="fused 2-D device grid for the block stepper: "
                         "B batch shards x P domain shards in ONE "
                         "shard_map (e.g. --mesh 2x2 with --devices 4). "
                         "B*P must equal --devices; composes batch "
                         "sharding with mesh_sharded domain decomposition "
                         "bit-for-bit (see docs/ensembles.md)")
    ap.add_argument("--impl", default=None,
                    choices=(None, "pallas", "pallas_interpret", "xla",
                             "fp64"))
    ap.add_argument("--dtype", default="fp32",
                    choices=("fp64", "fp32", "mixed"),
                    help="precision axis: 'fp64' (pure-jnp golden oracle), "
                         "'fp32' (paper device precision), or 'mixed' "
                         "(bfloat16 per-pair arithmetic with compensated "
                         "fp32 accumulation — the Tensix unpack-fp32/"
                         "compute-reduced/pack-fp32 pattern)")
    ap.add_argument("--diag-every", type=int, default=16)
    ap.add_argument("--w0", type=float, default=None,
                    help="King concentration (sugar for --param w0=...)")
    ap.add_argument("--param", action="append", metavar="K=V",
                    help="scenario parameter, repeatable")
    ap.add_argument("--out", default=None, help="JSON report path")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(nested macro-step -> event -> kernel-launch "
                         "spans; load at https://ui.perfetto.dev)")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    help="attach a metrics-registry snapshot to every K-th "
                         "diagnostics record (0 = final snapshot only; the "
                         "report always carries the final one under "
                         "'metrics')")
    ap.add_argument("--no-validate", dest="validate", action="store_false",
                    help="skip construction-time scenario diagnostics")
    ap.add_argument("--list-scenarios", action="store_true")
    args = ap.parse_args(argv)

    if args.devices > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.sim import api, scenarios, telemetry

    if args.list_scenarios:
        for name in scenarios.available():
            spec = scenarios.get_spec(name)
            print(f"{name:16s} {spec.description}  defaults={dict(spec.defaults)}")
        return 0

    params = _parse_params(args.param)
    if args.w0 is not None:
        params["w0"] = args.w0

    if args.levels == "auto":
        n_levels = None
    else:
        try:
            n_levels = int(args.levels)
        except ValueError:
            raise SystemExit(
                f"--levels expects an integer or 'auto', got {args.levels!r}"
            ) from None

    mesh = None
    if args.mesh is not None:
        try:
            b_sh, p_sh = (int(e) for e in args.mesh.lower().split("x"))
        except ValueError:
            raise SystemExit(
                f"--mesh expects BxP (e.g. 2x2), got {args.mesh!r}") \
                from None
        mesh = (b_sh, p_sh)

    # one token => homogeneous path (name:N is shorthand for --n N, so the
    # report keeps the real scenario label); several tokens => mixed padded
    # ensemble, bare names inheriting --n.  ScenarioSpec.parse validates at
    # the flag boundary (registry name, minimum N) with errors naming the
    # bad field — the same typed requests the serving layer admits.
    try:
        specs = [scenarios.ScenarioSpec.parse(t, seed=args.seed)
                 for t in args.scenario]
    except scenarios.ScenarioError as e:
        raise SystemExit(f"--scenario: {e}") from None
    mixed = len(specs) > 1
    if mixed:
        mix = tuple((s.name, s.with_n(args.n).n) for s in specs)
        scenario_name, n_arg = "mixed", max(n for _, n in mix)
    else:
        mix = None
        scenario_name = specs[0].name
        n_arg = specs[0].with_n(args.n).n
    pad = None
    if args.pad is not None:
        if not mixed:
            raise SystemExit("--pad only applies to mixed name:N ensembles")
        if args.pad != "auto":
            try:
                pad = int(args.pad)
            except ValueError:
                raise SystemExit(
                    f"--pad expects 'auto' or an integer, got {args.pad!r}") \
                    from None

    cfg = api.SimConfig(
        scenario=scenario_name, n=n_arg, seed=args.seed,
        ensemble=args.ensemble, t_end=args.t_end, dt=args.dt,
        stepper=args.stepper, dt_max=args.dt_max, n_levels=n_levels,
        compaction=args.compaction, bucket_mode=args.bucket_mode,
        block_i=args.block_i,
        block_j=args.block_j, sources=args.sources, mesh=mesh,
        neighbor_radius=args.neighbor_radius,
        refresh_levels=args.refresh_levels, eta=args.eta,
        order=args.order, strategy=args.strategy, devices=args.devices,
        impl=args.impl, kernel=args.kernel, dtype=args.dtype,
        mix=mix, pad=pad,
        diag_every=args.diag_every, scenario_params=params,
        validate_ic=args.validate,
        trace=args.trace, metrics_interval=args.metrics_interval,
        out=args.out or telemetry.default_report_path(
            {"scenario": scenario_name, "n": n_arg,
             "ensemble": args.ensemble if not mixed
             else len(mix) * args.ensemble,
             "strategy": args.strategy}),
    )
    report = api.run(cfg)

    desc = " ".join(f"{nm}:{n}" for nm, n in mix) if mixed \
        else f"{scenario_name} n={n_arg}"
    print(f"[sim] scenario={desc} "
          f"ensemble={report['ensemble']} strategy={args.strategy} "
          f"devices={args.devices} order={args.order} "
          + (f"mesh={mesh[0]}x{mesh[1]} " if mesh else "")
          + f"stepper={report.get('stepper', 'fixed')} "
          f"dtype={args.dtype}"
          + (f" sources={args.sources}" if args.sources != "full" else "")
          + (f" kernel={args.kernel}" if args.kernel else ""))
    if mixed:
        print(f"[sim] padded N_max={report['n_bodies']} "
              f"n_active={report['n_active']}")
    print(f"[sim] t={report['t_final']:.4f} steps={report['steps']} "
          f"wall={report['wall_s']:.2f}s "
          f"steps/s={report['steps_per_s']:.1f} "
          f"pairs/s={report['interactions_per_s']:.3e}"
          + (f" force_evals={report['force_evals_total']:.3e}"
             if "force_evals_total" in report else "")
          + (f" grid_tiles={report['grid_tiles_total']:.3e}"
             if "grid_tiles_total" in report else ""))
    if "grid_tiles_per_shard" in report:
        shards = " ".join(f"{t:.0f}" for t in report["grid_tiles_per_shard"])
        print(f"[sim] grid_tiles_per_shard=[{shards}]")
    print(f"[sim] |dE/E|={report['de_rel']:.3e} "
          f"E_model={report['modeled']['energy_J']:.1f}J "
          f"EDP={report['modeled']['edp_Js']:.1f}Js")
    metrics = report.get("metrics") or {}
    counters = metrics.get("counters") or {}
    if counters:
        bits = " ".join(f"{k}={v['value']:g}"
                        for k, v in sorted(counters.items()))
        print(f"[sim] metrics: {bits}")
    if "trace_path" in report:
        print(f"[sim] trace -> {report['trace_path']}")
    print(f"[sim] report -> {report.get('report_path', '(not written)')}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
