"""Training launcher.

Runs a real (allocating) training job for any assigned architecture at a
reduced width/depth factor — the CPU-runnable path — or at full config on a
real TPU mesh.  The launcher owns: mesh construction, sharding rules, data
pipeline, trainer (checkpoint/restart + straggler monitor).

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --scale 0.05 --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.data import BatchSpec, SyntheticLM, batch_spec_for
from repro.distributed.shardings import MeshRules
from repro.models import config as C
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, TrainerConfig


def scaled_config(cfg, scale: float):
    """Reduced config of the same family for CPU-scale runs."""
    if scale >= 1.0:
        return cfg
    d = max(64, int(cfg.d_model * scale) // 16 * 16)
    heads = max(2, min(cfg.n_heads, d // 64))
    kv = max(1, min(cfg.n_kv_heads, heads))
    layers = max(2, int(cfg.n_layers * scale))
    if cfg.family == "hybrid":
        layers = max(cfg.attn_every, layers // cfg.attn_every * cfg.attn_every)
    if cfg.family == "ssm":
        layers = max(cfg.slstm_every,
                     layers // cfg.slstm_every * cfg.slstm_every)
    hd = 64 if cfg.uses_mla else d // heads
    sections = ()
    if cfg.mrope:
        half = hd // 2
        sections = (half - half // 4 - half // 4, half // 4, half // 4)
    return dataclasses.replace(
        cfg,
        name=cfg.name + f"-x{scale}",
        n_layers=layers,
        d_model=d,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=None if not cfg.uses_mla else 64,
        mrope_sections=sections if cfg.mrope else cfg.mrope_sections,
        d_ff=max(128, int(cfg.d_ff * scale) // 16 * 16) if cfg.d_ff else 0,
        moe_d_ff=max(64, int(cfg.moe_d_ff * scale) // 16 * 16)
        if cfg.moe_d_ff else 0,
        vocab_size=min(cfg.vocab_size, 8192),
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        q_lora_rank=96 if cfg.q_lora_rank else 0,
        rope_head_dim=16 if cfg.rope_head_dim else 0,
        v_head_dim=64 if cfg.v_head_dim else 0,
        encoder_layers=max(2, int(cfg.encoder_layers * scale))
        if cfg.encoder_layers else 0,
        frontend_len=min(cfg.frontend_len, 64),
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_head_dim=min(cfg.ssm_head_dim, 32),
        chunk_size=min(cfg.chunk_size, 64),
        attn_chunk=128,
        attn_chunked_above=10 ** 9,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.available())
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = scaled_config(C.get(args.arch), args.scale)
    rules = MeshRules.single_device()  # real-mesh path: MeshRules.for_mesh
    spec = batch_spec_for(cfg, args.batch, args.seq)
    data = SyntheticLM(cfg, spec, seed=args.seed)
    opt = AdamW(learning_rate=warmup_cosine(
        args.lr, warmup=max(args.steps // 20, 5), total=args.steps))
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, accum=args.accum,
                         seed=args.seed)
    trainer = Trainer(cfg, rules, opt, data, tcfg)
    _, _, history = trainer.run()
    final = history[-1]
    print(f"[train.py] done: {len(history)} steps, final loss "
          f"{final['loss']:.4f}, stragglers flagged: "
          f"{trainer.monitor.flagged}")


if __name__ == "__main__":
    main()
