import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline terms.

MUST be imported before any other jax-touching module — the XLA_FLAGS line
above runs first and forces 512 placeholder CPU devices (jax locks the device
count at first init).  Never set that flag globally: smoke tests and benches
see 1 device.

Per cell this script:
  1. builds the (16,16) single-pod or (2,16,16) multi-pod mesh;
  2. lowers the target step (train_step / prefill / decode) against abstract
     ShapeDtypeStruct inputs carrying NamedShardings — no allocation;
  3. compiles, recording ``memory_analysis()`` (per-device bytes — proves the
     cell fits), ``cost_analysis()`` (per-device FLOPs/bytes), and the wire
     bytes of every collective parsed from the optimized HLO;
  4. writes one JSON to ``experiments/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --nbody --mesh single
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.shardings import MeshRules
from repro.launch import hlo_analysis as H
from repro.launch import shapes as S
from repro.launch.mesh import make_production_mesh
from repro.models import config as C
from repro.models import model as M
from repro.models import params as P
from repro.optim import AdamW, abstract_state
from repro.train import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (1 link assumed; conservative)


def roofline_terms(flops, bytes_accessed, wire_bytes):
    return {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": wire_bytes / ICI_BW,
    }


def _model_flops(cfg, case) -> float:
    """6*N_active*D for train, 2*N_active*D for serve (D = tokens/step)."""
    n_active = P.count_active(cfg)
    if case.kind == "train":
        toks = case.global_batch * case.seq_len
        return 6.0 * n_active * toks
    if case.kind == "prefill":
        return 2.0 * n_active * case.global_batch * case.seq_len
    return 2.0 * n_active * case.global_batch  # decode: 1 token/seq


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               rule_overrides: dict | None = None, accum: int = 0,
               flash: bool = False, accum_dtype="float32"):
    """Build + lower + compile one cell; returns (record, compiled).

    ``accum=0`` selects the per-arch default microbatching (shapes.TRAIN_ACCUM)
    for train cells.  Serve cells lower against bf16 weights.
    """
    cfg = C.get(arch)
    if flash:
        import dataclasses
        cfg = dataclasses.replace(cfg, attn_impl="flash")
    case = S.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    # decode caches: prefer kv-head sharding when it divides the model axis
    # (no softmax-axis communication); fall back to sequence-sharded caches
    # for small-kv GQA archs (memory enabler — see EXPERIMENTS.md §Dry-run)
    if cfg.n_kv_heads % model_size == 0 and not cfg.uses_mla:
        overrides = {"cache_seq": None}
    else:
        overrides = {"cache_seq": "model"}
    overrides.update(rule_overrides or {})
    rules = MeshRules.for_mesh(mesh, overrides)

    t0 = time.time()
    if case.kind == "train":
        accum = accum or S.TRAIN_ACCUM.get(arch, 1)
        # the global microbatch (batch/accum) must stay divisible by the
        # batch-sharding degree, or SPMD silently REPLICATES each microbatch
        # across the excess batch ranks (observed 16x flops bloat on
        # deepseek-67b multi-pod — EXPERIMENTS.md §Perf hypothesis log)
        batch_shards = mesh.size // model_size
        accum = max(1, min(accum, case.global_batch // batch_shards))
        opt = AdamW(learning_rate=1e-3)
        step = make_train_step(cfg, rules, opt, accum=accum,
                               accum_dtype=jnp.dtype(accum_dtype))
        params = P.abstract_params(cfg, rules)
        opt_state = abstract_state(params)
        batch = S.train_specs(cfg, case, rules)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt_state, batch)
    elif case.kind == "prefill":
        def step(params, batch):
            return M.prefill(cfg, rules, params, batch)

        params = P.abstract_params(cfg, rules, dtype="bfloat16")
        batch = S.prefill_specs(cfg, case, rules)
        with mesh:
            lowered = jax.jit(step).lower(params, batch)
    else:
        def step(params, cache, tokens):
            return M.decode_step(cfg, rules, params, cache, tokens)

        params = P.abstract_params(cfg, rules, dtype="bfloat16")
        spec = S.decode_specs(cfg, case, rules)
        with mesh:
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params, spec["cache"], spec["tokens"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    mem = compiled.memory_analysis()
    # trip-count-aware static analysis (XLA's cost_analysis counts while
    # bodies once — useless for scan-structured programs; see hlo_analysis)
    an = H.analyze(compiled.as_text())
    flops = an["flops"]
    bytes_acc = an["hbm_bytes"]
    coll = an["collectives"]
    terms = roofline_terms(flops, bytes_acc, coll["total"])
    mf = _model_flops(cfg, case)
    chips = mesh.size

    record = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "kind": case.kind,
        "per_device": {
            "flops": flops,
            "dot_flops": an["dot_flops"],
            "bytes_accessed": bytes_acc,
            "xla_flops_body_once": float(ca.get("flops", 0.0)),
            "collective_wire_bytes": coll["total"],
            "collectives": {k: v for k, v in coll.items()
                            if k not in ("total",)},
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "roofline": dict(
            terms,
            bottleneck=max(terms, key=terms.get).replace("_s", ""),
            step_time_s=max(terms.values()),
        ),
        "model_flops_total": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_fraction": (mf / chips) / flops if flops else 0.0,
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
    }
    return record, compiled


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str,
             tag: str = "", rule_overrides: dict | None = None,
             accum: int = 0, flash: bool = False, verbose: bool = True,
             accum_dtype="float32"):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, why = S.cell_supported(C.get(arch), shape)
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(
        out_dir, f"{arch}__{shape}__{mesh_name}{tag}.json")
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "skipped": why}
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape} x {mesh_name}: {why}")
        return rec
    try:
        rec, compiled = lower_cell(arch, shape, multi_pod=multi_pod,
                                   rule_overrides=rule_overrides, accum=accum,
                                   flash=flash, accum_dtype=accum_dtype)
    except Exception as e:  # a failing cell is a bug — record it loudly
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] FAIL {arch} x {shape} x {mesh_name}: {e}")
        return rec
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        t = rec["roofline"]
        pd = rec["per_device"]
        print(f"[dryrun] OK {arch} x {shape} x {mesh_name}: "
              f"compute {t['compute_s']:.4f}s  memory {t['memory_s']:.4f}s  "
              f"collective {t['collective_s']:.4f}s  "
              f"bottleneck={t['bottleneck']}  "
              f"peak {pd['peak_bytes']/2**30:.2f} GiB/dev  "
              f"(compile {rec['timings']['compile_s']:.0f}s)")
    return rec


# ---------------------------------------------------------------------------
# N-body cells (the paper's own workload on the production mesh)
# ---------------------------------------------------------------------------
def run_nbody_cell(strategy: str, *, n_particles: int = 409_600,
                   multi_pod: bool = False, out_dir: str = OUT_DIR,
                   order: int = 6, tag: str = "", impl: str = "xla",
                   verbose: bool = True):
    from repro.core import strategies as ST

    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    devs = list(mesh.devices.reshape(-1))
    ev = ST.make_strategy_evaluator(
        strategy, devices=devs, eps=1e-7, order=order, impl=impl,
        chips_per_card=2)
    n = n_particles
    f64 = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    pos = jax.ShapeDtypeStruct((n, 3), f64)
    vel = jax.ShapeDtypeStruct((n, 3), f64)
    mass = jax.ShapeDtypeStruct((n,), f64)

    t0 = time.time()
    lowered = jax.jit(lambda p, v, m: ev(p, v, m)).lower(pos, vel, mass)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    mem = compiled.memory_analysis()
    # trip-count-aware static analysis (XLA's cost_analysis counts while
    # bodies once — useless for scan-structured programs; see hlo_analysis)
    an = H.analyze(compiled.as_text())
    flops = an["flops"]
    bytes_acc = an["hbm_bytes"]
    coll = an["collectives"]
    if impl == "pallas_marked":
        # deployed-kernel HBM model: BlockSpec streaming traffic (residual
        # marked-path bytes are XLA layout copies the kernel never makes).
        # tgt blocks stay VMEM-resident across the j sweep (constant block
        # index); src blocks re-stream once per i block; out written once.
        import math as _math
        n_loc = -(-n // mesh.size)
        n_i = -(-n_loc // 256)
        passes = 2 if order >= 6 else 1           # acc/jerk + snap sweeps
        bytes_model = passes * (
            n_loc * 32 + 32 * float(n) * n_i + 2 * n_loc * 32)
        bytes_acc = min(bytes_acc, bytes_model)
        # the XLA stand-in's materialized pairwise buffers do not exist in
        # the kernel either: peak = operands + gathered sources + VMEM tiles
        kernel_peak = int(mem.argument_size_in_bytes
                          + mem.output_size_in_bytes
                          + 32 * n + (1 << 26))
        mem = type("M", (), dict(
            argument_size_in_bytes=mem.argument_size_in_bytes,
            output_size_in_bytes=mem.output_size_in_bytes,
            alias_size_in_bytes=mem.alias_size_in_bytes,
            temp_size_in_bytes=kernel_peak
            - mem.argument_size_in_bytes - mem.output_size_in_bytes))()
    terms = roofline_terms(flops, bytes_acc, coll["total"])
    # the all-pairs kernel is elementwise (VPU) work — the MXU bf16 peak
    # does not apply; v5e VPU fp32 is ~1/16 of the MXU peak (documented)
    terms["compute_vpu_s"] = flops / (PEAK_FLOPS / 16.0)
    # useful flops: acc+jerk ~44 flops/pair + snap pass ~50 flops/pair
    pair_flops = (44.0 + (50.0 if order >= 6 else 0.0)) * float(n) * n
    rec = {
        "arch": f"nbody-{strategy}",
        "shape": f"N{n}",
        "mesh": mesh_name,
        "chips": mesh.size,
        "kind": "nbody",
        "per_device": {
            "flops": flops,
            "dot_flops": an["dot_flops"],
            "bytes_accessed": bytes_acc,
            "xla_flops_body_once": float(ca.get("flops", 0.0)),
            "collective_wire_bytes": coll["total"],
            "collectives": {k: v for k, v in coll.items() if k != "total"},
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           - mem.alias_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "roofline": dict(
            terms,
            bottleneck=max(
                ("compute_vpu_s", "memory_s", "collective_s"),
                key=terms.get).replace("_s", ""),
            step_time_s=max(terms[k] for k in
                            ("compute_vpu_s", "memory_s", "collective_s")),
        ),
        "model_flops_total": pair_flops,
        "model_flops_per_chip": pair_flops / mesh.size,
        "useful_flops_fraction": (pair_flops / mesh.size) / flops
        if flops else 0.0,
        "timings": {"compile_s": t_compile},
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir,
                         f"nbody-{strategy}__N{n}__{mesh_name}{tag}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        t = rec["roofline"]
        print(f"[dryrun] OK nbody-{strategy} N={n} x {mesh_name}: "
              f"compute {t['compute_s']:.4f}s  memory {t['memory_s']:.4f}s  "
              f"collective {t['collective_s']:.4f}s  "
              f"bottleneck={t['bottleneck']} (compile {t_compile:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(S.SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--nbody", action="store_true",
                    help="N-body strategy cells instead of LM cells")
    ap.add_argument("--strategy", default=None,
                    help="nbody strategy (default: all four)")
    ap.add_argument("--n-particles", type=int, default=409_600)
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--flash", action="store_true",
                    help="attn_impl=flash (Pallas kernel / marked region)")
    ap.add_argument("--nbody-impl", default="xla",
                    choices=("xla", "pallas_marked"))
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    if args.nbody:
        from repro.core.strategies import STRATEGIES
        strats = [args.strategy] if args.strategy else list(STRATEGIES)
        for mp in meshes:
            for st in strats:
                run_nbody_cell(st, n_particles=args.n_particles,
                               multi_pod=mp, out_dir=args.out, tag=args.tag,
                               impl=args.nbody_impl)
        return

    archs = [args.arch] if args.arch else C.available()
    shps = [args.shape] if args.shape else list(S.SHAPES)
    if not (args.all or args.arch or args.shape):
        ap.error("pass --arch/--shape, --all, or --nbody")
    for mp in meshes:
        for arch in archs:
            for shape in shps:
                run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                         tag=args.tag, accum=args.accum,
                         rule_overrides=None,
                         flash=args.flash)


if __name__ == "__main__":
    main()
