"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then builds either the single-pod (16, 16) = 256-chip mesh or the
2-pod (2, 16, 16) = 512-chip mesh.

Axis semantics (DESIGN.md §6):
  pod   — data-parallel across pods (gradient all-reduce over DCN/ICI);
  data  — data-parallel + FSDP parameter sharding within a pod;
  model — tensor/expert parallel (heads, d_ff, vocab, experts).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, found {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    # more devices than the mesh needs (512 placeholders, 256-chip mesh)
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence[jax.Device]] = None) -> Mesh:
    """Small helper for tests: mesh over an explicit device subset."""
    devices = list(devices if devices is not None else jax.devices())
    n = int(np.prod(shape))
    return Mesh(np.asarray(devices[:n]).reshape(tuple(shape)), tuple(axes))
