"""N-body simulation launcher — the paper's workload end-to-end.

Runs a Plummer-sphere direct N-body simulation with the 6th-order Hermite
integrator, the FP32 force evaluation offloaded to the (Pallas/XLA) kernel,
under any of the paper's three scaling strategies (+ the beyond-paper ring):

  PYTHONPATH=src python -m repro.launch.nbody_run --n 4096 --t-end 1.0 \
      --strategy replicated --devices 4

``--devices k`` (k > 1) needs host-platform placeholder devices; the launcher
sets XLA_FLAGS accordingly BEFORE importing jax, mirroring the paper's tt-run
process-per-card launch.
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--t-end", type=float, default=1.0)
    ap.add_argument("--dt", type=float, default=None,
                    help="fixed step (default: shared adaptive Aarseth)")
    ap.add_argument("--eta", type=float, default=0.02)
    ap.add_argument("--order", type=int, default=6, choices=(4, 6))
    ap.add_argument("--strategy", default="single",
                    choices=("single", "replicated", "two_level",
                             "mesh_sharded", "ring"))
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--impl", default=None,
                    choices=(None, "pallas", "pallas_interpret", "xla"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--x64", action="store_true", default=True)
    args = ap.parse_args()

    if args.devices > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax

    if args.x64:
        jax.config.update("jax_enable_x64", True)

    from repro.core import hermite, nbody
    from repro.core.evaluate import make_evaluator
    from repro.core.strategies import make_strategy_evaluator

    state = nbody.plummer(args.n, seed=args.seed)
    impl = args.impl or ("xla" if args.strategy != "single" else None)
    if args.strategy == "single":
        ev = make_evaluator(order=args.order, impl=impl)
    else:
        ev = make_strategy_evaluator(
            args.strategy, devices=jax.devices()[: args.devices],
            order=args.order, impl=impl or "xla")

    e0_state = hermite.initialize(state, ev)
    e0 = float(nbody.total_energy(e0_state))
    t0 = time.perf_counter()
    out = hermite.evolve(state, ev, t_end=args.t_end, dt=args.dt,
                         eta=args.eta, order=args.order)
    jax.block_until_ready(out.pos)
    wall = time.perf_counter() - t0
    e1 = float(nbody.total_energy(out))
    print(f"[nbody] N={args.n} strategy={args.strategy} "
          f"devices={args.devices} order={args.order}")
    print(f"[nbody] t={float(out.time):.4f} wall={wall:.2f}s "
          f"E0={e0:.6f} E1={e1:.6f} |dE/E0|={abs((e1 - e0) / e0):.3e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
