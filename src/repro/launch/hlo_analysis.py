"""Trip-count-aware static analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body exactly ONCE
(verified: a k-step ``lax.scan`` of matmuls reports 1/k of the true FLOPs), so
for scan-structured programs — every model here — its numbers are useless for
a roofline.  This module re-derives per-device cost from the optimized HLO
text itself:

  * computations are parsed into an instruction list + call graph
    (``fusion calls=``, ``while body=/condition=``, ``conditional
    branch_computations=``);
  * while ops carry ``backend_config={"known_trip_count":{"n":...}}`` in
    scheduled HLO — each computation's execution multiplier is the sum over
    its call sites of (caller multiplier x trip count);
  * FLOPs: ``dot`` = 2 x |result| x contracted size (operand shapes resolved
    through a per-computation symbol table); elementwise ops weighted
    (transcendentals ~8); ``reduce`` = |operand|;
  * HBM bytes: operand + result bytes of every instruction at a
    *materialization boundary* (instructions inside fusion-called
    computations stay in registers/VMEM and are skipped);
  * collective wire bytes: ring-model per class, x the multiplier of the
    enclosing computation.

All numbers are per device (the HLO is the SPMD-partitioned per-device
module).  Conditional branches are counted in full (upper bound; the hot
paths here are branch-free).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\s*\{")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s([a-z][a-z0-9\-]*)\(")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")

# per-element flop weights for elementwise ops (XLA-cost-analysis-like)
_EW1 = ("add", "subtract", "multiply", "maximum", "minimum", "negate", "abs",
        "and", "or", "xor", "not", "compare", "select", "clamp", "sign",
        "floor", "ceil", "round-nearest-afz", "round-nearest-even",
        "shift-left", "shift-right-logical", "shift-right-arithmetic")
_EW4 = ("divide", "remainder", "sqrt", "rsqrt", "cbrt")
_EW8 = ("exponential", "exponential-minus-one", "log", "log-plus-one",
        "tanh", "logistic", "power", "atan2", "sine", "cosine", "tan",
        "erf", "expm1", "log1p")
_SKIP_BYTES = ("parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "while", "conditional", "after-all", "token",
               "opt-barrier", "partition-id", "replica-id", "call")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shapes(segment: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(segment):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result: list                 # [(dtype, dims)]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symbols: dict                # instr name -> result shapes


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(2), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, type_seg, opcode = mi.group(1), mi.group(2), mi.group(3)
        res = _shapes(type_seg)
        ins = Instr(name, opcode, res, line.strip())
        cur.instrs.append(ins)
        cur.symbols[name] = res
    return comps


def _multipliers(comps: Dict[str, Computation],
                 entry: str) -> Dict[str, float]:
    """Execution count per computation via the call graph."""
    edges: Dict[str, list] = {c: [] for c in comps}   # caller -> [(callee, w)]
    for cname, comp in comps.items():
        for ins in comp.instrs:
            line = ins.line
            if ins.opcode == "while":
                trips = 1.0
                mt = _TRIP_RE.search(line)
                if mt:
                    trips = float(mt.group(1))
                mb = _BODY_RE.search(line)
                mc = _COND_RE.search(line)
                if mb:
                    edges[cname].append((mb.group(1), trips))
                if mc:
                    edges[cname].append((mc.group(1), trips + 1.0))
            elif ins.opcode == "conditional":
                mbr = _BRANCH_RE.search(line)
                if mbr:
                    for ref in _OPERAND_RE.findall(mbr.group(1)):
                        edges[cname].append((ref, 1.0))
            elif ins.opcode == "call":
                # a real call region (CPU thunks wrap parallel loop bodies
                # this way: call(...), to_apply=%parallel_...) — unlike the
                # to_apply of reduce/sort/scatter, which stays a combiner
                mapply = _TO_APPLY_RE.search(line)
                if mapply:
                    edges[cname].append((mapply.group(1), 1.0))
            else:
                mcall = _CALLS_RE.search(line)
                if mcall:
                    edges[cname].append((mcall.group(1), 1.0))
                # NOTE: to_apply= (reduce/sort/scatter/all-reduce combiners)
                # is deliberately NOT an edge; those regions are per-element
                # combiners whose cost is approximated at the call site.

    mult = {c: 0.0 for c in comps}
    if entry in mult:
        mult[entry] = 1.0
    # fixpoint (call graph is a DAG; bounded by #comps iterations)
    for _ in range(len(comps) + 1):
        changed = False
        new = {c: 0.0 for c in comps}
        new[entry] = 1.0
        for caller, outs in edges.items():
            for callee, w in outs:
                if callee in new:
                    new[callee] += mult.get(caller, 0.0) * w
        for c in comps:
            if abs(new[c] - mult[c]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return mult


def _instr_flops(ins: Instr, comp: Computation) -> float:
    op = ins.opcode
    if op == "dot":
        res_elems = _elems_of(ins.result)
        mlhs = _LHS_CONTRACT_RE.search(ins.line)
        # operand list: first %ref inside the parens after the opcode
        paren = ins.line.split(f" {op}(", 1)[1]
        refs = _OPERAND_RE.findall(paren.split(")", 1)[0])
        k = 1
        if mlhs and refs:
            lhs_shape = comp.symbols.get(refs[0])
            if lhs_shape:
                dims = lhs_shape[0][1]
                for idx in (int(i) for i in mlhs.group(1).split(",")
                            if i != ""):
                    if idx < len(dims):
                        k *= dims[idx]
        return 2.0 * res_elems * k
    if op == "convolution":
        return 2.0 * _elems_of(ins.result) * 8.0   # coarse (unused here)
    if op in ("reduce", "reduce-window"):
        return float(_elems_of(ins.result)) * 4.0  # combiner per elem (est.)
    if op in _EW1:
        return float(_elems_of(ins.result))
    if op in _EW4:
        return 4.0 * _elems_of(ins.result)
    if op in _EW8:
        return 8.0 * _elems_of(ins.result)
    return 0.0


def _operand_refs(ins: Instr) -> list:
    if f" {ins.opcode}(" not in ins.line:
        return []
    paren = ins.line.split(f" {ins.opcode}(", 1)[1]
    return _OPERAND_RE.findall(paren.split(")", 1)[0])


def _slice_param_bytes(fusion_comp: Computation) -> dict:
    """For a fusion computation: parameter index -> effective read bytes when
    that parameter is consumed ONLY by dynamic-slice ops (hardware reads the
    slice, not the buffer — charging the full operand would bill a layer-scan
    for the whole stacked parameter array on every trip)."""
    out = {}
    params = {}
    for ins in fusion_comp.instrs:
        if ins.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.line)
            if m:
                params[ins.name] = int(m.group(1))
    for pname, pidx in params.items():
        consumers = [i for i in fusion_comp.instrs
                     if i.opcode != "parameter"
                     and pname in _operand_refs(i)]
        if consumers and all(c.opcode in ("dynamic-slice",
                                          "dynamic-update-slice")
                             for c in consumers):
            bytes_eff = 0
            for c in consumers:
                if c.opcode == "dynamic-slice":
                    bytes_eff += _bytes_of(c.result)
                else:
                    # DUS reads the update operand; the buffer itself is
                    # written in place (charged via the result at the
                    # boundary — approximate the touched region by the
                    # update operand's size)
                    refs = _operand_refs(c)
                    upd = fusion_comp.symbols.get(refs[1]) if len(refs) > 1 \
                        else None
                    bytes_eff += _bytes_of(upd) if upd else 0
            out[pidx] = bytes_eff
    return out


def _instr_bytes(ins: Instr, comp: Computation,
                 comps: Dict[str, Computation]) -> int:
    op = ins.opcode
    if op in _SKIP_BYTES or op.startswith("rng"):
        return 0
    refs = _operand_refs(ins)

    if op == "dynamic-slice":
        return 2 * _bytes_of(ins.result)          # read slice + write result
    if op == "dynamic-update-slice":
        # read + write the updated region only (in-place on the buffer)
        upd = comp.symbols.get(refs[1]) if len(refs) > 1 else None
        return 2 * _bytes_of(upd) if upd else _bytes_of(ins.result)
    if op in ("gather", "scatter"):
        return 2 * _bytes_of(ins.result)

    total = _bytes_of(ins.result)
    slice_map = {}
    if op == "fusion":
        m = _CALLS_RE.search(ins.line)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None:
            slice_map = _slice_param_bytes(callee)
        # in-place DUS fusions: result buffer aliases the sliced operand,
        # so the write is the update region, not the whole buffer
        if callee is not None and any(
                i.opcode == "dynamic-update-slice" for i in callee.instrs):
            dus_bytes = sum(
                _bytes_of(callee.symbols.get(_operand_refs(i)[1], []))
                for i in callee.instrs
                if i.opcode == "dynamic-update-slice"
                and len(_operand_refs(i)) > 1)
            if dus_bytes:
                total = min(total, dus_bytes)
    for pos, r in enumerate(refs):
        if pos in slice_map:
            total += slice_map[pos]
            continue
        sh = comp.symbols.get(r)
        if sh:
            total += _bytes_of(sh)
    return total


def _collective_wire(ins: Instr) -> Tuple[str, float]:
    op = ins.opcode
    base = None
    for c in _COLL_OPS:
        if op == c or op == c + "-start":
            base = c
            break
    if base is None:
        return "", 0.0
    rb = _bytes_of([s for s in ins.result if s[1] or s[0] != "u32"])
    if op.endswith("-start"):
        # async start result repeats the operand tuple; halve to the payload
        rb = rb / 2.0
    if base == "collective-permute":
        # permutes carry source_target_pairs (no replica_groups); every
        # device sends + receives exactly its payload
        return base, float(rb)
    g = 0
    m = _GROUPS_IOTA_RE.search(ins.line)
    if m:
        g = int(m.group(2))
    else:
        m = _GROUPS_LIST_RE.search(ins.line)
        if m:
            g = len([t for t in m.group(1).split(",") if t.strip()])
    if g <= 1:
        return base, 0.0
    if base == "all-gather":
        wire = rb * (g - 1) / g
    elif base == "all-reduce":
        wire = 2.0 * rb * (g - 1) / g
    elif base == "reduce-scatter":
        wire = rb * (g - 1)
    elif base == "all-to-all":
        wire = rb * (g - 1) / g
    else:
        wire = float(rb)
    return base, wire


VMEM_MARKER = "PALLAS_VMEM_REGION"


def analyze(hlo: str, vmem_marker: str = VMEM_MARKER) -> dict:
    """Full per-device cost: flops, hbm bytes, collective wire bytes.

    Instructions whose metadata carries ``vmem_marker`` model a region that
    deploys as a Pallas kernel on TPU (VMEM-resident intermediates): their
    FLOPs count normally but their HBM bytes are zero — boundary tensors are
    charged by the producing/consuming ops outside the region.  (The CPU
    dry-run cannot lower Mosaic custom-calls, so kernel-fused regions are
    marked with ``jax.named_scope`` instead; the kernels themselves are
    validated in interpret mode against their ref.py oracles.)"""
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                entry = m.group(2)
            break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult = _multipliers(comps, entry)

    # a computation may be fusion-called (register-resident) AND also be a
    # while body (materializing): classify by how it is referenced
    fusion_called = set()
    for comp in comps.values():
        for ins in comp.instrs:
            m = _CALLS_RE.search(ins.line)
            if m and ins.opcode == "fusion":
                fusion_called.add(m.group(1))

    flops = 0.0
    hbm = 0.0
    coll = {op: 0.0 for op in _COLL_OPS}
    coll_counts = {op: 0 for op in _COLL_OPS}
    dot_flops = 0.0
    for cname, comp in comps.items():
        k = mult.get(cname, 0.0)
        if k == 0.0:
            continue
        boundary = cname not in fusion_called
        for ins in comp.instrs:
            f = _instr_flops(ins, comp)
            flops += k * f
            if ins.opcode == "dot":
                dot_flops += k * f
            if boundary and vmem_marker not in ins.line:
                hbm += k * _instr_bytes(ins, comp, comps)
            base, wire = _collective_wire(ins)
            if base and wire:
                coll[base] += k * wire
                coll_counts[base] += 1
    return {
        "flops": flops,
        "dot_flops": dot_flops,
        "hbm_bytes": hbm,
        "collectives": dict(coll, counts=coll_counts,
                            total=sum(coll.values())),
    }


def analyze_file(path: str) -> dict:
    with open(path) as f:
        return analyze(f.read())


if __name__ == "__main__":
    import sys

    print(json.dumps(analyze_file(sys.argv[1]), indent=1))
