"""Assigned input shapes and abstract input specs for the dry-run.

Four LM shapes (seq_len x global_batch), each mapping to a lowering target:

  train_4k     (4096, 256)   -> train_step
  prefill_32k  (32768, 32)   -> prefill step (full-prompt forward + cache)
  decode_32k   (32768, 128)  -> decode step (1 new token, seq_len-deep cache)
  long_500k    (524288, 1)   -> decode step; SUB-QUADRATIC ONLY (zamba2-7b,
                                xlstm-1.3b) — full-attention archs are
                                recorded as skipped (DESIGN.md §5)

``input_specs`` returns weak-type-correct ShapeDtypeStructs (with shardings
when given rules) for every model input — no device allocation; the dry-run
lowers against them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.shardings import MeshRules
from repro.models import model as M
from repro.models.config import ArchConfig

SUBQUADRATIC = ("zamba2-7b", "xlstm-1.3b")

# Default gradient-accumulation factor per arch for the train_4k cell, chosen
# so the stored per-layer residual stream (b_local x seq x d_model x 2B x
# n_layers / accum under full remat) stays within a ~4 GiB budget on the
# (16,16) mesh (b_local = 16).  decode/prefill cells never accumulate.
TRAIN_ACCUM = {
    "stablelm-3b": 4,
    "deepseek-67b": 16,
    "qwen3-0.6b": 2,
    "stablelm-12b": 8,
    "zamba2-7b": 8,
    "seamless-m4t-medium": 2,
    "xlstm-1.3b": 4,
    "phi3.5-moe-42b-a6.6b": 8,
    "deepseek-v2-236b": 8,
    "qwen2-vl-2b": 2,
}


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCase("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCase("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCase("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(supported, reason).  long_500k needs sub-quadratic sequence mixing."""
    if shape == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, ("full-attention architecture: O(S^2) attention at "
                       "S=524288 is intentionally unsupported (DESIGN.md §5)")
    return True, ""


def _sds(shape, dtype, rules: Optional[MeshRules], logical):
    sh = rules.sharding(shape, logical) if rules is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def _frontend_splits(cfg: ArchConfig, case: ShapeCase):
    """(text_len, patch_len, enc_len) for the shape."""
    if cfg.family == "vlm":
        f = min(cfg.frontend_len, case.seq_len // 2)
        return case.seq_len - f, f, 0
    if cfg.family == "audio":
        return case.seq_len, 0, case.seq_len
    return case.seq_len, 0, 0


def train_specs(cfg: ArchConfig, case: ShapeCase,
                rules: Optional[MeshRules] = None) -> dict:
    b = case.global_batch
    s_txt, f, enc = _frontend_splits(cfg, case)
    batch = {
        "tokens": _sds((b, s_txt), jnp.int32, rules, ("batch", "seq")),
        "labels": _sds((b, s_txt), jnp.int32, rules, ("batch", "seq")),
    }
    if f:
        batch["patches"] = _sds((b, f, cfg.d_model), jnp.float32, rules,
                                ("batch", "seq", "d_model"))
    if enc:
        batch["frames"] = _sds((b, enc, cfg.d_model), jnp.float32, rules,
                               ("batch", "seq", "d_model"))
    return batch


def prefill_specs(cfg: ArchConfig, case: ShapeCase,
                  rules: Optional[MeshRules] = None) -> dict:
    return train_specs(cfg, case, rules)  # same inputs; labels are ignored


def decode_specs(cfg: ArchConfig, case: ShapeCase,
                 rules: Optional[MeshRules] = None) -> dict:
    b, s = case.global_batch, case.seq_len
    enc = s if cfg.family == "audio" else 0
    cache = M.cache_spec(cfg, b, s, rules, enc_len=enc)
    tokens = _sds((b, 1), jnp.int32, rules, ("batch", None))
    return {"cache": cache, "tokens": tokens}


def input_specs(cfg: ArchConfig, shape: str,
                rules: Optional[MeshRules] = None) -> dict:
    """All abstract inputs for (arch x shape); raises on unsupported cells."""
    case = SHAPES[shape]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape}: {why}")
    if case.kind == "train":
        return {"batch": train_specs(cfg, case, rules)}
    if case.kind == "prefill":
        return {"batch": prefill_specs(cfg, case, rules)}
    return decode_specs(cfg, case, rules)
