"""Neighbor-list construction for the Ahmad-Cohen block scheme.

The Ahmad-Cohen split (``--sources neighbor``) evaluates each target
block's *regular* (near) force against a small gathered window of source
blocks at every event, and refreshes the *irregular* (far) remainder on a
slower power-of-two level.  This module builds those windows:

* :func:`block_bounds` / :func:`block_spheres` — per-block axis-aligned
  bounding box / bounding sphere (validity-masked) over the contiguous
  index blocks the kernels tile by;
* :func:`build_windows` — the neighbor test itself: source block ``J``
  joins target block ``I``'s window iff the *box-to-box* distance
  between their AABBs is ``<= r``.  The box distance lower-bounds every
  particle-pair distance across the two blocks, so a pair inside the
  neighbor radius is *never* dropped — the Hypothesis property in
  ``tests/test_neighbor.py`` pins exactly this.  Boxes, not spheres: a
  sparse halo block legitimately spans a huge cell, and the sphere test
  ``|c_I - c_J| <= r_I + r_J + r`` would put it in *every* window (its
  radius covers the cluster) even though its box — ORB cells are
  disjoint — comes nowhere near most targets.  Windows are returned as
  a fixed-shape ``(n_blocks_i, n_blocks_j)`` index table whose first
  ``win_cnt[i]`` entries are the selected source blocks in ascending
  order (a stable argsort of the boolean test — deterministic,
  batch-independent);
* :func:`kd_perm` — the entry-point ordering: balanced orthogonal
  recursive bisection (median split on the widest extent), so every
  aligned ``leaf``-row index block is exactly one compact spatial cell.
  The scheme tiles *index* blocks, so spatial locality of contiguous
  rows is what makes the bounding spheres tight; a Morton (Z-order)
  sort (:func:`morton_keys` / :func:`morton_perm`) is kept as the cheap
  alternative, but its contiguous key runs straddle octant jumps — on
  centrally concentrated models (Plummer cores with heavy halos) that
  inflates the median block radius several-fold and the windows with
  it, which is why ORB is the default.  The physics is
  permutation-invariant, and entry points apply the sort once at build
  time (never mid-run — see docs/ensembles.md).

Capacity semantics live in :class:`repro.kernels.ops.CapacityPlan`: the
gathered window is dispatched over the plan's ``source_caps`` schedule
(block-aligned powers of two whose *last* bucket is the full padded
source extent), so a window that outgrows every smaller bucket falls back
to the full all-pairs window — overflow degrades to the exact result,
never to silent truncation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _spread_bits(x: jax.Array) -> jax.Array:
    """Spread the low 10 bits of ``x`` to every third bit (Morton)."""
    x = x & jnp.uint32(0x3FF)
    x = (x | (x << 16)) & jnp.uint32(0xFF0000FF)
    x = (x | (x << 8)) & jnp.uint32(0x0300F00F)
    x = (x | (x << 4)) & jnp.uint32(0x030C30C3)
    x = (x | (x << 2)) & jnp.uint32(0x09249249)
    return x


def morton_keys(pos: jax.Array, valid: jax.Array) -> jax.Array:
    """Morton (Z-order) key per row: 10 bits per axis, quantized in the
    valid rows' bounding box.  Invalid rows key to ``0xFFFFFFFF`` (all
    real keys fit in 30 bits) so a stable sort keeps them last."""
    v = valid[:, None]
    lo = jnp.min(jnp.where(v, pos, jnp.inf), axis=0)
    hi = jnp.max(jnp.where(v, pos, -jnp.inf), axis=0)
    span = jnp.maximum(hi - lo, jnp.asarray(1e-30, pos.dtype))
    q = jnp.clip((pos - lo) / span * 1024.0, 0.0, 1023.0).astype(jnp.uint32)
    key = (_spread_bits(q[:, 0])
           | (_spread_bits(q[:, 1]) << 1)
           | (_spread_bits(q[:, 2]) << 2))
    return jnp.where(valid, key, jnp.uint32(0xFFFFFFFF))


def morton_perm(pos: jax.Array, valid: jax.Array) -> jax.Array:
    """Permutation that Z-orders the valid rows (invalid rows stay last,
    in their original relative order — the stable-sort tie rule)."""
    return jnp.argsort(morton_keys(pos, valid), stable=True)


def kd_perm(pos: jax.Array, valid: jax.Array, *, leaf: int = 32
            ) -> jax.Array:
    """Balanced orthogonal-recursive-bisection (k-d) ordering.

    Recursively halves the row set by the median of its widest coordinate
    extent until every cell holds ``leaf`` rows, and returns the
    permutation that lays the cells out contiguously — so every aligned
    block of ``leaf`` (or any multiple of it) consecutive rows is one
    compact axis-aligned cell.  This is the classic ORB domain
    decomposition of parallel N-body codes, applied to *row order*: the
    neighbor windows test bounding spheres of contiguous index blocks,
    and median splits keep those spheres tight even in the heavy halo of
    a centrally concentrated model (where Morton runs go wide).

    Invalid rows key as ``+inf`` at every split, so they migrate to the
    right half of any cell that contains them and end the recursion as a
    right-aligned suffix in their original relative order — exactly the
    padding layout the engines expect (``arange(n) < n_active``).

    ``leaf`` should divide the kernel block sizes that will tile the
    sorted rows (any divisor keeps blocks cell-aligned); the number of
    bisection levels is static, derived from ``ceil(n / leaf)``.
    """
    n = pos.shape[0]
    depth = 0
    while leaf << depth < n:
        depth += 1
    p2 = leaf << depth
    pp = jnp.pad(pos, ((0, p2 - n), (0, 0)))
    vv = jnp.pad(valid, (0, p2 - n))
    order = jnp.arange(p2, dtype=jnp.int32)
    for level in range(depth):
        cells = order.reshape(1 << level, -1)
        cp, cv = pp[cells], vv[cells]
        v3 = cv[..., None]
        lo = jnp.min(jnp.where(v3, cp, jnp.inf), axis=1)
        hi = jnp.max(jnp.where(v3, cp, -jnp.inf), axis=1)
        ext = jnp.where(jnp.any(cv, axis=1)[:, None], hi - lo, 0.0)
        dim = jnp.argmax(ext, axis=1)
        key = jnp.take_along_axis(cp, dim[:, None, None], axis=2)[..., 0]
        key = jnp.where(cv, key, jnp.inf)
        cperm = jnp.argsort(key, axis=1, stable=True)
        order = jnp.take_along_axis(cells, cperm, axis=1).reshape(-1)
    return order[:n]


def block_spheres(pos: jax.Array, valid: jax.Array, block: int):
    """Bounding sphere of every contiguous ``block``-row index block.

    Centers and radii are weighted by the validity mask so zero-position
    padding rows never inflate a sphere; a block with no valid rows gets
    a zero-radius sphere at the origin and count 0 (callers must exclude
    empty blocks from the neighbor test — :func:`build_windows` does).

    Returns ``(centers (nb, 3), radii (nb,), counts (nb,) int32)``.
    """
    n = pos.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    p = jnp.pad(pos, ((0, pad), (0, 0))).reshape(nb, block, 3)
    w = jnp.pad(valid, ((0, pad),)).reshape(nb, block)
    cnt = jnp.sum(w, axis=1).astype(jnp.int32)
    wf = w[..., None].astype(p.dtype)
    c = jnp.sum(p * wf, axis=1) / jnp.maximum(cnt, 1)[:, None]
    r = jnp.max(jnp.where(w, jnp.linalg.norm(p - c[:, None, :], axis=-1),
                          jnp.asarray(0.0, p.dtype)), axis=1)
    return c, r, cnt


def block_bounds(pos: jax.Array, valid: jax.Array, block: int):
    """Axis-aligned bounding box of every contiguous ``block``-row block.

    Returns ``(lo (nb, 3), hi (nb, 3), counts (nb,) int32)``.  A block
    with no valid rows gets an inverted box (``lo = +inf, hi = -inf``)
    whose distance to anything is ``+inf`` — naturally never a neighbor.
    """
    n = pos.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    p = jnp.pad(pos, ((0, pad), (0, 0))).reshape(nb, block, 3)
    w = jnp.pad(valid, ((0, pad),)).reshape(nb, block)[..., None]
    lo = jnp.min(jnp.where(w, p, jnp.inf), axis=1)
    hi = jnp.max(jnp.where(w, p, -jnp.inf), axis=1)
    cnt = jnp.sum(w[..., 0], axis=1).astype(jnp.int32)
    return lo, hi, cnt


def build_windows(pos: jax.Array, valid: jax.Array, *, block_i: int,
                  block_j: int, radius: float):
    """Per-target-block neighbor windows over the source blocks.

    Source block ``J`` is selected for target block ``I`` iff the
    distance between their bounding boxes is ``<= radius``.  The box
    distance lower-bounds the distance of every particle pair across the
    two blocks, so every pair within ``radius`` is covered; unlike the
    bounding-sphere test it stays tight when block cells are large but
    disjoint (a sparse halo shell next to a dense core).  Blocks with no
    valid rows are never selected — their boxes are inverted, at
    ``+inf`` distance from everything — and an empty *target* block
    selects nothing (it must not widen the shared capacity bucket).

    Returns ``(win_idx (nbt, nsb) int32, win_cnt (nbt,) int32)``:
    ``win_idx[i, :win_cnt[i]]`` are the selected source blocks in
    ascending order; the remaining entries are the unselected blocks
    (also ascending) so every prefix of the row is a valid gather index.
    """
    tlo, thi, tcnt = block_bounds(pos, valid, block_i)
    slo, shi, scnt = block_bounds(pos, valid, block_j)
    zero = jnp.zeros((), pos.dtype)
    gap = jnp.maximum(jnp.maximum(slo[None, :] - thi[:, None],
                                  tlo[:, None] - shi[None, :]), zero)
    d = jnp.linalg.norm(gap, axis=-1)
    nbr = d <= jnp.asarray(radius, d.dtype)
    nbr &= (scnt > 0)[None, :] & (tcnt > 0)[:, None]
    win_cnt = jnp.sum(nbr, axis=1).astype(jnp.int32)
    win_idx = jnp.argsort(~nbr, axis=1, stable=True).astype(jnp.int32)
    return win_idx, win_cnt
