"""Pallas TPU flash-attention kernel (grouped-query, causal).

The train_4k / prefill_32k roofline is HBM-bound on attention: the XLA path
materializes (Sq, Sk) fp32 score tensors in HBM (~5 passes per layer).  This
kernel keeps the whole running-softmax state in VMEM — HBM traffic collapses
to the q/k/v/o tensors themselves, which is the memory-term fix identified in
EXPERIMENTS.md §Perf.

Layout (one (batch x kv-head) slab per grid row):
    q   : (B*KV, Sq, G*D)  — G = query heads per kv head, folded into lanes
    k   : (B*KV, Sk, D)
    v   : (B*KV, Sk, D)
    out : (B*KV, Sq, G*D)

Grid: (B*KV, Sq/BQ, Sk/BK) — the Sk axis is innermost, so the (m, l, acc)
running-softmax state lives in VMEM scratch across the KV sweep; BlockSpec
index maps stream K/V blocks while the q block stays resident (the paper's
resident-target / streamed-source schedule, DESIGN.md §2).  Causal masking
skips fully-masked KV blocks via ``pl.when`` on the block indices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  groups: int, head_dim: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # block (qi, ki) is live unless strictly above the diagonal
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _step():
        q = q_ref[0]                                   # (BQ, G*D)
        k = k_ref[0]                                   # (BK, D)
        v = v_ref[0]                                   # (BK, D)
        bq = q.shape[0]
        qg = q.reshape(bq, groups, head_dim)
        s = jax.lax.dot_general(
            qg, k, (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (BQ, G, BK)
        s = s * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (bq, groups, k.shape[0]), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (bq, groups, k.shape[0]), 2)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]                            # (BQ, G)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])              # (BQ, G, BK)
        l_ref[...] = l_prev * alpha + p.sum(axis=2)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (BQ, G, D)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + pv

    @pl.when(ki == nk - 1)
    def _finish():
        bq = acc_ref.shape[0]
        lsum = jnp.maximum(l_ref[...], 1e-30)[..., None]
        out = (acc_ref[...] / lsum).reshape(bq, groups * head_dim)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """Grouped-query flash attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D) with H % KV == 0.
    Returns (B, Sq, H, D) in q.dtype.  Sq % block_q == Sk % block_k == 0.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = d ** -0.5

    # fold (B, KV) into the grid's slab axis; queries carry G heads in lanes
    qs = q.reshape(b, sq, kv, g * d).transpose(0, 2, 1, 3).reshape(
        b * kv, sq, g * d)
    ks = k.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)
    vs = v.transpose(0, 2, 1, 3).reshape(b * kv, sk, d)

    grid = (b * kv, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, groups=g, head_dim=d)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, g * d), lambda s, i, j: (s, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda s, i, j: (s, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda s, i, j: (s, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, g * d), lambda s, i, j: (s, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kv, sq, g * d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, g), jnp.float32),       # running max m
            pltpu.VMEM((block_q, g), jnp.float32),       # running sum l
            pltpu.VMEM((block_q, g, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qs, ks, vs)
    return out.reshape(b, kv, sq, g, d).transpose(0, 2, 1, 3, 4).reshape(
        b, sq, h, d)
