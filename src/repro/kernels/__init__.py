from repro.kernels import nbody_force, ops, ref  # noqa: F401
