"""Public jit'd wrappers around the N-body force kernels.

These functions own the (un)packing between the physics-facing layout
(pos/vel/mass arrays, arbitrary N, any float dtype) and the kernel's packed,
block-padded FP32 layout. They dispatch to

* the Pallas TPU kernel (``nbody_force.py``) — compiled on TPU, interpreted
  (``interpret=True``) on CPU for validation, or
* a pure-XLA blocked fallback (``impl="xla"``) — used inside the multi-device
  strategies and the dry-run, where the CPU backend cannot lower Mosaic.

The primitive contract is *rectangular*: a set of N_t targets against a set
of N_s sources (the paper's "i-particles" x "j-particles"). Symmetric
all-pairs is the special case targets == sources; a target that also appears
in the source set self-cancels via the softened-zero-distance guard.

Mixed precision follows the paper: evaluation in FP32, caller keeps FP64
state.

**Mask contract** (tested by ``tests/test_padding_invariance.py``): a source
row with m = 0 contributes *exactly zero* acceleration, jerk, snap and
potential to every target — so callers may freely pad the source set with
zero-mass particles (block alignment here, device-count alignment in
``core.strategies``, ragged-N batches in ``sim.scenarios.build_padded``)
and the active particles' results stay invariant up to FP32 summation
order.

**Target-activity mask** (block timesteps): the rect wrappers take an
optional ``mask_t`` over targets — inactive rows return exact zeros, sources
stay full, and the Pallas kernel skips fully-inactive i-blocks via
``pl.when``.  ``mask_t=None`` is the all-active identity.

**vmap safety**: every wrapper is a pure shape-polymorphic function of its
array arguments, and ``pallas_call`` batches by prepending a grid dimension,
so ``jax.vmap`` lifts both the XLA fallback and the Pallas kernel (compiled
or interpreted) over a leading batch axis — the ensemble engine's path.
"""

from __future__ import annotations

import bisect
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import nbody_force, ref

_PAD_COLS = 8
IMPLS = ("pallas", "pallas_interpret", "xla", "pallas_marked")
# pallas_marked: ref math inside a PALLAS_VMEM_REGION named scope — the
# dry-run cost model for the deployed Pallas kernel (Mosaic cannot lower on
# the CPU dry-run host; hlo_analysis applies VMEM-fusion semantics to the
# marked region, and the kernel itself is interpret-validated in tests).

# The precision axis (--dtype): fp64 = the oracle path in core.evaluate,
# fp32 = the historical kernel path, mixed = fp32 I/O with reduced-precision
# per-pair arithmetic and compensated fp32 accumulation (the Tensix
# unpack-fp32 / compute-reduced / pack-fp32 datapath).
DTYPES = ("fp64", "fp32", "mixed")
_COMPUTE_DTYPE = {"fp32": None, "mixed": "bfloat16"}
_IO_BYTES = {"fp64": 8, "fp32": 4, "mixed": 4}
_COMPUTE_BYTES = {"fp64": 8, "fp32": 4, "mixed": 2}

# The source axis (--sources): "full" sweeps every launch over the complete
# source extent (the historical all-pairs path, bit-identical to before the
# axis existed); "neighbor" is the Ahmad-Cohen split — each target block
# sweeps only its gathered neighbor window of source blocks at every event,
# with the far-field remainder refreshed on a slower level (see
# kernels/neighbor.py and docs/ensembles.md "Neighbor scheme").
SOURCES = ("full", "neighbor")


def compute_dtype_for(dtype: str):
    """Kernel compute dtype for a precision-axis name (None = full fp32).

    ``mixed`` uses bfloat16 rather than fp16: the pairwise ``m_j / d^3``
    term overflows fp16's 65504 max on softened close encounters, while
    bf16 keeps fp32's exponent range — the reduced-*mantissa* half of the
    Tensix pattern is what changes the arithmetic.  ``fp64`` never reaches
    the packed kernels; ``core.evaluate``'s oracle branch owns it.
    """
    try:
        return _COMPUTE_DTYPE[dtype]
    except KeyError:
        raise ValueError(
            f"kernel dtype must be 'fp32' or 'mixed' (fp64 runs the oracle "
            f"path in core.evaluate); got {dtype!r}") from None


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pack_targets(pos, vel, n_pad: int, mask=None):
    """(N,3)x2 -> (n_pad, 8) target block [x y z act vx vy vz 0].

    Column 3 (the slot sources use for mass) carries the target **activity
    mask**: 1.0 = evaluate this row, 0.0 = skip (the kernel scales the row's
    output by it and skips fully-inactive i-blocks).  ``mask=None`` means all
    targets active; block-alignment padding rows are always inactive.
    """
    n = pos.shape[0]
    f32 = jnp.float32
    act = jnp.ones((n,), f32) if mask is None else jnp.asarray(mask, f32)
    cols = [
        pos[:, 0], pos[:, 1], pos[:, 2], act,
        vel[:, 0], vel[:, 1], vel[:, 2], jnp.zeros((n,), f32),
    ]
    tgt = jnp.stack([jnp.asarray(c, f32) for c in cols], axis=1)
    return jnp.pad(tgt, ((0, n_pad - n), (0, 0)))


def pack_sources(pos, vel, mass, n_pad: int):
    """(N,3)x2 + (N,) -> (8, n_pad) source block [x y z m vx vy vz 0] rows."""
    n = pos.shape[0]
    f32 = jnp.float32
    rows = [
        pos[:, 0], pos[:, 1], pos[:, 2], mass,
        vel[:, 0], vel[:, 1], vel[:, 2], jnp.zeros((n,), f32),
    ]
    src = jnp.stack([jnp.asarray(r, f32) for r in rows], axis=0)
    return jnp.pad(src, ((0, 0), (0, n_pad - n)))


def pack_acc_targets(acc, n_pad: int):
    a = jnp.pad(jnp.asarray(acc, jnp.float32), ((0, n_pad - acc.shape[0]), (0, _PAD_COLS - 3)))
    return a


def pack_acc_sources(acc, n_pad: int):
    a = jnp.pad(
        jnp.asarray(acc, jnp.float32).T, ((0, _PAD_COLS - 3), (0, n_pad - acc.shape[0]))
    )
    return a


def _mask_rows(mask_t, *arrays):
    """Zero the rows of each array where the target mask is inactive."""
    m = jnp.asarray(mask_t, arrays[0].dtype)
    return tuple(a * (m[:, None] if a.ndim == 2 else m) for a in arrays)


# --------------------------------------------------------------------------
# active-target compaction (gather/scatter around the rect kernels)
# --------------------------------------------------------------------------
# The block-timestep engine's activity mask lets the kernels *skip* inactive
# i-blocks, but the grid is still launched at the full N/BI target extent.
# Compaction converts the skipped work into launches that never happen:
# gather the active targets into a dense, block-aligned buffer of one of a
# few static capacities, run the rect kernels on a ceil(cap/BI) x N/BJ grid
# (sources stay full, so physics is unchanged), and scatter the outputs back
# to their particle slots.  Every per-target output row is a row-local sum
# over the same source blocks in the same order, so the compacted result is
# bit-for-bit the masked dense result (tests/test_compaction.py).


def capacity_buckets(n: int, block_i: int) -> tuple:
    """Static capacity schedule for ``n`` targets: block-aligned powers of
    two ``(BI, 2*BI, 4*BI, ..., ceil(n/BI)*BI)``.

    Each event picks the smallest bucket holding its active count (see
    :func:`bucket_index`) and dispatches via ``lax.switch`` over kernels
    pre-lowered at these sizes — XLA only ever sees static target extents.
    """
    n_pad = _round_up(n, block_i)
    caps = []
    c = block_i
    while c < n_pad:
        caps.append(c)
        c *= 2
    caps.append(n_pad)
    return tuple(caps)


def bucket_index(n_active, caps) -> jax.Array:
    """Index of the smallest capacity bucket with ``caps[i] >= n_active``.

    ``n_active`` may be traced; ``caps`` is the static ascending schedule
    from :func:`capacity_buckets` (its last entry is ``>= n``, so the result
    is always in range — buckets can never underestimate the active count).
    """
    return jnp.searchsorted(jnp.asarray(caps, jnp.int32),
                            jnp.asarray(n_active, jnp.int32), side="left")


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Static capacity-bucket plan for one compacted launch extent.

    The original compaction layer kept its schedule as a bare tuple computed
    at each call site; distributing compaction turns the schedule into a
    *plan*: the dense target extent being compacted (the full ``N`` on one
    device, the local ``N/P`` inside a shard), the source extent every launch
    sweeps, the tile shape, and the pass count travel together, so
    evaluators, engines and telemetry all agree on what one bucket costs.

    ``caps`` defaults to :func:`capacity_buckets` over ``n_targets``;
    :meth:`restrict` truncates it for a bucket *group* whose members can
    never exceed a known active-count ceiling (the per-member dispatch of
    mixed batches), and :meth:`shard` rescales the whole plan to the
    per-shard local extent (the distributed strategies).  The plan is
    hashable, so it can key lowering caches and ride as a static argument.

    ``n_passes`` counts the kernel launches one event performs at the chosen
    capacity: 2 for the 6th-order Hermite scheme's acc/jerk + snap sweeps
    over resident sources, ``2 * P`` for the ring strategy, whose every pass
    launches once per streamed source shard.
    """

    n_targets: int
    n_sources: int
    block_i: int
    block_j: int
    n_passes: int = 2
    caps: tuple = ()
    dtype: str = "fp32"
    sources: str = "full"

    def __post_init__(self):
        if self.dtype not in DTYPES:
            raise ValueError(
                f"plan dtype must be one of {DTYPES}, got {self.dtype!r}")
        if self.sources not in SOURCES:
            raise ValueError(
                f"plan sources must be one of {SOURCES}, got {self.sources!r}")
        if not self.caps:
            object.__setattr__(
                self, "caps", capacity_buckets(self.n_targets, self.block_i))

    @property
    def io_bytes_per_element(self) -> int:
        """Bytes per staged element (HBM<->VMEM) at this plan's dtype.

        ``mixed`` stages fp32 — the Tensix pattern unpacks/packs fp32 and
        only the in-register arithmetic narrows."""
        return _IO_BYTES[self.dtype]

    @property
    def compute_bytes_per_element(self) -> int:
        """Bytes per in-flight per-pair element at this plan's dtype."""
        return _COMPUTE_BYTES[self.dtype]

    @property
    def tile_io_bytes(self) -> int:
        """Bytes one (i, j) grid tile stages: the (BI, 8) target block and
        (8, BJ) source block in, the (BI, 8) output block out.

        A ``sources="neighbor"`` plan additionally pays the window gather
        per tile: the (8, BJ) source block is read from its resident slot
        and written into the per-target-block gathered window before the
        kernel streams it — the staging cost the Ahmad-Cohen split trades
        for sweeping far fewer j-tiles per event."""
        base = (2 * self.block_i * 8 + 8 * self.block_j) \
            * self.io_bytes_per_element
        if self.sources == "neighbor":
            base += 2 * 8 * self.block_j * self.io_bytes_per_element
        return base

    @property
    def tile_vmem_bytes(self) -> int:
        """Working-set bytes of one (BI, BJ) interaction tile: ~12 live
        per-pair intermediates at the compute width plus the staged blocks
        at the I/O width (the VMEM budget note in ``nbody_force.py``) —
        a ``mixed`` plan's tile fits in roughly half the fp32 footprint,
        which is what lets occupancy rise at fixed VMEM."""
        live = 12 * self.block_i * self.block_j * self.compute_bytes_per_element
        return live + self.tile_io_bytes

    def tiles_per_vmem(self, vmem_bytes: int) -> int:
        """How many interaction tiles a ``vmem_bytes`` budget holds — the
        occupancy headroom the narrower compute width buys."""
        return max(1, vmem_bytes // self.tile_vmem_bytes)

    @property
    def tiles_by_cap(self) -> tuple:
        """Grid tiles one event enqueues at each capacity (all passes)."""
        j_tiles = -(-self.n_sources // self.block_j)
        return tuple((c // self.block_i) * j_tiles * self.n_passes
                     for c in self.caps)

    @property
    def dense_tiles(self) -> int:
        """Tiles of the uncompacted (masked full-extent) launch this plan
        shrinks — the ``compaction="none"`` baseline."""
        return (nbody_force.grid_tiles(self.n_targets, self.n_sources,
                                       self.block_i, self.block_j)
                * self.n_passes)

    def bucket(self, n_active) -> jax.Array:
        """Traced index of the smallest bucket holding ``n_active``."""
        return bucket_index(n_active, self.caps)

    def tiles(self, idx) -> jax.Array:
        """Traced lookup: tiles one event enqueues at bucket ``idx``."""
        return jnp.asarray(self.tiles_by_cap, jnp.int32)[idx]

    # -- the source-extent schedule (the Ahmad-Cohen neighbor windows) -----
    @property
    def source_caps(self) -> tuple:
        """Static *source*-extent schedule, in rows: block_j-aligned powers
        of two up to the padded full source extent — the target-side
        ``caps`` idea applied to the source axis.  The last bucket **is**
        the full window, so a neighbor window that outgrows every smaller
        bucket dispatches the exact all-pairs sweep: overflow falls back to
        the full window, never to silent truncation (the same
        never-underestimate semantics as :func:`bucket_index`)."""
        return capacity_buckets(self.n_sources, self.block_j)

    def source_bucket(self, n_src_rows) -> jax.Array:
        """Traced index of the smallest source bucket holding
        ``n_src_rows`` gathered source rows."""
        return bucket_index(n_src_rows, self.source_caps)

    @property
    def window_tiles_by_cap(self) -> tuple:
        """Grid tiles one *neighbor* event enqueues at each source-window
        capacity (all passes): every target block sweeps its gathered
        window of ``cap / BJ`` source blocks instead of the full j-extent."""
        i_tiles = -(-self.n_targets // self.block_i)
        return tuple(i_tiles * (c // self.block_j) * self.n_passes
                     for c in self.source_caps)

    def window_tiles(self, idx) -> jax.Array:
        """Traced lookup: tiles one neighbor event enqueues at source
        bucket ``idx``."""
        return jnp.asarray(self.window_tiles_by_cap, jnp.int32)[idx]

    def shard(self, n_shards: int) -> "CapacityPlan":
        """The per-shard local plan: each shard compacts its own
        ``n_targets / n_shards`` target rows (the strategies pad to a device
        multiple before sharding, so the split is exact)."""
        if self.n_targets % n_shards:
            raise ValueError(
                f"{self.n_targets} targets do not split over "
                f"{n_shards} shards")
        return dataclasses.replace(
            self, n_targets=self.n_targets // n_shards, caps=())

    def restrict(self, ceiling: int) -> "CapacityPlan":
        """Plan truncated to the buckets a member with at most ``ceiling``
        active targets can ever select — its pre-lowered bucket group.

        A mixed batch groups members by this ceiling (their static
        ``n_active``): each group dispatches over its own shorter schedule,
        so a quiescent small member never lowers — let alone launches — the
        widest member's buckets.

        ``ceiling`` must lie in ``(0, caps[-1]]`` — the same range
        :meth:`admission_cap` enforces.  A ceiling above the top bucket is a
        caller error (the member could exceed every bucket this plan can
        launch), not a request for the full schedule.
        """
        ceiling = int(ceiling)
        if not 0 < ceiling <= self.caps[-1]:
            raise ValueError(
                f"ceiling={ceiling} outside this plan's capacity range "
                f"(0, {self.caps[-1]}]")
        idx = bisect.bisect_left(self.caps, ceiling)
        return dataclasses.replace(self, caps=self.caps[: idx + 1])

    def admission_cap(self, n_active: int) -> int:
        """Host-side capacity ceiling for admitting a run with ``n_active``
        bodies: the top bucket of :meth:`restrict`, i.e. the smallest pod
        extent whose launch schedule the member can never exceed.

        The serving layer's bucket-packing admission keys pods by this value
        — every member of a pod shares one ceiling, so its bucket groups
        (and with them the lowered engine) stay invariant under admit,
        retire and backfill.
        """
        n_active = int(n_active)
        if not 0 < n_active <= self.caps[-1]:
            raise ValueError(
                f"n_active={n_active} outside this plan's capacity range "
                f"(0, {self.caps[-1]}]")
        return self.restrict(n_active).caps[-1]


def compact_targets(perm, cap: int, *rows):
    """Gather the first ``cap`` permuted rows of each per-target array.

    ``perm`` puts active rows first (e.g. ``jnp.argsort(~mask)``), so with
    ``cap >= n_active`` the gathered buffer holds every active target
    followed by inactive fill rows (whose outputs the activity mask zeroes).
    ``cap`` is static — each capacity bucket is its own lowered computation.
    """
    with jax.named_scope(f"obs.compact_gather.cap{cap}"):
        idx = perm[: min(cap, perm.shape[0])]
        return tuple(r[idx] for r in rows)


def scatter_outputs(perm, cap: int, n: int, *outs):
    """Scatter compacted kernel outputs back to their particle slots.

    Rows outside the gathered set stay exactly zero — the same contract as
    the masked dense evaluation (inactive targets return exact zeros), so
    ``scatter_outputs`` after :func:`compact_targets` is the identity on
    active rows and zero elsewhere.
    """
    with jax.named_scope(f"obs.compact_scatter.cap{cap}"):
        idx = perm[: min(cap, perm.shape[0])]
        return tuple(
            jnp.zeros((n,) + o.shape[1:], o.dtype).at[idx].set(o)
            for o in outs
        )


def scatter_sources(perm, cap: int, base, upd, mask_c):
    """Blend compacted pass-1 outputs into a predicted source operand.

    The snap pass needs the acceleration of *every* source at the event
    time: fresh values for the targets the event just evaluated, the
    Taylor-predicted ``base`` rows for everyone else.  Scattering the
    compacted fresh rows (where their compacted activity mask is set)
    straight into ``base`` produces exactly
    ``where(mask, scatter_outputs(upd), base)`` — bit for bit — without
    materializing the dense scattered intermediate: the source-side
    compaction of the snap operand.  Inactive fill rows inside the gathered
    window write their own ``base`` value back, and rows outside the window
    are untouched (an active row is always inside the window when ``cap``
    bounds the active count).
    """
    with jax.named_scope(f"obs.scatter_sources.cap{cap}"):
        idx = perm[: min(cap, perm.shape[0])]
        m = mask_c[:, None] if upd.ndim == 2 else mask_c
        rows = jnp.where(m, upd.astype(base.dtype), base[idx])
        return base.at[idx].set(rows)


@partial(jax.jit,
         static_argnames=("eps", "block_i", "block_j", "impl", "dtype"))
def acc_jerk_pot_rect(
    pos_t, vel_t, pos_s, vel_s, mass_s,
    *,
    mask_t=None,
    eps: float = 1e-7,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    impl: str = "pallas",
    dtype: str = "fp32",
):
    """(acc, jerk, pot) of N_t targets due to N_s sources, FP32 I/O.

    ``mask_t`` (optional ``(N_t,)`` activity mask) restricts evaluation to
    the active *targets* — the block-timestep hot path.  Sources stay full.
    Inactive rows return exact zeros; in the Pallas path a fully-inactive
    i-block skips its compute, in the XLA path the mask zeroes the outputs
    (dense XLA cannot skip, so the saving there is accounting-only).
    ``dtype="mixed"`` narrows the per-pair arithmetic (see
    :func:`compute_dtype_for`) in both the Pallas and XLA paths.
    """
    compute_dtype = compute_dtype_for(dtype)
    if impl in ("xla", "pallas_marked"):
        f32 = jnp.float32
        args = (
            jnp.asarray(pos_t, f32), jnp.asarray(vel_t, f32),
            jnp.asarray(pos_s, f32), jnp.asarray(vel_s, f32),
            jnp.asarray(mass_s, f32),
        )
        if impl == "pallas_marked":
            with jax.named_scope("PALLAS_VMEM_REGION"):
                acc, jerk, pot = ref.acc_jerk_pot_rect(
                    *args, eps=eps, compute_dtype=compute_dtype)
        else:
            acc, jerk, pot = ref.acc_jerk_pot_rect(
                *args, eps=eps, compute_dtype=compute_dtype)
        if mask_t is not None:
            acc, jerk, pot = _mask_rows(mask_t, acc, jerk, pot)
        return acc, jerk, pot
    n_t, n_s = pos_t.shape[0], pos_s.shape[0]
    nt_pad = _round_up(n_t, block_i)
    ns_pad = _round_up(n_s, block_j)
    tgt = pack_targets(pos_t, vel_t, nt_pad, mask_t)
    src = pack_sources(pos_s, vel_s, mass_s, ns_pad)
    out = nbody_force.acc_jerk_pot_packed(
        tgt, src, eps=eps, block_i=block_i, block_j=block_j,
        interpret=(impl == "pallas_interpret"),
        compute_dtype=compute_dtype,
    )[:n_t]
    return out[:, 0:3], out[:, 3:6], out[:, 6]


@partial(jax.jit,
         static_argnames=("eps", "block_i", "block_j", "impl", "dtype"))
def snap_rect(
    pos_t, vel_t, acc_t, pos_s, vel_s, acc_s, mass_s,
    *,
    mask_t=None,
    eps: float = 1e-7,
    block_i: int = nbody_force.DEFAULT_BLOCK_I,
    block_j: int = nbody_force.DEFAULT_BLOCK_J,
    impl: str = "pallas",
    dtype: str = "fp32",
):
    """Snap of N_t targets due to N_s sources (second Hermite pass), FP32 I/O.

    ``mask_t`` restricts the pass to active targets (see
    :func:`acc_jerk_pot_rect`); ``acc_s`` must then hold the *predicted*
    acceleration of inactive sources (the caller blends evaluated/predicted).
    """
    compute_dtype = compute_dtype_for(dtype)
    if impl in ("xla", "pallas_marked"):
        f32 = jnp.float32
        args = (
            jnp.asarray(pos_t, f32), jnp.asarray(vel_t, f32),
            jnp.asarray(acc_t, f32),
            jnp.asarray(pos_s, f32), jnp.asarray(vel_s, f32),
            jnp.asarray(acc_s, f32), jnp.asarray(mass_s, f32),
        )
        if impl == "pallas_marked":
            with jax.named_scope("PALLAS_VMEM_REGION"):
                snp = ref.snap_rect(*args, eps=eps,
                                    compute_dtype=compute_dtype)
        else:
            snp = ref.snap_rect(*args, eps=eps, compute_dtype=compute_dtype)
        if mask_t is not None:
            (snp,) = _mask_rows(mask_t, snp)
        return snp
    n_t, n_s = pos_t.shape[0], pos_s.shape[0]
    nt_pad = _round_up(n_t, block_i)
    ns_pad = _round_up(n_s, block_j)
    tgt = pack_targets(pos_t, vel_t, nt_pad, mask_t)
    src = pack_sources(pos_s, vel_s, mass_s, ns_pad)
    tacc = pack_acc_targets(acc_t, nt_pad)
    sacc = pack_acc_sources(acc_s, ns_pad)
    out = nbody_force.snap_packed(
        tgt, src, tacc, sacc, eps=eps, block_i=block_i, block_j=block_j,
        interpret=(impl == "pallas_interpret"),
        compute_dtype=compute_dtype,
    )
    return out[:n_t, 0:3]


def acc_jerk_pot(pos, vel, mass, **kw):
    """Symmetric all-pairs (targets == sources) convenience wrapper."""
    return acc_jerk_pot_rect(pos, vel, pos, vel, mass, **kw)


def snap(pos, vel, acc, mass, **kw):
    """Symmetric all-pairs snap convenience wrapper."""
    return snap_rect(pos, vel, acc, pos, vel, acc, mass, **kw)


def default_impl() -> str:
    """Pallas kernels only lower on TPU; interpret everywhere else."""
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"
