"""Pallas TPU kernel for the all-pairs N-body force evaluation.

TPU adaptation of the paper's Tensix read/compute/write pipeline (DESIGN.md §2):

* The paper stages particle tiles through circular buffers between dedicated
  data-movement and compute RISC-V cores.  Here the same producer/consumer
  overlap is expressed by the Pallas grid pipeline: ``BlockSpec`` index maps
  describe which (i-block, j-block) of particle data each grid step consumes,
  and Mosaic double-buffers the HBM->VMEM DMAs against the VPU compute.
* The paper replicates every scalar 1024x so the Tensix tile engine can act on
  it.  TPUs broadcast natively, so we store each particle ONCE in a packed
  struct-of-arrays layout and broadcast inside the kernel (DESIGN.md §2.1):

      tgt  : (N, 8)  rows = target particles,  cols = [x y z act vx vy vz pad]
      src  : (8, N)  rows = [x y z m vx vy vz pad], cols = source particles
      out  : (N, 8)  cols = [ax ay az jx jy jz pot pad]

  Column 3 of the target block is the **activity mask** (1.0 = evaluate this
  target; ``ops.pack_targets`` writes all-ones when no mask is given, 0.0 on
  its alignment padding).  The block-timestep engine uses it to evaluate
  forces only *on* the currently active block of targets while sources stay
  full: each output row is scaled by its activity flag, and an i-block whose
  targets are all inactive skips its compute entirely via ``pl.when`` — the
  Tensix analogue would be the host simply not enqueueing that tile.

  A ``(BI, 8)`` target block meets an ``(8, BJ)`` source block and the whole
  (BI, BJ) interaction tile lives in VMEM registers/vregs.
* Accumulation runs along the source (j) grid axis, which is the innermost
  grid dimension, so the output block stays resident in VMEM across the sweep
  — the same "accumulate along the row direction" schedule as the paper's
  Fig. 2, without the dst-register acquire/release dance (VMEM is the staging
  buffer and Mosaic schedules the reuse).

The snap kernel is the second evaluation pass of the 6th-order Hermite scheme
and additionally consumes the pass-1 accelerations of both partners:

      tgt_acc : (N, 8) cols = [ax ay az pad...]
      src_acc : (8, N) rows = [ax ay az pad...]
      out     : (N, 8) cols = [sx sy sz pad...]

All math is FP32 (the paper's SFPU precision); padding particles carry m = 0
so they contribute exactly zero: every output term (acc, jerk, snap, pot) is
a sum over source columns of ``m_j * f(...)`` with ``f`` finite under the
zero-distance guard, so an m = 0 column is exactly annihilated.  This is the
mask contract that lets ``core.strategies`` pad to block multiples and
``sim.scenarios.build_padded`` pack ragged-N ensembles (tested by
``tests/test_padding_invariance.py``).

The kernel is also ``jax.vmap``-safe — batching a ``pallas_call`` prepends
grid dimensions (and the interpreter follows the same rule), which is how
``repro.sim.ensemble`` evaluates B stacked runs in one call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default interaction-block shape.  VMEM working set is ~12 live (BI, BJ)
# fp32 tensors: 12 * 256 * 512 * 4 B ~= 6.3 MB, comfortably inside the 16 MB
# VMEM of a v5e core with room for the double-buffered input blocks.
# BJ is lane-aligned (multiple of 128), BI sublane-aligned (multiple of 8).
DEFAULT_BLOCK_I = 256
DEFAULT_BLOCK_J = 512

_X, _Y, _Z, _M, _VX, _VY, _VZ = 0, 1, 2, 3, 4, 5, 6
_ACT = _M  # target blocks carry the activity mask in the (unused) mass slot


def _geometry(tgt, src, eps):
    """Pairwise displacement + softened inverse-distance for one block pair."""
    f32 = jnp.float32
    xi, yi, zi = (tgt[:, k : k + 1] for k in (_X, _Y, _Z))    # (BI, 1)
    xj, yj, zj = (src[k : k + 1, :] for k in (_X, _Y, _Z))    # (1, BJ)
    dx = xj - xi
    dy = yj - yi
    dz = zj - zi
    r2 = dx * dx + dy * dy + dz * dz
    d2 = r2 + f32(eps) ** 2
    # self-pairs (r2 == 0) must contribute exactly zero, incl. the potential
    safe = r2 > 0.0
    inv_r = jnp.where(safe, jax.lax.rsqrt(jnp.where(safe, d2, 1.0)), 0.0)
    d2s = jnp.where(safe, d2, 1.0)
    return dx, dy, dz, d2s, inv_r


def _dv(tgt, src):
    dvx = src[_VX : _VX + 1, :] - tgt[:, _VX : _VX + 1]
    dvy = src[_VY : _VY + 1, :] - tgt[:, _VY : _VY + 1]
    dvz = src[_VZ : _VZ + 1, :] - tgt[:, _VZ : _VZ + 1]
    return dvx, dvy, dvz


def _round(x, compute_dtype):
    """Round a per-pair term through the reduced compute dtype (fp32 I/O).

    Models the Tensix unpack-fp32 / compute-reduced / pack-fp32 datapath:
    the (BI, BJ) contribution tile is what the FPU emits at reduced
    precision; the accumulation that follows stays fp32.  ``None`` is the
    identity, keeping the full-precision path bit-identical.
    """
    if compute_dtype is None:
        return x
    return x.astype(compute_dtype).astype(jnp.float32)


def _accumulate(out_ref, comp_ref, contrib):
    """Accumulate ``contrib`` into ``out_ref`` across the j-sweep.

    With a compensation ref, each j-block add is an exact two-sum: the
    rounding error of ``out += contrib`` is recovered and carried in
    ``comp_ref``, so the j-loop accumulator error stays O(1 ulp) instead of
    growing with the number of source blocks (the fp32-accumulate half of
    the mixed-precision pattern).  Without one, this is the historical
    in-place add.
    """
    if comp_ref is None:
        out_ref[...] += contrib
    else:
        a = out_ref[...]
        s = a + contrib
        bb = s - a
        err = (a - (s - bb)) + (contrib - bb)
        out_ref[...] = s
        comp_ref[...] += err


def _fold_compensation(out_ref, comp_ref, j_step):
    """Fold the carried compensation into the output at the last j-block.

    Deliberately OUTSIDE the activity gate: an i-block whose final j-steps
    are predicated away must still fold the error term accumulated on its
    earlier active steps.
    """
    if comp_ref is None:
        return

    @pl.when(j_step == pl.num_programs(1) - 1)
    def _fold():
        out_ref[...] = out_ref[...] + comp_ref[...]


def _acc_jerk_kernel(tgt_ref, src_ref, out_ref, comp_ref=None, *,
                     eps: float, compute_dtype=None):
    """One (i-block, j-block) step of the acc/jerk/potential sweep."""
    j_step = pl.program_id(1)

    @pl.when(j_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if comp_ref is not None:
            comp_ref[...] = jnp.zeros_like(comp_ref)

    tgt = tgt_ref[...]
    act = tgt[:, _ACT : _ACT + 1]                       # target activity mask

    # an i-block with no active target contributes nothing: skip its compute
    # (the grid still visits the step, but the VPU work is predicated away)
    @pl.when(jnp.sum(act) > 0.0)
    def _compute():
        src = src_ref[...]
        dx, dy, dz, d2, inv_r = _geometry(tgt, src, eps)
        inv_r3 = inv_r * inv_r * inv_r
        mj = src[_M : _M + 1, :]
        t = mj * inv_r3                                 # t_j  (paper Alg. 3)

        dvx, dvy, dvz = _dv(tgt, src)
        rv = dx * dvx + dy * dvy + dz * dvz             # v_r
        q = (-3.0 * rv) / d2                            # A_ij * v_r

        ax = jnp.sum(_round(t * dx, compute_dtype), axis=1)
        ay = jnp.sum(_round(t * dy, compute_dtype), axis=1)
        az = jnp.sum(_round(t * dz, compute_dtype), axis=1)
        jx = jnp.sum(_round(t * (dvx + q * dx), compute_dtype), axis=1)
        jy = jnp.sum(_round(t * (dvy + q * dy), compute_dtype), axis=1)
        jz = jnp.sum(_round(t * (dvz + q * dz), compute_dtype), axis=1)
        pot = -jnp.sum(_round(mj * inv_r, compute_dtype), axis=1)
        zero = jnp.zeros_like(ax)

        partial = jnp.stack([ax, ay, az, jx, jy, jz, pot, zero], axis=1)
        _accumulate(out_ref, comp_ref, act * partial)

    _fold_compensation(out_ref, comp_ref, j_step)


def _snap_kernel(tgt_ref, src_ref, tacc_ref, sacc_ref, out_ref,
                 comp_ref=None, *, eps: float, compute_dtype=None):
    """Second Hermite pass: snap from positions, velocities and pass-1 accs."""
    j_step = pl.program_id(1)

    @pl.when(j_step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        if comp_ref is not None:
            comp_ref[...] = jnp.zeros_like(comp_ref)

    tgt = tgt_ref[...]
    act = tgt[:, _ACT : _ACT + 1]                       # target activity mask

    @pl.when(jnp.sum(act) > 0.0)
    def _compute():
        src = src_ref[...]
        dx, dy, dz, d2, inv_r = _geometry(tgt, src, eps)
        inv_r3 = inv_r * inv_r * inv_r
        mj = src[_M : _M + 1, :]
        t = mj * inv_r3

        dvx, dvy, dvz = _dv(tgt, src)
        dax = sacc_ref[0:1, :] - tacc_ref[:, 0:1]
        day = sacc_ref[1:2, :] - tacc_ref[:, 1:2]
        daz = sacc_ref[2:3, :] - tacc_ref[:, 2:3]

        alpha = (dx * dvx + dy * dvy + dz * dvz) / d2
        beta = (dvx * dvx + dvy * dvy + dvz * dvz
                + dx * dax + dy * day + dz * daz) / d2 + alpha * alpha

        # A0 / A1 / A2 chains, per component (paper Alg. 3 extended to snap).
        a3, b3 = -3.0 * alpha, -3.0 * beta
        px, py, pz = t * dx, t * dy, t * dz                   # A0
        jx_, jy_, jz_ = t * dvx + a3 * px, t * dvy + a3 * py, t * dvz + a3 * pz
        sx = jnp.sum(_round(t * dax - 6.0 * alpha * jx_ + b3 * px,
                            compute_dtype), axis=1)
        sy = jnp.sum(_round(t * day - 6.0 * alpha * jy_ + b3 * py,
                            compute_dtype), axis=1)
        sz = jnp.sum(_round(t * daz - 6.0 * alpha * jz_ + b3 * pz,
                            compute_dtype), axis=1)
        zero = jnp.zeros_like(sx)

        partial = jnp.stack([sx, sy, sz, zero, zero, zero, zero, zero],
                            axis=1)
        _accumulate(out_ref, comp_ref, act * partial)

    _fold_compensation(out_ref, comp_ref, j_step)


def grid_tiles(n_t: int, n_s: int, block_i: int, block_j: int) -> int:
    """Number of (i-block, j-block) grid tiles one kernel launch enqueues.

    This is the unit the compaction layer shrinks: a launch over ``n_t``
    targets costs ``ceil(n_t/BI) * ceil(n_s/BJ)`` tiles whether or not
    ``pl.when`` predicates some of them away — the Tensix analogue is the
    host enqueueing a tile descriptor per (i, j) pair.  Gathering the active
    targets into a dense ``cap``-row buffer replaces ``n_t = N`` with
    ``n_t = cap`` so the tiles are *not enqueued at all* (telemetry reports
    this count per run as ``grid_tiles``).
    """
    return -(-n_t // block_i) * -(-n_s // block_j)


def _grid_specs(n_t: int, n_s: int, block_i: int, block_j: int):
    # n_t is independent of n_s (rectangular contract): the compaction layer
    # exploits exactly this by shrinking the target extent to the gathered
    # active block while sources stay full.
    grid = (n_t // block_i, n_s // block_j)
    tgt_spec = pl.BlockSpec((block_i, 8), lambda i, j: (i, 0))
    src_spec = pl.BlockSpec((8, block_j), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((block_i, 8), lambda i, j: (i, 0))
    return grid, tgt_spec, src_spec, out_spec


def _out_wiring(n_t: int, out_spec, compute_dtype):
    """(out_specs, out_shape, unpack) for a launch.

    The full-precision path keeps its historical single output.  A reduced
    compute dtype adds a second (N_t, 8) output carrying the two-sum
    compensation term across the j-sweep; the kernel folds it into the
    primary output at the last j-step and the wrapper discards it.
    """
    shape = jax.ShapeDtypeStruct((n_t, 8), jnp.float32)
    if compute_dtype is None:
        return out_spec, shape, lambda out: out
    return [out_spec, out_spec], [shape, shape], lambda outs: outs[0]


@functools.partial(
    jax.jit,
    static_argnames=("eps", "block_i", "block_j", "interpret",
                     "compute_dtype"),
)
def acc_jerk_pot_packed(
    tgt,
    src,
    *,
    eps: float = 1e-7,
    block_i: int = DEFAULT_BLOCK_I,
    block_j: int = DEFAULT_BLOCK_J,
    interpret: bool = False,
    compute_dtype: str | None = None,
):
    """Pallas all-pairs acceleration+jerk+potential on packed operands.

    ``tgt``: (N_t, 8) float32, ``src``: (8, N_s) float32, with N_t divisible
    by ``block_i`` and N_s by ``block_j`` (``ops.py`` handles padding).
    Returns packed (N_t, 8) output.  N_t and N_s may differ — the rectangular
    contract used by the multi-device strategies (local targets x streamed
    sources).  ``compute_dtype`` (e.g. ``"bfloat16"``) rounds per-pair terms
    through the reduced dtype and compensates the j-loop accumulation.
    """
    n_t, n_s = tgt.shape[0], src.shape[1]
    grid, tgt_spec, src_spec, out_spec = _grid_specs(n_t, n_s, block_i, block_j)
    out_specs, out_shape, unpack = _out_wiring(n_t, out_spec, compute_dtype)
    return unpack(pl.pallas_call(
        functools.partial(_acc_jerk_kernel, eps=eps,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[tgt_spec, src_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(tgt, src))


@functools.partial(
    jax.jit,
    static_argnames=("eps", "block_i", "block_j", "interpret",
                     "compute_dtype"),
)
def snap_packed(
    tgt,
    src,
    tgt_acc,
    src_acc,
    *,
    eps: float = 1e-7,
    block_i: int = DEFAULT_BLOCK_I,
    block_j: int = DEFAULT_BLOCK_J,
    interpret: bool = False,
    compute_dtype: str | None = None,
):
    """Pallas all-pairs snap pass on packed operands (see module docstring)."""
    n_t, n_s = tgt.shape[0], src.shape[1]
    grid, tgt_spec, src_spec, out_spec = _grid_specs(n_t, n_s, block_i, block_j)
    acc_t_spec = pl.BlockSpec((block_i, 8), lambda i, j: (i, 0))
    acc_s_spec = pl.BlockSpec((8, block_j), lambda i, j: (0, j))
    out_specs, out_shape, unpack = _out_wiring(n_t, out_spec, compute_dtype)
    return unpack(pl.pallas_call(
        functools.partial(_snap_kernel, eps=eps,
                          compute_dtype=compute_dtype),
        grid=grid,
        in_specs=[tgt_spec, src_spec, acc_t_spec, acc_s_spec],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(tgt, src, tgt_acc, src_acc))
