"""Pure-jnp oracle for the all-pairs N-body force kernels.

This is the analogue of the paper's "golden reference": a naive, brute-force
direct-summation evaluation of accelerations, jerks (and snaps for the
6th-order Hermite scheme), run at whatever precision the caller requests
(float64 when x64 is enabled reproduces the paper's CPU golden run).

Conventions (G = 1, N-body units):
    acc_i  = sum_j m_j * r_ij / (r^2 + eps^2)^{3/2}
    jerk_i = sum_j m_j * [ v_ij / d3 + q * r_ij / d3 ],  q = -3 (r.v)/d2
    snap_i = sum_j [ m_j * a_ij / d3 - 6 alpha * J_ij - 3 beta * P_ij ]
with r_ij = r_j - r_i, v_ij = v_j - v_i, a_ij = a_j - a_i,
     d2 = r^2 + eps^2, alpha = (r.v)/d2, beta = (v.v + r.a)/d2 + alpha^2,
     P_ij / J_ij the pairwise acc/jerk contributions.

The potential phi_i = -sum_j m_j / sqrt(d2) is returned alongside for energy
diagnostics (paper Fig. 4 validation).

Mixed precision (``compute_dtype``): the Wormhole FPU the paper benchmarks
computes in reduced precision with fp32 I/O (unpack fp32 -> compute fp16 ->
pack fp32).  Passing ``compute_dtype="bfloat16"`` emulates that datapath at
the oracle level: every *per-pair* contribution is rounded through the
compute dtype before accumulation, and the source-axis reductions switch to
a compensated (Neumaier two-sum) summation so the accumulator error stays
O(1 ulp) instead of O(N) — the fp32-accumulate half of the Tensix pattern.
``compute_dtype=None`` (the default) is bit-identical to the historical
full-precision path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compensated_sum(x, axis: int = 0):
    """Neumaier compensated sum along ``axis``.

    Maintains a running compensation term alongside the accumulator: each
    add performs a two-sum (``t = s + v``; the rounding error of that add is
    recovered exactly as ``(s - t) + v`` or ``(v - t) + s`` depending on
    which operand dominates) and folds the accumulated error back in at the
    end.  The result carries O(1 ulp) error independent of the number of
    summands — the property the kernel-side j-loop compensation mirrors.
    """
    x = jnp.moveaxis(x, axis, 0)

    def add(carry, v):
        s, c = carry
        t = s + v
        err = jnp.where(jnp.abs(s) >= jnp.abs(v), (s - t) + v, (v - t) + s)
        return (t, c + err), None

    zero = jnp.zeros(x.shape[1:], x.dtype)
    (s, c), _ = jax.lax.scan(add, (zero, zero), x)
    return s + c


def _precision_ops(compute_dtype):
    """(round-per-pair, reduce-over-axis) pair for a compute dtype.

    ``None`` keeps the historical full-precision expressions untouched;
    otherwise per-pair terms round through ``compute_dtype`` (fp32 in/out,
    reduced-precision arithmetic — the Tensix unpack/compute/pack shape) and
    reductions run compensated in fp32.
    """
    if compute_dtype is None:
        return (lambda x: x), jnp.sum
    cdt = jnp.dtype(compute_dtype)

    def rnd(x):
        return x.astype(cdt).astype(jnp.float32)

    return rnd, compensated_sum


#: pair-count ceiling of one dense rectangle evaluation (~2^26 pairs keeps
#: the fused (N_t, N_s, 3) temporaries around ~6 GB at fp64).  Larger
#: rectangles stream row chunks of the *target* side through ``lax.map``:
#: each output row is a row-local reduction over the full source axis, so
#: chunking the rows never reorders any sum — rectangles at or under the
#: ceiling take the historical single-fusion path untouched, and a
#: 65536-body sweep peaks at the chunk footprint instead of >100 GiB.
DENSE_PAIR_LIMIT = 1 << 26


def _map_row_chunks(fn, targets, n_s):
    """``fn(*targets)`` evaluated over row chunks of the target-side arrays
    when the rectangle exceeds :data:`DENSE_PAIR_LIMIT` pairs."""
    n_t = targets[0].shape[0]
    if n_t * max(n_s, 1) <= DENSE_PAIR_LIMIT:
        return fn(*targets)
    rows = min(n_t, max(1, DENSE_PAIR_LIMIT // n_s))
    pad = -n_t % rows
    chunked = tuple(
        jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
        .reshape((-1, rows) + a.shape[1:]) for a in targets)
    out = jax.lax.map(lambda xs: fn(*xs), chunked)
    return jax.tree_util.tree_map(
        lambda o: o.reshape((-1,) + o.shape[2:])[:n_t], out)


def _pairwise_geometry(pos_t, pos_s, eps):
    """Displacements r_ij = r_j - r_i and softened inverse distances.

    Rectangular contract: axis 0 = target i (N_t), axis 1 = source j (N_s).
    A target that also appears in the source set self-cancels (dr = 0).
    """
    dr = pos_s[None, :, :] - pos_t[:, None, :]
    r2 = jnp.sum(dr * dr, axis=-1)
    d2 = r2 + jnp.asarray(eps, pos_t.dtype) ** 2
    # Self-interactions (exact zero displacement) contribute NOTHING — with
    # softening d2 = eps^2 > 0 there, so the guard must use the unsoftened
    # distance (otherwise the potential gains a spurious -m/eps per particle).
    safe = r2 > 0
    inv_r = jnp.where(safe, 1.0 / jnp.sqrt(jnp.where(safe, d2, 1.0)), 0.0)
    return dr, d2, inv_r


def acc_jerk_pot_rect(pos_t, vel_t, pos_s, vel_s, mass_s, *,
                      eps: float = 1e-7, compute_dtype=None):
    """Brute-force acc/jerk/potential of targets due to sources.

    Args:
        pos_t, vel_t: (N_t, 3) target positions/velocities.
        pos_s, vel_s: (N_s, 3) source positions/velocities.
        mass_s: (N_s,) source masses.
        eps: Plummer softening length (paper Appendix A: 1e-7).
        compute_dtype: reduced per-pair precision (e.g. ``"bfloat16"``) with
            compensated fp32 accumulation; ``None`` = full precision.

    Returns:
        acc (N_t, 3), jerk (N_t, 3), pot (N_t,) in ``pos_t.dtype``.
    """
    rnd, sum_ = _precision_ops(compute_dtype)

    def dense(pt, vt):
        dr, d2, inv_r = _pairwise_geometry(pt, pos_s, eps)
        inv_r3 = inv_r * inv_r * inv_r
        dv = vel_s[None, :, :] - vt[:, None, :]

        t = mass_s[None, :] * inv_r3                 # m_j / d^3
        rv = jnp.sum(dr * dv, axis=-1)               # r_ij . v_ij
        q = -3.0 * rv / jnp.where(d2 > 0, d2, 1.0)   # A_ij * v_r in the paper

        acc = sum_(rnd(t[:, :, None] * dr), axis=1)
        jerk = sum_(rnd(t[:, :, None] * (dv + q[:, :, None] * dr)), axis=1)
        pot = -sum_(rnd(mass_s[None, :] * inv_r), axis=1)
        return acc, jerk, pot

    return _map_row_chunks(dense, (pos_t, vel_t), pos_s.shape[0])


def acc_jerk_pot(pos, vel, mass, *, eps: float = 1e-7, compute_dtype=None):
    """Symmetric all-pairs form (targets == sources)."""
    return acc_jerk_pot_rect(pos, vel, pos, vel, mass, eps=eps,
                             compute_dtype=compute_dtype)


def snap_rect(
    pos_t, vel_t, acc_t, pos_s, vel_s, acc_s, mass_s, *,
    eps: float = 1e-7, compute_dtype=None,
):
    """Brute-force snap of targets due to sources, given accelerations.

    This is the second evaluation pass of the 6th-order Hermite scheme: it
    needs the acceleration of *both* interaction partners (a_ij = a_j - a_i),
    which is why the paper's single-pass device kernel (acc+jerk only) caps at
    4th order; see DESIGN.md §2.2.
    """
    rnd, sum_ = _precision_ops(compute_dtype)

    def dense(pt, vt, at):
        dr, d2, inv_r = _pairwise_geometry(pt, pos_s, eps)
        inv_r3 = inv_r * inv_r * inv_r
        d2s = jnp.where(d2 > 0, d2, 1.0)
        dv = vel_s[None, :, :] - vt[:, None, :]
        da = acc_s[None, :, :] - at[:, None, :]
        mass = mass_s

        t = mass[None, :] * inv_r3
        alpha = jnp.sum(dr * dv, axis=-1) / d2s
        beta = (jnp.sum(dv * dv, axis=-1) + jnp.sum(dr * da, axis=-1)) \
            / d2s + alpha * alpha

        p_pair = t[:, :, None] * dr                                    # A0
        j_pair = t[:, :, None] * dv - 3.0 * alpha[:, :, None] * p_pair  # A1
        s_pair = t[:, :, None] * da - 6.0 * alpha[:, :, None] * j_pair \
            - 3.0 * beta[:, :, None] * p_pair                           # A2
        return sum_(rnd(s_pair), axis=1)

    return _map_row_chunks(dense, (pos_t, vel_t, acc_t), pos_s.shape[0])


def snap(pos, vel, acc, mass, *, eps: float = 1e-7, compute_dtype=None):
    """Symmetric all-pairs snap (targets == sources)."""
    return snap_rect(pos, vel, acc, pos, vel, acc, mass, eps=eps,
                     compute_dtype=compute_dtype)


def acc_jerk_snap_pot(pos, vel, mass, *, eps: float = 1e-7,
                      compute_dtype=None):
    """Full two-pass evaluation: (acc, jerk, snap, pot)."""
    acc, jerk, pot = acc_jerk_pot(pos, vel, mass, eps=eps,
                                  compute_dtype=compute_dtype)
    snp = snap(pos, vel, acc, mass, eps=eps, compute_dtype=compute_dtype)
    return acc, jerk, snp, pot
