"""Pure-jnp oracle for the all-pairs N-body force kernels.

This is the analogue of the paper's "golden reference": a naive, brute-force
direct-summation evaluation of accelerations, jerks (and snaps for the
6th-order Hermite scheme), run at whatever precision the caller requests
(float64 when x64 is enabled reproduces the paper's CPU golden run).

Conventions (G = 1, N-body units):
    acc_i  = sum_j m_j * r_ij / (r^2 + eps^2)^{3/2}
    jerk_i = sum_j m_j * [ v_ij / d3 + q * r_ij / d3 ],  q = -3 (r.v)/d2
    snap_i = sum_j [ m_j * a_ij / d3 - 6 alpha * J_ij - 3 beta * P_ij ]
with r_ij = r_j - r_i, v_ij = v_j - v_i, a_ij = a_j - a_i,
     d2 = r^2 + eps^2, alpha = (r.v)/d2, beta = (v.v + r.a)/d2 + alpha^2,
     P_ij / J_ij the pairwise acc/jerk contributions.

The potential phi_i = -sum_j m_j / sqrt(d2) is returned alongside for energy
diagnostics (paper Fig. 4 validation).
"""

from __future__ import annotations

import jax.numpy as jnp


def _pairwise_geometry(pos_t, pos_s, eps):
    """Displacements r_ij = r_j - r_i and softened inverse distances.

    Rectangular contract: axis 0 = target i (N_t), axis 1 = source j (N_s).
    A target that also appears in the source set self-cancels (dr = 0).
    """
    dr = pos_s[None, :, :] - pos_t[:, None, :]
    r2 = jnp.sum(dr * dr, axis=-1)
    d2 = r2 + jnp.asarray(eps, pos_t.dtype) ** 2
    # Self-interactions (exact zero displacement) contribute NOTHING — with
    # softening d2 = eps^2 > 0 there, so the guard must use the unsoftened
    # distance (otherwise the potential gains a spurious -m/eps per particle).
    safe = r2 > 0
    inv_r = jnp.where(safe, 1.0 / jnp.sqrt(jnp.where(safe, d2, 1.0)), 0.0)
    return dr, d2, inv_r


def acc_jerk_pot_rect(pos_t, vel_t, pos_s, vel_s, mass_s, *, eps: float = 1e-7):
    """Brute-force acc/jerk/potential of targets due to sources.

    Args:
        pos_t, vel_t: (N_t, 3) target positions/velocities.
        pos_s, vel_s: (N_s, 3) source positions/velocities.
        mass_s: (N_s,) source masses.
        eps: Plummer softening length (paper Appendix A: 1e-7).

    Returns:
        acc (N_t, 3), jerk (N_t, 3), pot (N_t,) in ``pos_t.dtype``.
    """
    dr, d2, inv_r = _pairwise_geometry(pos_t, pos_s, eps)
    inv_r3 = inv_r * inv_r * inv_r
    dv = vel_s[None, :, :] - vel_t[:, None, :]

    t = mass_s[None, :] * inv_r3                    # m_j / d^3
    rv = jnp.sum(dr * dv, axis=-1)                  # r_ij . v_ij
    q = -3.0 * rv / jnp.where(d2 > 0, d2, 1.0)      # A_ij * v_r in the paper

    acc = jnp.sum(t[:, :, None] * dr, axis=1)
    jerk = jnp.sum(t[:, :, None] * (dv + q[:, :, None] * dr), axis=1)
    pot = -jnp.sum(mass_s[None, :] * inv_r, axis=1)
    return acc, jerk, pot


def acc_jerk_pot(pos, vel, mass, *, eps: float = 1e-7):
    """Symmetric all-pairs form (targets == sources)."""
    return acc_jerk_pot_rect(pos, vel, pos, vel, mass, eps=eps)


def snap_rect(
    pos_t, vel_t, acc_t, pos_s, vel_s, acc_s, mass_s, *, eps: float = 1e-7
):
    """Brute-force snap of targets due to sources, given accelerations.

    This is the second evaluation pass of the 6th-order Hermite scheme: it
    needs the acceleration of *both* interaction partners (a_ij = a_j - a_i),
    which is why the paper's single-pass device kernel (acc+jerk only) caps at
    4th order; see DESIGN.md §2.2.
    """
    dr, d2, inv_r = _pairwise_geometry(pos_t, pos_s, eps)
    inv_r3 = inv_r * inv_r * inv_r
    d2s = jnp.where(d2 > 0, d2, 1.0)
    dv = vel_s[None, :, :] - vel_t[:, None, :]
    da = acc_s[None, :, :] - acc_t[:, None, :]
    mass = mass_s

    t = mass[None, :] * inv_r3
    alpha = jnp.sum(dr * dv, axis=-1) / d2s
    beta = (jnp.sum(dv * dv, axis=-1) + jnp.sum(dr * da, axis=-1)) / d2s \
        + alpha * alpha

    p_pair = t[:, :, None] * dr                                   # A0
    j_pair = t[:, :, None] * dv - 3.0 * alpha[:, :, None] * p_pair  # A1
    s_pair = t[:, :, None] * da - 6.0 * alpha[:, :, None] * j_pair \
        - 3.0 * beta[:, :, None] * p_pair                          # A2
    return jnp.sum(s_pair, axis=1)


def snap(pos, vel, acc, mass, *, eps: float = 1e-7):
    """Symmetric all-pairs snap (targets == sources)."""
    return snap_rect(pos, vel, acc, pos, vel, acc, mass, eps=eps)


def acc_jerk_snap_pot(pos, vel, mass, *, eps: float = 1e-7):
    """Full two-pass evaluation: (acc, jerk, snap, pot)."""
    acc, jerk, pot = acc_jerk_pot(pos, vel, mass, eps=eps)
    snp = snap(pos, vel, acc, mass, eps=eps)
    return acc, jerk, snp, pot
