"""The paper's Fig. 6 / Table 1 energy model — single source of truth.

The CPU host has no TPU power rails, so energy-to-solution is *modeled* the
way the paper's own analysis does it (documented constants, dominant-term
occupancy):

  P_chip = 170 W            (TPU v5e nameplate, ~compute-bound)
  P_host = 250 W            (host CPUs amortized across the job)
  E = T * (P_host + n_chips * P_chip * util),  util from the roofline
      (idle chips draw ~0.35 * P_chip)

``repro.sim.telemetry`` and ``benchmarks.common`` both import from here —
the constants used by the telemetry reports and the benchmark tables can
never drift apart (``tests/test_telemetry.py`` pins them against the
paper's Fig. 6 values).
"""

from __future__ import annotations

#: chip nameplate power draw at full occupancy (W)
P_CHIP = 170.0
#: host CPU power amortized across the job (W)
P_HOST = 250.0
#: fraction of P_CHIP an idle chip still draws
IDLE_FRAC = 0.35

#: Dominant-term device occupancy assumed for the modeled energy accounting
#: (matches the util figure used by benchmarks/table1_strategies.py).
DEFAULT_UTIL = 0.6


def modeled_energy(t_solution: float, n_chips: int, util: float) -> dict:
    """Paper Fig. 6 energy model; returns E (J), peak power (W), EDP (J s).

    ``util`` is a device occupancy *fraction* and must lie in [0, 1]: a
    roofline ratio above 1 (or a negative one) would silently model
    above-nameplate chip power in every EDP row downstream.
    """
    util = float(util)
    if not 0.0 <= util <= 1.0:
        raise ValueError(
            f"util={util} must be an occupancy fraction in [0, 1] "
            "(util > 1 would model above-nameplate chip power)")
    p_chips = n_chips * P_CHIP * (IDLE_FRAC + (1 - IDLE_FRAC) * util)
    p_total = P_HOST + p_chips
    e = t_solution * p_total
    return {"energy_J": e, "peak_W": p_total, "edp_Js": e * t_solution}
