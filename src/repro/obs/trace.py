"""Event-level span tracing: nested host spans -> Chrome-trace/Perfetto JSON.

A :class:`SpanTracer` records *complete* events (``ph: "X"``) with host
timestamps relative to the tracer's start; Perfetto (https://ui.perfetto.dev)
and ``chrome://tracing`` both infer nesting from time containment on one
track, so a ``with tracer.span("macro-step"): ...`` enclosing
``tracer.span("event")`` renders as a nested flame.

Two integration points line host spans up with device activity:

* every live span also enters a ``jax.profiler.TraceAnnotation`` of the same
  name, so when a device profile is captured (``jax.profiler.trace``) the
  host spans appear on the profiler timeline next to the XLA ops;
* traced code is annotated with ``jax.named_scope`` at the emission sites
  (``sim/ensemble.py``, ``core/strategies.py``, ``kernels/ops.py``), so the
  HLO itself carries the same taxonomy.

Spans the engine cannot time individually (the per-event work lives inside a
``lax.scan`` under ``jit``) are reconstructed by the driver as *synthetic*
spans via :meth:`SpanTracer.add_span` — evenly subdividing a measured chunk,
flagged ``{"synthetic": true}`` so a reader never mistakes them for measured
host timestamps.  The aggregate (chunk wall, event count, tiles) is measured;
only the subdivision is synthetic.

The module-level *current tracer* defaults to a zero-overhead
:class:`NullTracer`; ``sim/driver.py`` installs a live tracer for the run
when ``SimConfig.trace`` (CLI ``--trace out.json``) is set.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Optional

try:  # host-span mirroring onto the device profiler timeline
    from jax.profiler import TraceAnnotation
except Exception:  # pragma: no cover - jax always ships it today
    TraceAnnotation = None

#: schema tag carried in the exported JSON's ``otherData``
TRACE_SCHEMA_VERSION = 1


class NullTracer:
    """Disabled tracer: every operation is a no-op (the default)."""

    enabled = False

    @contextmanager
    def span(self, name: str, **args):
        yield

    def add_span(self, name: str, start_us: float, dur_us: float,
                 *, args: Optional[Dict[str, Any]] = None,
                 tid: Optional[int] = None) -> None:
        pass

    def instant(self, name: str, **args) -> None:
        pass

    def now_us(self) -> float:
        return 0.0

    def export(self, path: str) -> Optional[str]:
        return None


class SpanTracer(NullTracer):
    """Collects nestable spans; thread-safe; exports Chrome trace JSON."""

    enabled = True

    def __init__(self):
        self._t0 = time.perf_counter()
        self.wall_t0 = time.time()
        self._events: list = []
        self._lock = threading.Lock()
        self._tids: Dict[int, int] = {}

    # ------------------------------------------------------------- recording
    def now_us(self) -> float:
        """Microseconds since tracer start (the exported time base)."""
        return (time.perf_counter() - self._t0) * 1e6

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            return self._tids.setdefault(ident, len(self._tids))

    @contextmanager
    def span(self, name: str, **args):
        """Live nested span; also a ``jax.profiler.TraceAnnotation``."""
        t0 = self.now_us()
        ann = TraceAnnotation(name) if TraceAnnotation is not None else None
        if ann is not None:
            ann.__enter__()
        try:
            yield self
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            self.add_span(name, t0, self.now_us() - t0,
                          args=args or None)

    def add_span(self, name: str, start_us: float, dur_us: float,
                 *, args: Optional[Dict[str, Any]] = None,
                 tid: Optional[int] = None) -> None:
        """Record a span with explicit timestamps (synthetic subdivisions)."""
        ev = {"name": name, "ph": "X", "ts": float(start_us),
              "dur": max(float(dur_us), 0.001), "pid": os.getpid(),
              "tid": self._tid() if tid is None else tid, "cat": "sim"}
        if args:
            ev["args"] = dict(args)
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, **args) -> None:
        """Point-in-time marker (``ph: "i"``)."""
        ev = {"name": name, "ph": "i", "ts": self.now_us(), "s": "t",
              "pid": os.getpid(), "tid": self._tid(), "cat": "sim"}
        if args:
            ev["args"] = dict(args)
        with self._lock:
            self._events.append(ev)

    # --------------------------------------------------------------- export
    @property
    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON; returns the path.

        Events are sorted by (tid, ts) — what Perfetto's importer expects —
        and stamped with the wall-clock epoch of the tracer start so traces
        from different runs can be aligned offline.
        """
        with self._lock:
            events = sorted(self._events,
                            key=lambda e: (e["tid"], e["ts"], -e["dur"]
                                           if e["ph"] == "X" else 0.0))
        doc = {
            "displayTimeUnit": "ms",
            "traceEvents": events,
            "otherData": {
                "schema_version": TRACE_SCHEMA_VERSION,
                "epoch_unix_s": self.wall_t0,
                "producer": "repro.obs.trace",
            },
        }
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return path


_NULL = NullTracer()
_current: NullTracer = _NULL


def get_tracer() -> NullTracer:
    """The current tracer (a :class:`NullTracer` unless a run installed one)."""
    return _current


def set_tracer(tracer: Optional[NullTracer]) -> NullTracer:
    """Install ``tracer`` (None restores the null tracer); returns previous."""
    global _current
    prev = _current
    _current = tracer if tracer is not None else _NULL
    return prev


@contextmanager
def tracing(path: Optional[str] = None):
    """Scope a live :class:`SpanTracer` as current; export to ``path`` on
    exit when given.  Yields the tracer."""
    tracer = SpanTracer()
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)
        if path:
            tracer.export(path)
