"""repro.obs — observability substrate for the ensemble engine.

Four small, dependency-free modules every other layer reports through:

* :mod:`repro.obs.trace`   — nestable host-side spans exported as
  Chrome-trace/Perfetto JSON, lined up with device activity via
  ``jax.profiler.TraceAnnotation`` / ``jax.named_scope``;
* :mod:`repro.obs.metrics` — counters / gauges / histograms collected into a
  per-run registry and snapshotted into the telemetry report under a
  versioned ``metrics`` key;
* :mod:`repro.obs.energy`  — the paper's Fig. 6 energy model (single source
  of truth for ``P_CHIP`` / ``P_HOST`` / ``IDLE_FRAC``);
* :mod:`repro.obs.regress` — the CI perf-regression gate over the
  ``BENCH_ci.json`` trajectory.

See ``docs/observability.md`` for the span taxonomy and metric names.

Submodules are imported explicitly (``from repro.obs import metrics``) —
no eager re-exports here, so ``python -m repro.obs.regress`` never trips
the runpy double-import warning.
"""
