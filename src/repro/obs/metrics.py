"""Metrics registry: counters / gauges / histograms for one simulation run.

The engine layers (``sim/ensemble.py``, ``core/strategies.py``,
``kernels/ops.py``) emit into the *current* registry via :func:`registry`;
``sim/api.py`` scopes a fresh :class:`MetricsRegistry` around each run
(:func:`use`) and snapshots it into the telemetry report under a versioned
``metrics`` key (:meth:`MetricsRegistry.snapshot`,
``telemetry.finalize(metrics=...)``).

Metric taxonomy (names are ``layer.what``; units ride in the snapshot):

* ``engine.cache_miss``      — engine builds = XLA lowerings triggered (the
  lru-cached engine constructors only execute on a miss, so this IS the
  recompile count of the pre-lowered bucket groups);
* ``engine.bucket_branches`` — kernel branches lowered across bucket groups;
* ``sim.events``             — productive block events executed;
* ``sim.tiles_launched``     — kernel grid tiles enqueued (both passes);
* ``sim.tiles_occupancy_bound`` — analytic a-priori tile bound from
  ``hermite.block_level_occupancy`` (launched <= bound, asserted in tests);
* ``sim.tiles_dense_baseline``  — what the masked ``compaction="none"``
  launch would have enqueued;
* ``sim.active_fraction``    — per-chunk histogram of mean active-target
  fraction (force evals / events / n_active^2);
* ``sim.pad_waste``          — padded-slot fraction of the batch;
* ``sim.shard_imbalance``    — max/mean per-shard launched tiles;
* ``sim.bucket_hits``        — capacity-bucket switch hit distribution;
* ``ring.shifts_issued``     — ring ``ppermute`` rounds *traced* per pass
  (counted at trace time: the overlapped sweep unrolls ``p - 1`` real
  shifts, the sync baseline traces one body looped ``p`` times at runtime
  — see ``core.strategies._ring_sweep``);
* ``ring.overlap_frac``      — measured wall-clock fraction the overlapped
  ring saves over the sync baseline, ``1 - wall_overlap / wall_sync``
  (gauge, set by ``benchmarks/bench_ci.py``'s ``ring_overlap`` probe);
* ``serve.queue_depth``      — requests waiting for a slot (gauge);
* ``serve.slot_occupancy``   — live-slot fraction across pods (gauge);
* ``serve.admission_latency_s`` — submit -> admit wait (histogram);
* ``serve.turnaround_s``     — submit -> retire latency (histogram);
* ``serve.requests_admitted`` / ``serve.requests_retired`` — lifecycle
  counters of the simulation server (``repro.serve.sim_engine``).

Everything is plain Python on the host side — nothing here ever runs under
``jit``; traced code is annotated with ``jax.named_scope`` instead (see
``repro.obs.trace``).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

#: version of the ``metrics`` snapshot schema embedded in telemetry reports
METRICS_SCHEMA_VERSION = 1

#: histograms keep at most this many raw observations (summary stats keep
#: accumulating past the cap — only the percentile resolution degrades)
HISTOGRAM_SAMPLE_CAP = 4096


class Counter:
    """Monotonically increasing value."""

    def __init__(self, name: str, unit: str = "", help: str = ""):
        self.name, self.unit, self.help = name, unit, help
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (v={v})")
        self.value += float(v)

    def dump(self) -> Dict[str, Any]:
        return {"value": self.value, "unit": self.unit}


class Gauge:
    """Last-written value (numbers, or small JSON-able vectors)."""

    def __init__(self, name: str, unit: str = "", help: str = ""):
        self.name, self.unit, self.help = name, unit, help
        self.value: Any = None

    def set(self, v: Any) -> None:
        self.value = v

    def dump(self) -> Dict[str, Any]:
        return {"value": self.value, "unit": self.unit}


class Histogram:
    """Streaming distribution: count/sum/min/max plus sampled percentiles."""

    def __init__(self, name: str, unit: str = "", help: str = ""):
        self.name, self.unit, self.help = name, unit, help
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if len(self._samples) < HISTOGRAM_SAMPLE_CAP:
            self._samples.append(v)

    def percentile(self, q: float) -> Optional[float]:
        if not self._samples:
            return None
        xs = sorted(self._samples)
        idx = min(int(q * (len(xs) - 1) + 0.5), len(xs) - 1)
        return xs[idx]

    def dump(self) -> Dict[str, Any]:
        return {
            "count": self.count, "sum": self.sum,
            "min": self.min, "max": self.max,
            "mean": self.sum / self.count if self.count else None,
            "p50": self.percentile(0.50),
            "p95": self.percentile(0.95),
            "p99": self.percentile(0.99),
            "unit": self.unit,
        }


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors and snapshots."""

    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, unit: str, help: str):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, unit=unit, help=help)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str, unit: str = "", help: str = "") -> Counter:
        return self._get(Counter, name, unit, help)

    def gauge(self, name: str, unit: str = "", help: str = "") -> Gauge:
        return self._get(Gauge, name, unit, help)

    def histogram(self, name: str, unit: str = "",
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, unit, help)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready, versioned dump — the telemetry ``metrics`` payload."""
        with self._lock:
            metrics = dict(self._metrics)
        out: Dict[str, Any] = {
            "schema_version": METRICS_SCHEMA_VERSION,
            "counters": {}, "gauges": {}, "histograms": {},
        }
        kind = {Counter: "counters", Gauge: "gauges",
                Histogram: "histograms"}
        for name, m in sorted(metrics.items()):
            out[kind[type(m)]][name] = m.dump()
        return out


def validate_snapshot(snap: Dict[str, Any]) -> None:
    """Raise ``ValueError`` unless ``snap`` is a well-formed metrics payload
    of the current schema (the telemetry-report ``metrics`` key contract)."""
    if not isinstance(snap, dict):
        raise ValueError(f"metrics snapshot must be a dict, got {type(snap)}")
    version = snap.get("schema_version")
    if version != METRICS_SCHEMA_VERSION:
        raise ValueError(
            f"metrics schema_version {version!r} != {METRICS_SCHEMA_VERSION}")
    for section, fields in (("counters", ("value",)),
                            ("gauges", ("value",)),
                            ("histograms", ("count", "sum", "mean"))):
        body = snap.get(section)
        if not isinstance(body, dict):
            raise ValueError(f"metrics snapshot missing section {section!r}")
        for name, dump in body.items():
            if not isinstance(dump, dict):
                raise ValueError(f"{section}[{name!r}] must be a dict")
            missing = [f for f in fields if f not in dump]
            if missing:
                raise ValueError(
                    f"{section}[{name!r}] missing fields {missing}")


#: process-default registry: emissions outside any driver run land here
_default = MetricsRegistry()
_current = _default


def registry() -> MetricsRegistry:
    """The current registry (run-scoped inside a driver run)."""
    return _current


def set_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Install ``reg`` (None restores the process default); returns previous."""
    global _current
    prev = _current
    _current = reg if reg is not None else _default
    return prev


@contextmanager
def use(reg: Optional[MetricsRegistry] = None):
    """Scope ``reg`` (or a fresh registry) as current; yields it."""
    reg = reg if reg is not None else MetricsRegistry()
    prev = set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(prev)
