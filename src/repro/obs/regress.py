"""Perf-regression gate over the ``BENCH_ci.json`` trajectory.

``benchmarks/bench_ci.py`` appends one stamped record per bench-smoke run —
git SHA, trajectory ``schema_version``, jax version, device count — turning
the file from an anecdote into a trajectory.  This module is the gate over
it: the newest record is compared against the most recent *comparable*
earlier record (or an explicit ``--baseline`` file), and CI fails when any
tracked lower-is-better metric — wall per event, launched tiles, modeled
EDP, the neighbor-scheme wall and |dE/E|, the overlapped ring's wall per
evaluation and ppermute rounds, serving seconds-per-request /
p99 turnaround — regresses more than
:data:`DEFAULT_THRESHOLD` (20%).

Two refusal rules keep the gate honest:

* records without matching provenance (``schema_version`` / ``jax_version``
  / ``device_count`` / ``dtype``) are *incomparable* — never silently
  compared.  When scanning the trajectory they are skipped; an explicit
  ``--baseline`` that is incomparable is a hard error (exit 2).  A record
  stamped before the precision axis existed carries no ``dtype`` field and
  is read as the historical ``"fp32"`` — the committed history keeps gating
  non-vacuously, but a mixed-precision run never compares against it;
* a metric present in the baseline but missing from the current record is a
  regression (a silently dropped row must not pass the gate); a metric new
  in the current record is informational only.

CLI (the CI bench-smoke job's last step)::

    python -m repro.obs.regress BENCH_ci.json [--threshold 0.2]
    python -m repro.obs.regress new.json --baseline committed.json

Exit codes: 0 pass, 1 regression, 2 refused (incomparable / malformed).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
from typing import Any, Dict, List, Optional, Tuple

#: version of the BENCH_ci.json *trajectory* format (bumped from the
#: implicit v1 single-record file the gate still reads as legacy)
BENCH_SCHEMA_VERSION = 2

#: relative regression that fails the gate (current > (1+thr) * baseline)
DEFAULT_THRESHOLD = 0.20

#: provenance fields that must match for two records to be comparable
_COMPARABLE_FIELDS = ("schema_version", "jax_version", "device_count",
                      "dtype")

#: fields whose absence reads as a historical default instead of a mismatch
#: (records stamped before the precision axis existed are all-fp32 runs)
_COMPARABLE_DEFAULTS = {"dtype": "fp32"}


# --------------------------------------------------------------------------
# provenance stamping
# --------------------------------------------------------------------------
def git_sha(repo: Optional[str] = None) -> str:
    """HEAD commit of ``repo`` (cwd by default); ``"unknown"`` off-repo."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo, capture_output=True,
            text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def provenance(device_count: int, *, repo: Optional[str] = None,
               jax_version: Optional[str] = None,
               dtype: str = "fp32") -> Dict[str, Any]:
    """The stamp every bench-smoke record carries (comparability contract).

    ``dtype`` is the suite's *base* precision axis: per-dtype sweeps (e.g.
    ``precision_sweep``) key their rows by dtype inside the record, so the
    stamp records the precision of the single-dtype suites.
    """
    if jax_version is None:
        try:
            from importlib.metadata import version
            jax_version = version("jax")
        except Exception:
            jax_version = "unknown"
    return {
        "git_sha": git_sha(repo),
        "schema_version": BENCH_SCHEMA_VERSION,
        "jax_version": jax_version,
        "device_count": int(device_count),
        "dtype": str(dtype),
    }


# --------------------------------------------------------------------------
# trajectory I/O
# --------------------------------------------------------------------------
def load_trajectory(path: str) -> List[Dict[str, Any]]:
    """Records oldest-first.  A legacy single-record file (the pre-gate
    ``BENCH_ci.json``: one suite dict, no provenance) loads as a one-record
    trajectory so history survives the format migration."""
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, dict) and "records" in doc:
        records = doc["records"]
        if not isinstance(records, list):
            raise ValueError(f"{path}: 'records' must be a list")
        return records
    if isinstance(doc, dict) and doc.get("suite") == "bench_ci":
        return [doc]  # legacy v1: the bare suite record
    raise ValueError(
        f"{path}: neither a bench_ci trajectory nor a legacy suite record")


def save_trajectory(path: str, records: List[Dict[str, Any]]) -> str:
    doc = {
        "format": "bench_ci_trajectory",
        "schema_version": BENCH_SCHEMA_VERSION,
        "records": records,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


def append_record(path: str, record: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Append ``record`` to the trajectory at ``path`` (created if absent);
    returns the full record list."""
    records = load_trajectory(path) if os.path.exists(path) else []
    records.append(record)
    save_trajectory(path, records)
    return records


# --------------------------------------------------------------------------
# tracked metrics
# --------------------------------------------------------------------------
def tracked_metrics(record: Dict[str, Any]) -> Dict[str, float]:
    """Flatten one suite record to its gated lower-is-better metrics.

    Keys are stable row paths (``sweep/row-key/metric``) so trajectories
    remain joinable as sweeps grow rows.
    """
    out: Dict[str, float] = {}

    def put(key: str, value: Any) -> None:
        try:
            v = float(value)
        except (TypeError, ValueError):
            return
        if v > 0:  # zero/absent measurements carry no regression signal
            out[key] = v

    for row in record.get("stepper_modes") or ():
        base = f"stepper_modes/{row.get('stepper')}"
        put(f"{base}/wall_per_event_s", row.get("wall_per_event_s"))
        put(f"{base}/edp_Js", row.get("edp_Js"))
    for row in record.get("block_compaction") or ():
        base = f"block_compaction/seed{row.get('seed')}"
        put(f"{base}/wall_per_event_gather_s",
            row.get("wall_per_event_gather_s"))
        put(f"{base}/tiles_gather", row.get("tiles_gather"))
    for row in record.get("strategy_compaction") or ():
        base = f"strategy_compaction/seed{row.get('seed')}"
        put(f"{base}/wall_per_event_gather_s",
            row.get("wall_per_event_gather_s"))
        put(f"{base}/tiles_shard_max_gather",
            row.get("tiles_shard_max_gather"))
    for row in record.get("precision_sweep") or ():
        # rows are keyed by their own dtype so fp32 wall only ever compares
        # against fp32 wall, mixed |dE/E| against mixed |dE/E|, etc.
        base = f"precision_sweep/{row.get('dtype')}"
        put(f"{base}/wall_per_event_s", row.get("wall_per_event_s"))
        put(f"{base}/de_rel", row.get("de_rel"))
    for row in record.get("neighbor_sweep") or ():
        # only the CI-reproducible rows gate (``gate=True``): the large-N
        # rows exist only in BENCH_NEIGHBOR_FULL=1 local sweeps, and a
        # tracked metric missing from the next record reads as a regression
        if not row.get("gate"):
            continue
        base = f"neighbor_sweep/n{row.get('n')}"
        put(f"{base}/wall_per_event_neighbor_s",
            row.get("wall_per_event_neighbor_s"))
        put(f"{base}/de_rel_neighbor", row.get("de_rel_neighbor"))
    for row in record.get("ring_overlap") or ():
        # rows key by forced-host device count; the shift-round count is
        # exact (trace-time counter), so reintroducing the dead ppermute
        # (p-1 -> p rounds per pass) is a +33%-at-p=4 gated regression
        base = f"ring_overlap/dev{row.get('devices')}"
        put(f"{base}/wall_per_eval_overlap_s",
            row.get("wall_per_eval_overlap_s"))
        put(f"{base}/shift_rounds_overlap", row.get("shift_rounds_overlap"))
    for row in record.get("serve_throughput") or ():
        # only the server row gates: the one-process-per-request baseline
        # is informational (its wall is dominated by interpreter startup)
        if row.get("mode") != "server":
            continue
        base = "serve_throughput/server"
        put(f"{base}/s_per_request", row.get("s_per_request"))
        put(f"{base}/p99_turnaround_s", row.get("p99_turnaround_s"))
    return out


def comparable(current: Dict[str, Any],
               baseline: Dict[str, Any]) -> Tuple[bool, str]:
    """Whether two stamped records may be compared; (ok, reason-if-not)."""
    pc, pb = current.get("provenance"), baseline.get("provenance")
    if not isinstance(pc, dict):
        return False, "current record is unstamped (no provenance)"
    if not isinstance(pb, dict):
        return False, "baseline record is unstamped (no provenance)"
    for field in _COMPARABLE_FIELDS:
        default = _COMPARABLE_DEFAULTS.get(field)
        fc, fb = pc.get(field, default), pb.get(field, default)
        if fc is None:
            fc = default
        if fb is None:
            fb = default
        if fc != fb:
            return False, (f"{field} mismatch: current={fc!r} "
                           f"baseline={fb!r}")
    return True, ""


# --------------------------------------------------------------------------
# the gate
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Regression:
    metric: str
    baseline: float
    current: float

    @property
    def ratio(self) -> float:
        return self.current / self.baseline if self.baseline else float("inf")

    def __str__(self) -> str:
        return (f"{self.metric}: {self.baseline:g} -> {self.current:g} "
                f"({self.ratio:.2f}x)")


@dataclasses.dataclass
class GateResult:
    ok: bool
    regressions: List[Regression]
    notes: List[str]
    baseline_sha: Optional[str] = None

    def summary(self) -> str:
        lines = [f"# regress: {'PASS' if self.ok else 'FAIL'}"
                 + (f" (baseline {self.baseline_sha})"
                    if self.baseline_sha else "")]
        lines += [f"#   REGRESSED {r}" for r in self.regressions]
        lines += [f"#   note: {n}" for n in self.notes]
        return "\n".join(lines)


def compare(current: Dict[str, Any], baseline: Dict[str, Any],
            threshold: float = DEFAULT_THRESHOLD) -> List[Regression]:
    """Tracked metrics of ``current`` vs ``baseline``; all lower-is-better.

    A metric the baseline tracked but the current record dropped is a
    regression (value ``inf``): a sweep silently vanishing must not pass.
    """
    cur, base = tracked_metrics(current), tracked_metrics(baseline)
    regressions = []
    for key, b in sorted(base.items()):
        c = cur.get(key)
        if c is None:
            regressions.append(Regression(key, b, float("inf")))
        elif c > b * (1.0 + threshold):
            regressions.append(Regression(key, b, c))
    return regressions


def find_baseline(records: List[Dict[str, Any]]
                  ) -> Tuple[Optional[Dict[str, Any]], List[str]]:
    """Most recent record comparable with the newest one, scanning backwards;
    incomparable records are skipped with a note (never silently compared)."""
    notes = []
    current = records[-1]
    for rec in reversed(records[:-1]):
        ok, reason = comparable(current, rec)
        if ok:
            return rec, notes
        sha = (rec.get("provenance") or {}).get("git_sha", "unstamped")
        notes.append(f"skipped baseline candidate {sha}: {reason}")
    return None, notes


def check(path: str, *, baseline_path: Optional[str] = None,
          threshold: float = DEFAULT_THRESHOLD) -> GateResult:
    """Gate the newest record of ``path``.

    With ``baseline_path`` the baseline is that file's newest record and an
    incomparable pair *refuses* (raises ``ValueError``) — the explicit-
    baseline caller asked for exactly that comparison.  Without it, the
    trajectory is scanned for the latest comparable record; if none exists
    (e.g. the first stamped run after the format migration) the gate passes
    with a note rather than inventing a comparison.
    """
    records = load_trajectory(path)
    if not records:
        raise ValueError(f"{path}: empty trajectory")
    current = records[-1]
    notes: List[str] = []
    if baseline_path is not None:
        baseline = load_trajectory(baseline_path)[-1]
        ok, reason = comparable(current, baseline)
        if not ok:
            raise ValueError(
                f"refusing to compare {path} against {baseline_path}: "
                f"{reason}")
    else:
        baseline, notes = find_baseline(records)
        if baseline is None:
            notes.append("no comparable baseline in trajectory; gate passes "
                         "vacuously (first stamped record?)")
            return GateResult(ok=True, regressions=[], notes=notes)
    regressions = compare(current, baseline, threshold)
    sha = (baseline.get("provenance") or {}).get("git_sha")
    return GateResult(ok=not regressions, regressions=regressions,
                      notes=notes, baseline_sha=sha)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trajectory", help="BENCH_ci.json trajectory to gate")
    ap.add_argument("--baseline", default=None,
                    help="explicit baseline trajectory (newest record); "
                         "incomparable records refuse instead of skipping")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative regression that fails the gate "
                         "(default 0.20)")
    args = ap.parse_args(argv)
    try:
        result = check(args.trajectory, baseline_path=args.baseline,
                       threshold=args.threshold)
    except (ValueError, OSError) as e:
        print(f"# regress: REFUSED — {e}")
        return 2
    print(result.summary())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
