from repro.serve.engine import Engine, ServeConfig, prefill_step, decode_step  # noqa: F401
