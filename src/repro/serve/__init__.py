from repro.serve.engine import Engine, ServeConfig, prefill_step, decode_step  # noqa: F401
from repro.serve.sim_engine import (  # noqa: F401
    SERVABLE_STEPPERS,
    Pod,
    ServerConfig,
    SimRequest,
    SimServer,
    fifo_event_tiles,
    packed_event_tiles,
)
