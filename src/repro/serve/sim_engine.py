"""Continuous-batching simulation server: admit, advance, retire, backfill.

The batch-of-scenarios engine (``repro.sim.ensemble``) keeps the machine
saturated only while a whole ensemble is in flight; this module turns it
into a long-lived service.  A :class:`SimServer` holds a queue of
:class:`SimRequest`\\ s (a validated ``ScenarioSpec`` + stepper + ``t_end``)
and a set of **pods** — padded ``(B, cap)`` ensembles advanced in lockstep —
and on every scheduler tick:

1. **admits** queued requests into free pod slots (bucket-packing policy,
   below), bootstrapping each member's derivatives with the shared
   ``ensemble_initialize`` engine;
2. **advances** every pod by one engine chunk (``chunk_events`` macro-step
   boundaries — the only points where membership may change);
3. **retires** members whose sim time reached their deadline, streaming a
   versioned :class:`~repro.sim.telemetry.RunReport` per run;
4. **backfills** the freed slots from the queue.

**Admission policy (bucket packing).**  Pods are keyed by ``(stepper,
capacity ceiling)`` where the ceiling is
``ops.CapacityPlan.admission_cap(n)`` — the top capacity bucket a request of
``n`` bodies can ever select.  Every member of a pod therefore shares one
bucket-group signature, so the block engine's pre-lowered groups (and the
lowered XLA executables with them) are invariant under admit/retire/
backfill: after :meth:`SimServer.warmup` a steady-state trace runs with
**zero recompiles**, asserted via the ``engine.cache_miss`` counter.
Packing requests into cap-sized pods also launches at most the tiles of a
FIFO shared-``n_max`` pod (property-tested in ``tests/test_sim_server.py``).

**Retirement freezing.**  A retired slot keeps its ``n_active`` (so the
bucket groups never change) and keeps ``t_end <= time`` (so the engine
freezes the member whole); the vmapped engines touch members independently,
which makes batch-mates bit-identical across a neighbour's retire+backfill.

**Suspend/resume.**  :meth:`SimServer.suspend` checkpoints every pod's
array state (state + stepper carries, via ``repro.checkpoint.store``'s
atomic writer) plus a JSON manifest of queue/slot bookkeeping;
:meth:`SimServer.resume` rebuilds an equivalent server that continues
bit-identically (dtype-strict restore — see ``store.restore``).
"""

from __future__ import annotations

import collections
import dataclasses
import json
import math
import os
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.core.nbody import ParticleState, zeros_like_state
from repro.kernels import nbody_force, ops
from repro.obs import metrics as obs_metrics
from repro.sim import ensemble as ens
from repro.sim import scenarios
from repro.sim import telemetry
from repro.sim.scenarios import ScenarioError, ScenarioSpec
from repro.sim.telemetry import RunReport

#: steppers with per-member deadline semantics (the fixed-dt mode shares one
#: global step count and cannot freeze a retired member mid-batch)
SERVABLE_STEPPERS = ("adaptive", "block")

SERVER_META = "server_meta.json"


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Engine profile shared by every pod of one server."""

    slots_per_pod: int = 4           # B of each padded ensemble
    n_max: int = 1024                # largest admissible request N
    chunk_events: int = 16           # engine chunk per scheduler tick
    order: int = 6
    eps: float = 1e-7
    impl: str = "xla"
    dtype: str = "fp32"              # kernel precision axis (state is f64)
    eta: float = 0.02
    dt_max: float = 0.0625
    n_levels: int = 8                # block pods
    compaction: str = "none"         # block pods ("none" | "gather")
    block_i: Optional[int] = None
    block_j: Optional[int] = None
    sources: str = "full"            # block pods ("full" | "neighbor")
    neighbor_radius: float = 0.25
    refresh_levels: int = 2
    devices: int = 1
    mesh: Optional[Tuple[int, int]] = None   # fused (batch, domain) grid for
    #   block pods; product must equal devices (JSON manifests round-trip it
    #   as a 2-list, so compare via tuple())

    def validate(self) -> "ServerConfig":
        if self.slots_per_pod < 1:
            raise ValueError(
                f"slots_per_pod={self.slots_per_pod} must be >= 1")
        if self.mesh is not None:
            if len(self.mesh) != 2 or any(int(e) < 1 for e in self.mesh):
                raise ValueError(
                    f"mesh={self.mesh!r} must be two positive extents "
                    "(B_shards, P_shards)")
            if self.mesh[0] * self.mesh[1] != self.devices:
                raise ValueError(
                    f"mesh={tuple(self.mesh)} covers "
                    f"{self.mesh[0] * self.mesh[1]} devices; devices says "
                    f"{self.devices}")
        # the batch axis pads to the mesh's batch extent (all of `devices`
        # without a fused mesh)
        batch_extent = self.mesh[0] if self.mesh is not None else self.devices
        if batch_extent >= 1 and self.slots_per_pod % batch_extent:
            raise ValueError(
                f"slots_per_pod={self.slots_per_pod} must be a multiple of "
                f"the batch extent {batch_extent} (the batch axis shards "
                "evenly)")
        if self.chunk_events < 1:
            raise ValueError(
                f"chunk_events={self.chunk_events} must be >= 1")
        if self.dtype not in ops.DTYPES:
            raise ValueError(
                f"dtype must be one of {ops.DTYPES}; got {self.dtype!r}")
        if self.sources not in ops.SOURCES:
            raise ValueError(
                f"sources must be one of {ops.SOURCES}; got {self.sources!r}")
        if self.sources == "neighbor" and self.compaction != "none":
            raise ValueError(
                "sources='neighbor' gathers its own per-block source "
                "windows; it composes with compaction='none' only")
        if self.refresh_levels < 0:
            raise ValueError(
                f"refresh_levels={self.refresh_levels} must be >= 0")
        plan = self.plan()
        if self.n_max != plan.caps[-1]:
            raise ValueError(
                f"n_max={self.n_max} must be block_i-aligned "
                f"(next aligned value: {plan.caps[-1]})")
        return self

    @property
    def tile_shape(self) -> Tuple[int, int]:
        return (self.block_i or nbody_force.DEFAULT_BLOCK_I,
                self.block_j or nbody_force.DEFAULT_BLOCK_J)

    def plan(self) -> ops.CapacityPlan:
        """The full admission plan (the FIFO baseline's launch schedule)."""
        bi, bj = self.tile_shape
        return ops.CapacityPlan(self.n_max, self.n_max, bi, bj,
                                dtype=self.dtype)


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """One scenario run to serve: what + how + until when."""

    spec: ScenarioSpec
    stepper: str = "adaptive"
    t_end: float = 0.25

    def validate(self, cfg: ServerConfig) -> "SimRequest":
        self.spec.validate()
        if self.spec.n is None:
            raise ScenarioError(
                "SimRequest.spec.n: unset; the server admits fully sized "
                "requests (call spec.with_n(...))")
        if self.spec.n > cfg.n_max:
            raise ValueError(
                f"SimRequest.spec.n: n={self.spec.n} exceeds the server's "
                f"n_max={cfg.n_max}")
        if self.stepper not in SERVABLE_STEPPERS:
            raise ValueError(
                f"SimRequest.stepper: {self.stepper!r} not servable; one of "
                f"{SERVABLE_STEPPERS} (fixed-dt runs share one global step "
                "count and cannot freeze at a per-member deadline)")
        if not self.t_end > 0.0:
            raise ValueError(
                f"SimRequest.t_end: {self.t_end} must be > 0")
        return self

    def describe(self) -> Dict[str, Any]:
        return {"scenario": self.spec.format(), "seed": self.spec.seed,
                "params": dict(self.spec.params), "stepper": self.stepper,
                "t_end": self.t_end}


# --------------------------------------------------------------------------
# admission policy (pure host math; property-tested)
# --------------------------------------------------------------------------
def packed_event_tiles(plan: ops.CapacityPlan, n: int) -> int:
    """Worst-case per-event kernel tiles for ``n`` bodies in its bucket pod.

    The pod's source extent is the request's capacity ceiling, so both grid
    axes shrink with the request — compare :func:`fifo_event_tiles`, where
    the source axis stays at ``n_max``.
    """
    cap = plan.admission_cap(n)
    pod = ops.CapacityPlan(cap, cap, plan.block_i, plan.block_j,
                           n_passes=plan.n_passes, dtype=plan.dtype)
    return int(pod.tiles_by_cap[len(pod.restrict(n).caps) - 1])


def fifo_event_tiles(plan: ops.CapacityPlan, n: int) -> int:
    """Worst-case per-event tiles for ``n`` bodies under FIFO admission into
    one shared ``n_max``-sized pod (the naive policy's launch schedule)."""
    return int(plan.tiles_by_cap[len(plan.restrict(n).caps) - 1])


@dataclasses.dataclass
class _Pending:
    request_id: int
    request: SimRequest
    t_submit: float


@dataclasses.dataclass
class _Slot:
    request_id: int
    request: SimRequest
    t_submit: float
    t_admit: float
    e0: float
    recorder: telemetry.TelemetryRecorder


class Pod:
    """One padded ``(B, cap)`` lockstep ensemble with per-slot deadlines.

    Free slots hold frozen placeholders: their ``n_active`` keeps the last
    occupant's value (bucket groups stay invariant) and their deadline sits
    at/below their sim time (the engine freezes them whole).
    """

    def __init__(self, cfg: ServerConfig, stepper: str, cap: int):
        self.cfg, self.stepper, self.cap = cfg, stepper, cap
        b = cfg.slots_per_pod
        state_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
        zero = zeros_like_state(jnp.zeros((cap, 3), state_dtype),
                                jnp.zeros((cap, 3), state_dtype),
                                jnp.zeros((cap,), state_dtype))
        self.batched: ParticleState = ens.stack_states([zero] * b)
        self.state_dtype = self.batched.pos.dtype
        self.n_active = np.full(b, cap, np.int64)
        self.t_end = np.zeros(b, np.float64)      # all frozen at t=0
        self.slots: List[Optional[_Slot]] = [None] * b
        self.h_prev = jnp.zeros(b, self.state_dtype)       # adaptive carry
        self.n_taken = jnp.zeros(b, jnp.int32)
        self.carry: Optional[ens.BlockCarry] = None        # block carry

    # ------------------------------------------------------------- geometry
    @property
    def size(self) -> int:
        return self.cfg.slots_per_pod

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def occupied(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _devices(self):
        return jax.devices()[: self.cfg.devices] \
            if self.cfg.devices > 1 else None

    def _engine_kw(self) -> Dict[str, Any]:
        cfg = self.cfg
        return dict(order=cfg.order, eps=cfg.eps, impl=cfg.impl,
                    dtype=cfg.dtype)

    # ------------------------------------------------------------ lifecycle
    def init_member(self, request: SimRequest
                    ) -> Tuple[ParticleState, float]:
        """Build + pad + bootstrap one member; returns ``(state, e0)``.

        Runs through the same ``ensemble_initialize`` engine as a fresh
        batch, at the pod's padded width, so an admitted member's
        derivatives are bit-identical to a cold ``(1, cap)`` start.
        """
        member = request.spec.build(dtype=self.state_dtype)
        if self.stepper == "block" and self.cfg.sources == "neighbor":
            # sort once at admission (row order is carry-aligned and must
            # never change mid-run) so contiguous index blocks are compact
            # spatial cells and the member's neighbor windows stay tight
            member = ens.spatial_sort_state(
                member, leaf=math.gcd(*self.cfg.tile_shape))
        b1 = ens.stack_states([scenarios.pad_state(member, self.cap)])
        b1 = ens.ensemble_initialize(
            b1, n_active=[request.spec.n], devices=None, **self._engine_kw())
        e0 = float(np.asarray(ens.batched_total_energy(b1))[0])
        return jax.tree_util.tree_map(lambda x: x[0], b1), e0

    def admit(self, pending: _Pending, slot: int, now: float) -> _Slot:
        cfg, req = self.cfg, pending.request
        member, e0 = self.init_member(req)
        self.batched = jax.tree_util.tree_map(
            lambda full, m: full.at[slot].set(m), self.batched, member)
        self.n_active[slot] = req.spec.n
        self.t_end[slot] = req.t_end
        if self.stepper == "adaptive":
            self.h_prev = self.h_prev.at[slot].set(0.0)   # "first step" mark
            self.n_taken = self.n_taken.at[slot].set(0)
        elif self.carry is not None:
            # a never-advanced pod has no carry yet: the batch-wide init at
            # its first advance bootstraps every member, this one included
            self.carry = ens.block_admit_member(
                self.carry, member, slot, req.t_end, eta=cfg.eta,
                dt_max=cfg.dt_max, n_levels=cfg.n_levels)
        recorder = telemetry.TelemetryRecorder({
            **req.describe(), "request_id": pending.request_id,
            "n": req.spec.n, "pod_cap": self.cap, "dtype": cfg.dtype})
        recorder.record_snapshot(0, 0.0, energy=e0, de_rel=0.0)
        s = _Slot(request_id=pending.request_id, request=req,
                  t_submit=pending.t_submit, t_admit=now, e0=e0,
                  recorder=recorder)
        self.slots[slot] = s
        return s

    def advance(self) -> float:
        """One engine chunk; returns the chunk wall seconds (0.0 if idle)."""
        if not self.occupied():
            return 0.0
        cfg = self.cfg
        kw = dict(n_active=self.n_active, devices=self._devices(),
                  **self._engine_kw())
        t0 = time.perf_counter()
        if self.stepper == "adaptive":
            self.batched, self.h_prev, self.n_taken = \
                ens.ensemble_run_adaptive(
                    self.batched, t_end=self.t_end,
                    n_steps=cfg.chunk_events, h_prev=self.h_prev,
                    n_taken=self.n_taken, eta=cfg.eta, dt_max=cfg.dt_max,
                    **kw)
        else:
            self.batched, self.carry = ens.ensemble_run_block(
                self.batched, t_end=self.t_end, n_events=cfg.chunk_events,
                dt_max=cfg.dt_max, n_levels=cfg.n_levels, carry=self.carry,
                eta=cfg.eta, compaction=cfg.compaction,
                block_i=cfg.block_i, block_j=cfg.block_j,
                sources=cfg.sources, neighbor_radius=cfg.neighbor_radius,
                refresh_levels=cfg.refresh_levels,
                mesh=tuple(cfg.mesh) if cfg.mesh is not None else None,
                **kw)
        jax.block_until_ready(self.batched.pos)
        wall = time.perf_counter() - t0
        times = np.asarray(self.batched.time, np.float64)
        steps = self._per_slot_steps()
        for i in self.occupied():
            self.slots[i].recorder.record_step(int(steps[i]),
                                               float(times[i]), wall)
        return wall

    def _per_slot_steps(self) -> np.ndarray:
        if self.stepper == "adaptive":
            return np.asarray(self.n_taken, np.int64)
        if self.carry is None:
            return np.zeros(self.size, np.int64)
        return np.asarray(self.carry.n_events, np.int64)

    def finished_slots(self) -> List[int]:
        times = np.asarray(self.batched.time, np.float64)
        return [i for i in self.occupied() if times[i] >= self.t_end[i]]

    def retire(self, slot: int, now: float) -> RunReport:
        """Finalize one finished member's report and free its slot.

        The member's rows stay in place, frozen: ``n_active`` keeps its
        value (bucket-group invariance) and ``time >= t_end`` keeps the
        engine's freeze select active until a backfill overwrites the rows.
        """
        cfg, s = self.cfg, self.slots[slot]
        n = s.request.spec.n
        e = np.asarray(ens.batched_total_energy(self.batched), np.float64)
        e1 = float(e[slot])
        t_final = float(np.asarray(self.batched.time)[slot])
        steps = int(self._per_slot_steps()[slot])
        if self.stepper == "adaptive":
            pairs = [float(steps) * n * n]
            tiles = None
        else:
            pairs = [float(np.asarray(self.carry.n_pairs)[slot])]
            tiles = [float(np.asarray(self.carry.n_tiles)[slot])]
        de_rel = abs(e1 - s.e0) / max(abs(s.e0), np.finfo(np.float64).tiny)
        s.recorder.record_snapshot(steps, t_final, energy=e1, de_rel=de_rel)
        extra = {"e0": s.e0, "e1": e1, "de_rel": de_rel,
                 "t_final": t_final, "request_id": s.request_id,
                 "pod_cap": self.cap,
                 "admission_latency_s": s.t_admit - s.t_submit,
                 "turnaround_s": now - s.t_submit}
        if self.carry is not None and self.carry.nbr is not None:
            extra["neighbor_refreshes"] = int(
                np.asarray(self.carry.nbr.n_refresh)[slot])
            extra["neighbor_overflows"] = int(
                np.asarray(self.carry.nbr.n_overflow)[slot])
        report = s.recorder.finalize(
            n_bodies=self.cap, ensemble=1, n_devices=max(cfg.devices, 1),
            n_active=[n], per_run_steps=[steps], per_run_pairs=pairs,
            per_run_tiles=tiles, extra=extra)
        self.slots[slot] = None
        return report

    # ----------------------------------------------------- suspend / resume
    def state_tree(self) -> Dict[str, Any]:
        """The pod's array state as one checkpointable pytree."""
        tree: Dict[str, Any] = {
            "state": self.batched,
            "n_active": jnp.asarray(self.n_active, jnp.int32),
            "t_end": jnp.asarray(self.t_end, self.state_dtype),
        }
        if self.stepper == "adaptive":
            tree["h_prev"] = self.h_prev
            tree["n_taken"] = self.n_taken
        elif self.carry is not None:
            tree["carry"] = self.carry
        return tree

    def carry_template(self) -> ens.BlockCarry:
        """A zeros :class:`~repro.sim.ensemble.BlockCarry` with this pod's
        exact shapes/dtypes (the ``like`` tree of a dtype-strict restore)."""
        b, cap, cfg = self.size, self.cap, self.cfg
        bi, bj = cfg.tile_shape
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
        n_caps = len(ops.CapacityPlan(cap, cap, bi, bj).caps)
        nbr = None
        if self.stepper == "block" and cfg.sources == "neighbor":
            nbt, nsb = -(-cap // bi), -(-cap // bj)
            nbr = ens.NeighborCarry(
                win_idx=jnp.zeros((b, nbt, nsb), jnp.int32),
                win_cnt=jnp.zeros((b, nbt), jnp.int32),
                acc_far=jnp.zeros((b, cap, 3), self.state_dtype),
                jerk_far=jnp.zeros((b, cap, 3), self.state_dtype),
                snap_far=jnp.zeros((b, cap, 3), self.state_dtype),
                pot_far=jnp.zeros((b, cap), self.state_dtype),
                t_ref=jnp.full((b,), -1, jnp.int32),
                n_refresh=jnp.zeros((b,), jnp.int32),
                n_overflow=jnp.zeros((b,), jnp.int32))
        return ens.BlockCarry(
            t_last=jnp.zeros((b, cap), jnp.int32),
            levels=jnp.zeros((b, cap), jnp.int32),
            dt_macro=jnp.zeros(b, self.state_dtype),
            n_pairs=jnp.zeros(b, count_dtype),
            n_events=jnp.zeros(b, jnp.int32),
            n_tiles=jnp.zeros(b, count_dtype),
            bucket_hits=jnp.zeros((b, n_caps), count_dtype),
            nbr=nbr)

    def load_tree(self, tree: Dict[str, Any]) -> None:
        self.batched = tree["state"]
        self.n_active = np.asarray(tree["n_active"], np.int64)
        self.t_end = np.asarray(tree["t_end"], np.float64)
        if self.stepper == "adaptive":
            self.h_prev = tree["h_prev"]
            self.n_taken = tree["n_taken"]
        else:
            self.carry = tree.get("carry")


class SimServer:
    """The long-lived scheduler over a queue and a dict of pods.

    All engine work runs under this server's own metrics registry, so
    ``serve.*`` gauges and the ``engine.cache_miss`` recompile counter are
    attributable to the service (snapshot via :meth:`metrics_snapshot`).
    """

    def __init__(self, cfg: Optional[ServerConfig] = None):
        self.cfg = (cfg or ServerConfig()).validate()
        self.plan = self.cfg.plan()
        self.registry = obs_metrics.MetricsRegistry()
        self.queue: Deque[_Pending] = collections.deque()
        self.pods: Dict[Tuple[str, int], Pod] = {}
        self.reports: List[RunReport] = []
        self._next_id = 0

    # ------------------------------------------------------------ submission
    def submit(self, request: SimRequest,
               now: Optional[float] = None) -> int:
        """Queue one validated request; returns its request id."""
        request.validate(self.cfg)
        self.plan.admission_cap(request.spec.n)   # range check
        rid = self._next_id
        self._next_id += 1
        self.queue.append(_Pending(request_id=rid, request=request,
                                   t_submit=self._now(now)))
        self._set_gauges()
        return rid

    def _now(self, now: Optional[float] = None) -> float:
        return time.perf_counter() if now is None else now

    def pod_for(self, request: SimRequest) -> Pod:
        """Get-or-create the ``(stepper, capacity ceiling)`` pod (the plan
        restriction on admission that keeps engine builds invariant)."""
        key = (request.stepper, self.plan.admission_cap(request.spec.n))
        pod = self.pods.get(key)
        if pod is None:
            pod = self.pods[key] = Pod(self.cfg, key[0], key[1])
        return pod

    # ------------------------------------------------------------- scheduler
    def _admit(self, now: float) -> int:
        """Bucket-packing admission: any queued request whose pod has a free
        slot is admitted (FIFO within each bucket); head-of-line requests
        whose pod is full never block a different bucket's backfill."""
        admitted = 0
        remaining: Deque[_Pending] = collections.deque()
        while self.queue:
            p = self.queue.popleft()
            pod = self.pod_for(p.request)
            slot = pod.free_slot()
            if slot is None:
                remaining.append(p)
                continue
            pod.admit(p, slot, now)
            admitted += 1
            self.registry.counter(
                "serve.requests_admitted", unit="requests").inc()
            self.registry.histogram(
                "serve.admission_latency_s", unit="s",
                help="submit -> admit wait").observe(now - p.t_submit)
        self.queue = remaining
        return admitted

    def step(self, now: Optional[float] = None) -> List[RunReport]:
        """One scheduler tick: admit, advance all pods one chunk, retire
        finished members, backfill the freed slots.  Returns the reports of
        the members retired this tick (also appended to ``self.reports``)."""
        now = self._now(now)
        retired: List[RunReport] = []
        with obs_metrics.use(self.registry):
            self._admit(now)
            for pod in self.pods.values():
                pod.advance()
            for pod in self.pods.values():
                for slot in pod.finished_slots():
                    report = pod.retire(slot, self._now())
                    self.registry.counter(
                        "serve.requests_retired", unit="requests").inc()
                    self.registry.histogram(
                        "serve.turnaround_s", unit="s",
                        help="submit -> retire latency").observe(
                        report["turnaround_s"])
                    retired.append(report)
            self._admit(self._now())   # backfill freed slots immediately
        self._set_gauges()
        self.reports.extend(retired)
        return retired

    def busy(self) -> bool:
        return bool(self.queue) or any(p.occupied()
                                       for p in self.pods.values())

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> List[RunReport]:
        """Tick until queue and pods are empty; returns the new reports."""
        out: List[RunReport] = []
        ticks = 0
        while self.busy():
            if ticks >= max_ticks:
                raise RuntimeError(
                    f"server not drained after {max_ticks} ticks "
                    f"(queue={len(self.queue)})")
            out.extend(self.step())
            ticks += 1
        return out

    def _set_gauges(self) -> None:
        slots = sum(p.size for p in self.pods.values()) or 1
        live = sum(len(p.occupied()) for p in self.pods.values())
        self.registry.gauge(
            "serve.queue_depth", unit="requests",
            help="requests waiting for a slot").set(float(len(self.queue)))
        self.registry.gauge(
            "serve.slot_occupancy", unit="fraction",
            help="live-slot fraction across pods").set(live / slots)

    # -------------------------------------------------------------- warmup
    def warmup(self, requests: List[SimRequest]) -> float:
        """Pre-lower every engine a request mix will touch.

        For each distinct ``(stepper, cap)`` the mix maps to, builds the pod,
        bootstraps a throwaway member (the ``(1, cap)`` admission path) and
        advances one chunk (the ``(B, cap)`` engines + the energy
        diagnostics).  Steady state after this runs with zero recompiles —
        returns the ``engine.cache_miss`` count the warmup itself spent.
        """
        before = self.cache_misses()
        seen = set()
        with obs_metrics.use(self.registry):
            for req in requests:
                req.validate(self.cfg)
                key = (req.stepper, self.plan.admission_cap(req.spec.n))
                if key in seen:
                    continue
                seen.add(key)
                pod = self.pod_for(req)
                slot = pod.free_slot()
                warm = _Pending(request_id=-1, request=req,
                                t_submit=self._now())
                pod.admit(warm, slot, self._now())     # (1, cap) admission
                pod.advance()                          # (B, cap) engines
                pod.retire(slot, self._now())          # diagnostics + report
                pod.t_end[slot] = 0.0                  # freeze the warm rows
        return self.cache_misses() - before

    def cache_misses(self) -> float:
        """Engine builds charged to this server (fresh XLA lowerings)."""
        metric = self.registry._metrics.get("engine.cache_miss")
        return float(metric.value) if metric is not None else 0.0

    def metrics_snapshot(self) -> Dict[str, Any]:
        return self.registry.snapshot()

    # ----------------------------------------------------- suspend / resume
    def _pod_dir(self, root: str, key: Tuple[str, int]) -> str:
        return os.path.join(root, f"pod_{key[0]}_{key[1]}")

    def suspend(self, ckpt_dir: str, step: int = 0) -> str:
        """Checkpoint every pod's arrays + the scheduler bookkeeping."""
        os.makedirs(ckpt_dir, exist_ok=True)
        pods_meta = {}
        for key, pod in self.pods.items():
            store.save(self._pod_dir(ckpt_dir, key), step, pod.state_tree())
            pods_meta["/".join(map(str, key))] = {
                "stepper": pod.stepper, "cap": pod.cap,
                "has_carry": pod.stepper == "block"
                and pod.carry is not None,
                "slots": [None if s is None else {
                    "request_id": s.request_id,
                    "request": s.request.describe(),
                    "t_submit": s.t_submit, "t_admit": s.t_admit,
                    "e0": s.e0,
                    "meta": s.recorder.meta,
                    "steps": [dataclasses.asdict(x)
                              for x in s.recorder.steps],
                    "snapshots": s.recorder.snapshots,
                } for s in pod.slots],
            }
        meta = {
            "config": dataclasses.asdict(self.cfg),
            "next_id": self._next_id,
            "step": step,
            "queue": [{"request_id": p.request_id,
                       "request": p.request.describe(),
                       "t_submit": p.t_submit} for p in self.queue],
            "pods": pods_meta,
        }
        path = os.path.join(ckpt_dir, SERVER_META)
        with open(path, "w") as f:
            json.dump(meta, f, indent=1)
        return path

    @staticmethod
    def _request_from_meta(d: Dict[str, Any]) -> SimRequest:
        spec = ScenarioSpec.parse(d["scenario"], seed=int(d["seed"]))
        spec = dataclasses.replace(spec, params=dict(d.get("params") or {}))
        return SimRequest(spec=spec, stepper=d["stepper"],
                          t_end=float(d["t_end"]))

    @classmethod
    def resume(cls, ckpt_dir: str) -> "SimServer":
        """Rebuild a suspended server; pods continue bit-identically."""
        with open(os.path.join(ckpt_dir, SERVER_META)) as f:
            meta = json.load(f)
        cfg = ServerConfig(**meta["config"])
        server = cls(cfg)
        server._next_id = int(meta["next_id"])
        for p in meta["queue"]:
            server.queue.append(_Pending(
                request_id=int(p["request_id"]),
                request=cls._request_from_meta(p["request"]),
                t_submit=float(p["t_submit"])))
        for key_s, pm in meta["pods"].items():
            stepper, cap = pm["stepper"], int(pm["cap"])
            pod = Pod(server.cfg, stepper, cap)
            like = pod.state_tree()
            if pm.get("has_carry"):
                like["carry"] = pod.carry_template()
            step, tree = store.restore_latest(
                server._pod_dir(ckpt_dir, (stepper, cap)), like)
            if tree is None:
                raise FileNotFoundError(
                    f"no checkpoint for pod {key_s} under {ckpt_dir}")
            pod.load_tree(tree)
            for i, sm in enumerate(pm["slots"]):
                if sm is None:
                    continue
                recorder = telemetry.TelemetryRecorder(sm["meta"])
                recorder.steps = [telemetry.StepSample(**x)
                                  for x in sm["steps"]]
                recorder.snapshots = list(sm["snapshots"])
                pod.slots[i] = _Slot(
                    request_id=int(sm["request_id"]),
                    request=cls._request_from_meta(sm["request"]),
                    t_submit=float(sm["t_submit"]),
                    t_admit=float(sm["t_admit"]),
                    e0=float(sm["e0"]), recorder=recorder)
            server.pods[(stepper, cap)] = pod
        server._set_gauges()
        return server
