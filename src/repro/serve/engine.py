"""Batched serving engine: prefill + decode with a shared KV/state cache.

The two jitted entry points mirror the dry-run shapes:

* ``prefill_step``   — full-prompt forward filling the cache (prefill_32k);
* ``decode_step``    — one token for every active sequence (decode_32k,
  long_500k).

Batching model: requests are right-aligned into a fixed (B, S_prompt) block
(shorter prompts left-padded with token 0 and masked out of the loss-free
serving path by position bookkeeping at the client layer); decode advances
all sequences in lock-step, which matches the aligned-batch serving shape of
the dry-run.  Greedy and temperature sampling are provided.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.shardings import MeshRules
from repro.models import model
from repro.models.config import ArchConfig


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 2048
    temperature: float = 0.0     # 0 => greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, rules: MeshRules, params: dict,
                 scfg: ServeConfig = ServeConfig()):
        self.cfg, self.rules, self.params, self.scfg = cfg, rules, params, scfg
        self._prefill = jax.jit(
            functools.partial(model.prefill, cfg, rules),
            static_argnames=("max_len",))
        self._decode = jax.jit(functools.partial(model.decode_step, cfg, rules),
                               donate_argnums=(1,))

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.scfg.temperature)

    def generate(self, batch: dict, n_tokens: int):
        """Greedy/temperature generation; returns (tokens (B, n), stats)."""
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch,
                                      max_len=self.scfg.max_len)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        key = jax.random.PRNGKey(self.scfg.seed)
        toks = []
        nxt = self._sample(logits, key)
        t0 = time.perf_counter()
        for i in range(n_tokens):
            toks.append(nxt)
            logits, cache = self._decode(self.params, cache, nxt[:, None])
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub)
        jax.block_until_ready(nxt)
        t_decode = time.perf_counter() - t0
        out = jnp.stack(toks, axis=1)
        b = out.shape[0]
        return out, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tok_per_s": b * n_tokens / max(t_decode, 1e-9),
        }


def prefill_step(cfg: ArchConfig, rules: MeshRules):
    """Bare prefill fn(params, batch) -> (logits, cache) — dry-run target."""

    def step(params, batch):
        return model.prefill(cfg, rules, params, batch)

    return step


def decode_step(cfg: ArchConfig, rules: MeshRules):
    """Bare decode fn(params, cache, tokens) -> (logits, cache) — dry-run
    target (one new token against a seq_len-deep cache)."""

    def step(params, cache, tokens):
        return model.decode_step(cfg, rules, params, cache, tokens)

    return step
