"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The vision frontend
is a STUB: ``input_specs()`` provides precomputed patch embeddings
(B, frontend_len, d_model) that are prepended to the token embeddings; the
backbone applies M-RoPE with (t, h, w) position streams over the image span.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    frontend="vision_patches",
    frontend_len=256,        # 16x16 patch grid stub
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
))
