"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H (kv=128 => MHA semantics under MLA) per-expert
d_ff=1536 vocab=102400. First layer uses a dense FFN (DeepSeek-V2 paper).
MLA: q_lora=1536, kv_lora=512, decoupled rope dim 64, v_head_dim=128.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,             # dense-FFN width for the first_k_dense layers
    moe_d_ff=1536,
    vocab_size=102400,
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    first_k_dense=1,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    head_dim=128,           # nope head dim
    v_head_dim=128,
    rope_theta=10_000.0,
))
