"""qwen3-0.6b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936. Qwen3 uses an
explicit head_dim=128 (q/k/v projections wider than d_model/n_heads).
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
))
