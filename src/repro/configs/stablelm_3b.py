"""stablelm-3b [dense] — hf:stabilityai/stablelm-2-1_6b family (unverified).

32L d_model=2560 32H (GQA kv=32, i.e. MHA) d_ff=6912 vocab=50304.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    rope_theta=10_000.0,
))
