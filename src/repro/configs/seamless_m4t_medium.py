"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206 (padded to 256256).
The speech frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, frontend_len, d_model) consumed by the encoder directly.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,             # decoder layers
    encoder_layers=12,
    is_encoder_decoder=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio_frames",
    rope_theta=10_000.0,
))
