"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
One *shared-weight* attention block is applied every ``attn_every`` Mamba2
layers (the Zamba2 weight-sharing trick). Sub-quadratic: runs long_500k.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    chunk_size=256,
))
