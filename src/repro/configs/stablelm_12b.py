"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-1_6b family].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    rope_theta=10_000.0,
))
