"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 vocab=50304. 7:1 mLSTM:sLSTM block ratio (every
8th block is sLSTM); mLSTM blocks carry their own factor-2 up/down projection
(d_ff=0: no separate FFN). Sub-quadratic: runs long_500k.
"""

from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    chunk_size=256,
    tie_embeddings=True,
))
