"""``repro.sim`` — scenario library, batched ensemble engine, telemetry.

Three layers on top of the core Hermite/strategy machinery:

* ``scenarios``  — a registry of named initial-condition generators behind a
  common :class:`~repro.sim.scenarios.Scenario` dataclass, each validated by
  construction-time diagnostics (centre-of-mass frame, virial ratio);
* ``ensemble``   — packs B independent simulations into stacked
  ``ParticleState`` arrays and runs the full predict-evaluate-correct loop
  under ``jax.vmap`` with the batch axis sharded across devices; mixed
  scenarios of different N ride in one rectangular batch via zero-mass
  padding + a per-run ``n_active`` mask, with force evaluation switchable
  between the reference op and the tiled Pallas kernel, and three stepper
  modes — fixed dt, per-run shared-adaptive lockstep, and hierarchical
  per-particle block timesteps (see ``docs/ensembles.md``);
* ``driver`` / ``telemetry`` — a unified run loop (diagnostics cadence,
  per-step wall time, modeled energy/EDP) emitting one JSON report per run,
  wired into the ``repro.launch.sim_run`` CLI.
"""

from repro.sim import api, driver, ensemble, scenarios, \
    telemetry  # noqa: F401
