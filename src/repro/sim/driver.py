"""Compatibility shim: the unified run loop moved to :mod:`repro.sim.api`.

``driver.run`` / ``driver.SimConfig`` remain the stable entry names — tests,
benchmarks and committed reports reference them — but the implementation is
now a registry of composable build/step/collect runners (see
:class:`repro.sim.api.Runner`): ``run()`` is the monolithic recomposition,
and the serving layer (``repro.serve.sim_engine``) consumes the split calls
directly.  New code should import from ``repro.sim.api``.
"""

from __future__ import annotations

from repro.sim.api import (  # noqa: F401
    MAX_STEPS,
    RUNNERS,
    RunHandle,
    Runner,
    SimConfig,
    _auto_levels,
    _build_states,
    _chunk_spans,
    _device_list,
    _mix_params,
    get_runner,
    register_runner,
    resolve_kind,
    run,
    validate_config,
)
