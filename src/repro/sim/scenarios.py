"""Scenario library: named initial-condition generators behind a registry.

Every generator produces ``(pos, vel, mass)`` as float64 numpy arrays in a
self-consistent unit system; :func:`build` then recentres to the
centre-of-mass frame, (optionally) rescales bound systems to standard N-body
units (G = M = 1, E = -1/4) while preserving the generated virial ratio, and
runs construction-time diagnostics before handing back a ``ParticleState``.

The registry extends the seed's two hard-coded initial conditions
(``repro.core.nbody.plummer`` / ``two_body_circular``) with the workload
shapes that related work shows can reorder the paper's strategy rankings:
King models (W0-parameterised concentration), cold uniform-sphere collapse,
two-cluster mergers, binary-rich clusters, and a Keplerian disk.

Heterogeneous mixes: :func:`build_padded` stacks scenarios of *different* N
(and different generators) into one rectangular ``(B, N_max, ...)`` batch by
padding each member with zero-mass particles, returning the per-run
``n_active`` vector that the ensemble engine's mask and the telemetry
accounting honour (see :func:`pad_state` for the mask contract).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, \
    Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nbody
from repro.core.nbody import ParticleState, zeros_like_state

Arrays = Tuple[np.ndarray, np.ndarray, np.ndarray]
Generator = Callable[..., Arrays]

#: Virial-ratio window accepted for equilibrium models (T/|U| should be 0.5;
#: finite-N sampling noise widens it).
VIRIAL_TOL = 0.15


class ScenarioError(ValueError):
    """A generated initial condition failed its construction diagnostics."""


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ScenarioDef:
    """Registry entry: the generator plus its validation contract."""

    name: str
    generator: Generator
    equilibrium: bool           # expect T/|U| ~ 0.5 at construction
    rescale: bool               # rescale to standard units (E = -1/4)
    description: str
    defaults: Mapping[str, Any]
    min_n: int = 2


SCENARIOS: Dict[str, ScenarioDef] = {}


def register(name: str, *, equilibrium: bool, rescale: bool = True,
             description: str = "", min_n: int = 2, **defaults):
    def deco(fn: Generator) -> Generator:
        SCENARIOS[name] = ScenarioDef(
            name=name, generator=fn, equilibrium=equilibrium,
            rescale=rescale, description=description, defaults=dict(defaults),
            min_n=min_n)
        return fn
    return deco


def available() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


def get_spec(name: str) -> ScenarioDef:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ScenarioError(
            f"unknown scenario {name!r}; available: {available()}") from None


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A fully specified initial condition: registry name + parameters."""

    name: str
    n: int
    seed: int = 0
    dtype: Any = jnp.float64
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def build(self, *, validate: bool = True) -> ParticleState:
        return build(self, validate=validate)

    def describe(self) -> dict:
        return {"scenario": self.name, "n": self.n, "seed": self.seed,
                "params": dict(self.params)}


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """A validated scenario *request*: registry name + size (+ seed/params).

    The typed replacement for the stringly ``name[:N]`` CLI tokens —
    :meth:`parse` / :meth:`format` round-trip exactly, and :meth:`validate`
    raises a :class:`ScenarioError` that names the offending field
    (``ScenarioSpec.name: ...``, ``ScenarioSpec.n: ...``), so a bad request
    fails at the admission boundary (CLI flag parsing, server submit) instead
    of deep inside a generator.  ``n=None`` means "caller's default N"; fill
    it with :meth:`with_n` before building.
    """

    name: str
    n: Optional[int] = None
    seed: int = 0
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def parse(cls, token: str, *, seed: int = 0) -> "ScenarioSpec":
        """Parse ``name[:N]`` (e.g. ``"king:256"``) into a validated spec."""
        name, sep, count = str(token).partition(":")
        n: Optional[int] = None
        if sep:
            try:
                n = int(count)
            except ValueError:
                raise ScenarioError(
                    f"ScenarioSpec.n: {count!r} (from token {token!r}) "
                    "is not an integer N") from None
        return cls(name=name, n=n, seed=seed).validate()

    def format(self) -> str:
        """Inverse of :meth:`parse`: ``"king:256"``, or ``"king"`` (n=None)."""
        return self.name if self.n is None else f"{self.name}:{self.n}"

    def validate(self) -> "ScenarioSpec":
        """Check every field against the registry; return ``self``.

        Errors name the bad field so callers (CLI, server admission) can
        surface them without reverse-engineering the message.
        """
        if not isinstance(self.name, str) or not self.name:
            raise ScenarioError(
                f"ScenarioSpec.name: expected a non-empty scenario name, "
                f"got {self.name!r}")
        spec = SCENARIOS.get(self.name)
        if spec is None:
            raise ScenarioError(
                f"ScenarioSpec.name: unknown scenario {self.name!r}; "
                f"available: {available()}")
        if self.n is not None:
            if not isinstance(self.n, int) or isinstance(self.n, bool):
                raise ScenarioError(
                    f"ScenarioSpec.n: expected an int (or None), "
                    f"got {self.n!r}")
            if self.n < spec.min_n:
                raise ScenarioError(
                    f"ScenarioSpec.n: n={self.n} below {self.name!r}'s "
                    f"minimum {spec.min_n}")
        if not isinstance(self.seed, int) or isinstance(self.seed, bool) \
                or self.seed < 0:
            raise ScenarioError(
                f"ScenarioSpec.seed: expected a non-negative int, "
                f"got {self.seed!r}")
        unknown = set(self.params) - set(spec.defaults)
        if unknown:
            raise ScenarioError(
                f"ScenarioSpec.params: unknown parameter(s) "
                f"{sorted(unknown)} for {self.name!r}; "
                f"accepts {sorted(spec.defaults)}")
        return self

    def with_n(self, default_n: int) -> "ScenarioSpec":
        """Fill an unset ``n`` with the caller's default."""
        if self.n is not None:
            return self
        return dataclasses.replace(self, n=default_n)

    def scenario(self, *, dtype=jnp.float64) -> Scenario:
        """Lower to a buildable :class:`Scenario` (requires ``n`` set)."""
        if self.n is None:
            raise ScenarioError(
                "ScenarioSpec.n: unset; call with_n(default) before building")
        return Scenario(name=self.name, n=self.n, seed=self.seed,
                        dtype=dtype, params=dict(self.params))

    def build(self, *, dtype=jnp.float64, validate: bool = True
              ) -> ParticleState:
        self.validate()
        return build(self.scenario(dtype=dtype), validate=validate)


# --------------------------------------------------------------------------
# diagnostics (pure numpy; FP64 host precision, blocked O(N^2) potential)
# --------------------------------------------------------------------------
def _pairwise_potential(pos: np.ndarray, mass: np.ndarray,
                        block: int = 1024) -> float:
    """Total potential energy, blocked so N~10^4 stays in memory."""
    n = pos.shape[0]
    u = 0.0
    for lo in range(0, n, block):
        hi = min(lo + block, n)
        d = pos[lo:hi, None, :] - pos[None, :, :]
        r = np.sqrt((d * d).sum(-1))
        inv = np.zeros_like(r)
        np.divide(1.0, r, out=inv, where=r > 0)
        u -= 0.5 * (mass[lo:hi, None] * mass[None, :] * inv).sum()
    return float(u)


def diagnostics(pos: np.ndarray, vel: np.ndarray, mass: np.ndarray) -> dict:
    """COM frame, kinetic/potential energy and virial ratio T/|U|."""
    m = mass.sum()
    com_pos = (mass[:, None] * pos).sum(0) / m
    com_vel = (mass[:, None] * vel).sum(0) / m
    t = 0.5 * float((mass * (vel * vel).sum(-1)).sum())
    u = _pairwise_potential(pos, mass)
    return {
        "com_pos": float(np.abs(com_pos).max()),
        "com_vel": float(np.abs(com_vel).max()),
        "kinetic": t,
        "potential": u,
        "energy": t + u,
        "virial_ratio": t / abs(u) if u != 0.0 else math.inf,
        "total_mass": float(m),
    }


def state_diagnostics(state: ParticleState) -> dict:
    return diagnostics(np.asarray(state.pos, np.float64),
                       np.asarray(state.vel, np.float64),
                       np.asarray(state.mass, np.float64))


def _validate(spec: ScenarioDef, diag: dict) -> None:
    for key in ("kinetic", "potential", "energy"):
        if not math.isfinite(diag[key]):
            raise ScenarioError(f"{spec.name}: non-finite {key}: {diag[key]}")
    if diag["com_pos"] > 1e-8 or diag["com_vel"] > 1e-8:
        raise ScenarioError(
            f"{spec.name}: not in the centre-of-mass frame "
            f"(|com|={diag['com_pos']:.2e}, |vcom|={diag['com_vel']:.2e})")
    if spec.equilibrium:
        q = diag["virial_ratio"]
        if abs(q - 0.5) > VIRIAL_TOL:
            raise ScenarioError(
                f"{spec.name}: virial ratio {q:.3f} outside "
                f"0.5 +/- {VIRIAL_TOL} for an equilibrium model")


# --------------------------------------------------------------------------
# unit handling
# --------------------------------------------------------------------------
def _recenter(pos, vel, mass) -> Tuple[np.ndarray, np.ndarray]:
    m = mass.sum()
    return (pos - (mass[:, None] * pos).sum(0) / m,
            vel - (mass[:, None] * vel).sum(0) / m)


def to_standard_units(pos, vel, mass, q_target: float = None
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Rescale a bound system to E = -1/4 at virial ratio ``q_target``.

    With T' = Q|U'| and E' = T' + U' = -(1-Q)|U'| = -1/4, the target energies
    are fixed by Q alone; positions scale by |U|/|U'| and velocities by
    sqrt(T'/T).  ``q_target=None`` preserves the measured ratio; equilibrium
    models pass Q = 0.5, which also absorbs any inconsistency between the
    generator's raw length and velocity units (e.g. the King sample's core
    radius vs sigma).  Q = 0 (cold) degenerates to a pure position rescale.
    """
    t = 0.5 * float((mass * (vel * vel).sum(-1)).sum())
    u = _pairwise_potential(pos, mass)
    if u >= 0:
        raise ScenarioError(f"cannot rescale an unbound system (U={u:.3e})")
    q = t / abs(u) if q_target is None else q_target
    if q >= 1.0:
        raise ScenarioError(f"cannot rescale: virial ratio {q:.3f} >= 1")
    u_target = 1.0 / (4.0 * (1.0 - q))        # |U'|
    t_target = q * u_target                   # T'
    pos = pos * (abs(u) / u_target)
    if t > 0:
        vel = vel * math.sqrt(t_target / t)
    return pos, vel


def _iso_dirs(rng: np.random.Generator, n: int) -> np.ndarray:
    u = rng.uniform(-1.0, 1.0, n)
    phi = rng.uniform(0.0, 2.0 * np.pi, n)
    st = np.sqrt(1.0 - u * u)
    return np.stack([st * np.cos(phi), st * np.sin(phi), u], axis=1)


# --------------------------------------------------------------------------
# build
# --------------------------------------------------------------------------
def build(scenario: Scenario, *, validate: bool = True) -> ParticleState:
    """Generate, recentre, rescale and validate one scenario."""
    spec = get_spec(scenario.name)
    if scenario.n < spec.min_n:
        raise ScenarioError(
            f"{scenario.name}: n={scenario.n} below minimum {spec.min_n}")
    unknown = set(scenario.params) - set(spec.defaults)
    if unknown:
        raise ScenarioError(
            f"{scenario.name}: unknown parameter(s) {sorted(unknown)}; "
            f"accepts {sorted(spec.defaults)}")
    params = {**spec.defaults, **dict(scenario.params)}
    rng = np.random.default_rng(scenario.seed)
    pos, vel, mass = spec.generator(scenario.n, rng, **params)
    pos = np.asarray(pos, np.float64)
    vel = np.asarray(vel, np.float64)
    mass = np.asarray(mass, np.float64)
    pos, vel = _recenter(pos, vel, mass)
    if spec.rescale:  # scaling preserves the COM frame
        pos, vel = to_standard_units(
            pos, vel, mass, q_target=0.5 if spec.equilibrium else None)
    if validate:
        _validate(spec, diagnostics(pos, vel, mass))
    dtype = scenario.dtype
    return zeros_like_state(jnp.asarray(pos, dtype), jnp.asarray(vel, dtype),
                            jnp.asarray(mass, dtype))


def make(name: str, n: int, *, seed: int = 0, dtype=jnp.float64,
         validate: bool = True, **params) -> ParticleState:
    """Convenience one-shot: ``make("king", 256, w0=6.0)``."""
    return build(Scenario(name=name, n=n, seed=seed, dtype=dtype,
                          params=params), validate=validate)


# --------------------------------------------------------------------------
# padded packing: heterogeneous scenarios into one rectangular batch
# --------------------------------------------------------------------------
def pad_state(state: ParticleState, n_max: int) -> ParticleState:
    """Pad a state with zero-mass particles up to ``n_max`` rows.

    Mask contract (tested by ``tests/test_padding_invariance.py``): a padding
    row carries zero mass, zero velocity and zero derivatives, so it is

    * **invisible as a source** — the kernels guarantee m = 0 rows contribute
      exactly zero force, jerk, snap and potential to every other particle;
    * **inert as a target** — the ensemble engine's mask zeroes its evaluated
      derivatives, so it stays frozen at its (arbitrary) padding position and
      never influences the shared-adaptive timestep;
    * **invisible to diagnostics** — kinetic, potential and virial accounting
      are mass-weighted, so energy drift counts only active particles.
    """
    n = state.pos.shape[0]
    if n > n_max:
        raise ScenarioError(f"cannot pad n={n} down to n_max={n_max}")

    def pad(x):
        if x.ndim == 0:                       # the scalar time leaf
            return x
        return jnp.pad(x, ((0, n_max - n),) + ((0, 0),) * (x.ndim - 1))

    return jax.tree_util.tree_map(pad, state)


def build_padded(specs: Sequence[Scenario], n_max: Optional[int] = None, *,
                 validate: bool = True) -> Tuple[ParticleState, jax.Array]:
    """Pack heterogeneous scenario specs into one ``(B, N_max, ...)`` batch.

    Each spec is built independently (its own generator, N and seed), padded
    with zero-mass particles to ``n_max`` (default: the largest member's N)
    and stacked on a new leading batch axis.  Returns ``(batched, n_active)``
    where ``n_active`` is the ``(B,)`` int32 vector of real particle counts —
    the mask the ensemble engine and telemetry honour (see :func:`pad_state`
    for the full contract).
    """
    specs = list(specs)
    if not specs:
        raise ScenarioError("build_padded needs at least one scenario spec")
    states = [build(s, validate=validate) for s in specs]
    ns = [int(s.pos.shape[0]) for s in states]
    if n_max is None:
        n_max = max(ns)
    if n_max < max(ns):
        raise ScenarioError(
            f"n_max={n_max} below the largest member N={max(ns)}")
    padded = [pad_state(s, n_max) for s in states]
    batched = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *padded)
    return batched, jnp.asarray(ns, jnp.int32)


def parse_mix_token(token: str) -> Tuple[str, Optional[int]]:
    """Parse one CLI scenario token ``name[:N]`` -> ``(name, n_or_None)``.

    ``"king:256"`` -> ``("king", 256)``; a bare ``"king"`` leaves N to the
    caller's ``--n`` default.  The name is validated against the registry.
    Thin tuple view over :meth:`ScenarioSpec.parse` (the typed surface).
    """
    spec = ScenarioSpec.parse(token)
    return spec.name, spec.n


def make_mix(mix: Sequence[Tuple[str, int]], *, seed: int = 0,
             repeat: int = 1, dtype=jnp.float64,
             params: Optional[Mapping[str, Any]] = None) -> List[Scenario]:
    """Expand ``[(name, n), ...]`` into Scenario specs with distinct seeds.

    ``repeat`` tiles the whole mix (seeds keep incrementing), so a 3-scenario
    mix with ``repeat=2`` yields a B=6 padded batch.  Per-scenario ``params``
    are looked up by name in ``params`` (a mapping name -> kwargs) when given.
    """
    specs: List[Scenario] = []
    i = 0
    for _ in range(max(1, repeat)):
        for name, n in mix:
            kw = dict((params or {}).get(name, {}))
            specs.append(Scenario(name=name, n=n, seed=seed + i, dtype=dtype,
                                  params=kw))
            i += 1
    return specs


# --------------------------------------------------------------------------
# adapters for the seed's hard-coded initial conditions
# --------------------------------------------------------------------------
@register("plummer", equilibrium=True, rescale=False,
          description="Plummer sphere (seed recipe, already standard units)")
def _plummer(n: int, rng: np.random.Generator) -> Arrays:
    state = nbody.plummer(n, seed=int(rng.integers(0, 2**31 - 1)))
    return (np.asarray(state.pos, np.float64),
            np.asarray(state.vel, np.float64),
            np.asarray(state.mass, np.float64))


@register("two_body", equilibrium=True, rescale=False, min_n=2,
          description="equal-mass circular binary (analytic test case)")
def _two_body(n: int, rng: np.random.Generator) -> Arrays:
    del rng  # fixed analytic configuration
    if n != 2:
        raise ScenarioError(f"two_body is exactly 2 bodies; got n={n} "
                            "(telemetry would misreport the particle count)")
    state = nbody.two_body_circular()
    return (np.asarray(state.pos, np.float64),
            np.asarray(state.vel, np.float64),
            np.asarray(state.mass, np.float64))


# --------------------------------------------------------------------------
# King model (lowered isothermal sphere, W0-parameterised)
# --------------------------------------------------------------------------
_erf = np.vectorize(math.erf)


def _king_density(w: np.ndarray) -> np.ndarray:
    """Dimensionless King DF density rho(W) (zero for W <= 0)."""
    w = np.maximum(w, 0.0)
    rho = np.exp(w) * _erf(np.sqrt(w)) \
        - np.sqrt(4.0 * w / np.pi) * (1.0 + 2.0 * w / 3.0)
    return np.maximum(rho, 0.0)


def _king_profile(w0: float, dx: float = 2e-3, x_max: float = 1e3):
    """Integrate the King ODE outward; returns (x, W(x), M(x)) grids.

    (1/x^2) d/dx (x^2 dW/dx) = -9 rho(W)/rho(W0), W(0)=W0, W'(0)=0;
    the enclosed mass is M(x) = -x^2 W'(x) up to a constant factor.
    """
    rho0 = float(_king_density(np.asarray([w0]))[0])

    def rhs(x, y):
        w, dw = y
        rho = float(_king_density(np.asarray([w]))[0]) / rho0
        return np.asarray([dw, -9.0 * rho - 2.0 * dw / x])

    # series start (W ~ W0 - 1.5 x^2 near the centre)
    x = 1e-4
    y = np.asarray([w0 - 1.5 * x * x, -3.0 * x])
    xs, ws, ms = [x], [y[0]], [-x * x * y[1]]
    while y[0] > 0.0 and x < x_max:
        h = min(dx * max(x, 1.0), 0.25)
        k1 = rhs(x, y)
        k2 = rhs(x + h / 2, y + h / 2 * k1)
        k3 = rhs(x + h / 2, y + h / 2 * k2)
        k4 = rhs(x + h, y + h * k3)
        y = y + h / 6 * (k1 + 2 * k2 + 2 * k3 + k4)
        x += h
        xs.append(x)
        ws.append(max(float(y[0]), 0.0))
        ms.append(-x * x * float(y[1]))
    return np.asarray(xs), np.asarray(ws), np.asarray(ms)


@register("king", equilibrium=True, w0=6.0,
          description="King model; w0 sets the concentration")
def _king(n: int, rng: np.random.Generator, *, w0: float = 6.0) -> Arrays:
    if not 0.5 <= w0 <= 16.0:
        raise ScenarioError(f"king: w0={w0} outside the supported (0.5, 16)")
    xs, ws, ms = _king_profile(float(w0))

    # radii from the cumulative mass profile (inverse-CDF interpolation)
    u = rng.uniform(0.0, ms[-1], n)
    r = np.interp(u, ms, xs)
    w_r = np.interp(r, xs, ws)
    pos = r[:, None] * _iso_dirs(rng, n)

    # speeds from f(v) ~ v^2 (exp(W - v^2/2) - 1), v in [0, sqrt(2W)],
    # rejection-sampled under a per-particle numerical envelope
    vmax = np.sqrt(2.0 * np.maximum(w_r, 1e-12))
    grid = np.linspace(0.0, 1.0, 64)[None, :] * vmax[:, None]
    g = grid**2 * (np.exp(w_r[:, None] - grid**2 / 2.0) - 1.0)
    envelope = 1.05 * np.maximum(g.max(axis=1), 1e-300)
    v = np.zeros(n)
    todo = np.ones(n, bool)
    while todo.any():
        idx = np.flatnonzero(todo)
        cand = rng.uniform(0.0, vmax[idx])
        gval = cand**2 * (np.exp(w_r[idx] - cand**2 / 2.0) - 1.0)
        ok = rng.uniform(0.0, envelope[idx]) < gval
        v[idx[ok]] = cand[ok]
        todo[idx[ok]] = False
    vel = v[:, None] * _iso_dirs(rng, n)
    mass = np.full(n, 1.0 / n)
    return pos, vel, mass


# --------------------------------------------------------------------------
# cold uniform-sphere collapse
# --------------------------------------------------------------------------
@register("cold_collapse", equilibrium=False, virial_ratio=0.0,
          description="uniform sphere with (near-)zero initial kinetic energy")
def _cold_collapse(n: int, rng: np.random.Generator, *,
                   virial_ratio: float = 0.0) -> Arrays:
    if not 0.0 <= virial_ratio < 1.0:
        raise ScenarioError(
            f"cold_collapse: virial_ratio={virial_ratio} outside [0, 1)")
    r = rng.uniform(0.0, 1.0, n) ** (1.0 / 3.0)   # uniform in the ball
    pos = r[:, None] * _iso_dirs(rng, n)
    vel = rng.standard_normal((n, 3))
    mass = np.full(n, 1.0 / n)
    # scale the velocity field so T/|U| hits the requested (sub-virial) ratio
    u = abs(_pairwise_potential(pos, mass))
    t = 0.5 * float((mass * (vel * vel).sum(-1)).sum())
    target_t = virial_ratio * u
    vel *= 0.0 if target_t == 0.0 else math.sqrt(target_t / t)
    return pos, vel, mass


# --------------------------------------------------------------------------
# two-cluster merger (offset Plummer spheres on an approach orbit)
# --------------------------------------------------------------------------
@register("merger", equilibrium=False, rescale=False, min_n=16,
          separation=4.0, impact_parameter=0.5, v_scale=1.0,
          description="two Plummer spheres on a (near-)parabolic approach")
def _merger(n: int, rng: np.random.Generator, *, separation: float = 4.0,
            impact_parameter: float = 0.5, v_scale: float = 1.0) -> Arrays:
    """Each half is an internally virialised Plummer sphere of mass 1/2
    (mass m -> m/2 keeps equilibrium when v -> v/sqrt(2)); the halves
    approach with v_scale x the parabolic two-point-mass speed."""
    if separation <= 0:
        raise ScenarioError(f"merger: separation={separation} must be > 0")
    n_a = n // 2
    halves = []
    for n_h in (n_a, n - n_a):
        s = nbody.plummer(n_h, seed=int(rng.integers(0, 2**31 - 1)))
        halves.append((np.asarray(s.pos, np.float64),
                       np.asarray(s.vel, np.float64) / math.sqrt(2.0),
                       np.asarray(s.mass, np.float64) / 2.0))
    d = math.hypot(separation, impact_parameter)
    v_par = v_scale * math.sqrt(2.0 * 1.0 / d)    # G * (M_a + M_b) = 1
    offset = np.asarray([separation / 2.0, impact_parameter / 2.0, 0.0])
    approach = np.asarray([v_par / 2.0, 0.0, 0.0])
    (pa, va, ma), (pb, vb, mb) = halves
    pos = np.concatenate([pa + offset, pb - offset])
    vel = np.concatenate([va - approach, vb + approach])
    mass = np.concatenate([ma, mb])
    return pos, vel, mass


# --------------------------------------------------------------------------
# binary-rich Plummer sphere
# --------------------------------------------------------------------------
@register("binary_plummer", equilibrium=True, rescale=False, min_n=16,
          binary_frac=0.1, sma=0.02,
          description="Plummer sphere with a fraction of stars in tight "
                      "circular binaries")
def _binary_plummer(n: int, rng: np.random.Generator, *,
                    binary_frac: float = 0.1, sma: float = 0.02) -> Arrays:
    """k centres of a Plummer model are each split into an equal-mass
    circular binary of semi-major axis ``sma``; a circular binary satisfies
    2T = |U| instantaneously, so the global virial ratio stays ~0.5."""
    if not 0.0 <= binary_frac <= 1.0:
        raise ScenarioError(f"binary_plummer: binary_frac={binary_frac}")
    k = int(round(binary_frac * n / 2.0))
    k = min(k, n // 2)
    base = nbody.plummer(n - k, seed=int(rng.integers(0, 2**31 - 1)))
    pos = np.asarray(base.pos, np.float64)
    vel = np.asarray(base.vel, np.float64)
    mass = np.asarray(base.mass, np.float64)
    if k == 0:
        return pos, vel, mass
    centres = rng.choice(n - k, size=k, replace=False)
    sep = _iso_dirs(rng, k)
    # orbit direction: any unit vector orthogonal to the separation axis
    tmp = _iso_dirs(rng, k)
    orb = np.cross(sep, tmp)
    orb /= np.linalg.norm(orb, axis=1, keepdims=True)
    m_c = mass[centres]
    v_orb = 0.5 * np.sqrt(m_c / sma)   # each component about the binary COM
    pos_a = pos[centres] + 0.5 * sma * sep
    pos_b = pos[centres] - 0.5 * sma * sep
    vel_a = vel[centres] + v_orb[:, None] * orb
    vel_b = vel[centres] - v_orb[:, None] * orb
    keep = np.setdiff1d(np.arange(n - k), centres)
    pos = np.concatenate([pos[keep], pos_a, pos_b])
    vel = np.concatenate([vel[keep], vel_a, vel_b])
    mass = np.concatenate([mass[keep], m_c / 2.0, m_c / 2.0])
    return pos, vel, mass


# --------------------------------------------------------------------------
# Keplerian disk around a dominant central mass
# --------------------------------------------------------------------------
@register("kepler_disk", equilibrium=True, rescale=False, min_n=8,
          m_central=0.99, r_in=0.1, r_out=1.0, aspect=0.02,
          description="near-circular Keplerian disk around a central mass")
def _kepler_disk(n: int, rng: np.random.Generator, *, m_central: float = 0.99,
                 r_in: float = 0.1, r_out: float = 1.0,
                 aspect: float = 0.02) -> Arrays:
    """Central point mass + (n-1)-particle disk, surface density ~ 1/r
    (uniform in radius), on circular orbits with small vertical structure.
    Every circular orbit satisfies 2T = |U| in the dominant potential, so
    the disk as a whole sits at virial ratio ~0.5."""
    if not 0.5 <= m_central < 1.0:
        raise ScenarioError(f"kepler_disk: m_central={m_central} not in "
                            "[0.5, 1)")
    if not 0.0 < r_in < r_out:
        raise ScenarioError(f"kepler_disk: need 0 < r_in < r_out, got "
                            f"({r_in}, {r_out})")
    n_d = n - 1
    m_disk = (1.0 - m_central) / n_d
    r = rng.uniform(r_in, r_out, n_d)            # Sigma ~ 1/r
    phi = rng.uniform(0.0, 2.0 * np.pi, n_d)
    z = aspect * r * rng.standard_normal(n_d)
    pos_d = np.stack([r * np.cos(phi), r * np.sin(phi), z], axis=1)
    # circular speed in the monopole field of everything interior
    order = np.argsort(r)
    m_enc = np.empty(n_d)
    m_enc[order] = m_central + m_disk * np.arange(n_d)
    v_c = np.sqrt(m_enc / r)
    vel_d = np.stack([-v_c * np.sin(phi), v_c * np.cos(phi),
                      np.zeros(n_d)], axis=1)
    pos = np.concatenate([np.zeros((1, 3)), pos_d])
    vel = np.concatenate([np.zeros((1, 3)), vel_d])
    mass = np.concatenate([[m_central], np.full(n_d, m_disk)])
    return pos, vel, mass
