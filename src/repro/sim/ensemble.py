"""Batched ensemble engine: B independent simulations in one traced loop.

Independent runs are stacked on a leading batch axis of every
``ParticleState`` leaf and advanced in lockstep: the full Hermite
predict-evaluate-correct step is lifted over the batch with ``jax.vmap``,
the step loop is a single ``lax.scan``, and the batch axis carries a
sharding constraint over a 1-D device mesh (built by
``repro.core.strategies.make_batch_mesh``), so many small-N runs fill the
hardware the way one large-N run does.

Because the runs are independent there is *no cross-run communication*: all
of the paper's distribution strategies coincide on the batch axis (the
strategy label is accepted for CLI symmetry and recorded in telemetry).

**Kernels.** Per-run force evaluation routes through either the reference
all-pairs op (``kernel="ref"``, i.e. ``impl="xla"``), the tiled Pallas
kernel (``kernel="pallas"`` — compiled on TPU, ``interpret=True`` elsewhere;
``pallas_call`` is vmap-safe, the batch axis simply prepends a grid
dimension), or the FP64 golden reference (``impl="fp64"``).

**Masking (ragged batches).** Heterogeneous mixes are packed by
``repro.sim.scenarios.build_padded`` into a rectangular ``(B, N_max, ...)``
batch plus a per-run ``n_active`` vector.  Rows ``>= n_active[b]`` are
padding: zero mass makes them invisible as force *sources* (a kernel
invariant, property-tested), and the engine's per-member mask zeroes their
evaluated derivatives so they are inert as *targets* — frozen in place, with
no influence on the per-run Aarseth timestep (zero acc/jerk/snap falls into
the ``num > 0`` guard) nor on mass-weighted energy diagnostics.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import hermite, nbody
from repro.core.evaluate import make_evaluator
from repro.core.hermite import Evaluation
from repro.core.nbody import ParticleState
from repro.core.strategies import STRATEGIES, make_batch_mesh
from repro.kernels import ops

BATCH_AXIS = "ensemble"
#: vmap-safe evaluation paths (the Pallas kernel batches by grid extension)
ENSEMBLE_IMPLS = ("xla", "fp64", "pallas", "pallas_interpret")
#: user-facing force-kernel switch: "ref" (all-pairs XLA op) | "pallas"
KERNELS = ("ref", "pallas")


def resolve_kernel(kernel: Optional[str]) -> str:
    """Map the user-facing ``kernel`` switch to an evaluation ``impl``.

    ``"ref"`` is the blocked all-pairs XLA op; ``"pallas"`` is the tiled
    kernel — compiled where Mosaic can lower (TPU), interpreted elsewhere so
    the same kernel body is validated on CPU.
    """
    if kernel in (None, "ref"):
        return "xla"
    if kernel == "pallas":
        return ops.default_impl()
    raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")


def resolve_eval_impl(impl: Optional[str], kernel: Optional[str], *,
                      default: Optional[str] = "xla") -> Optional[str]:
    """Resolve the (``impl``, ``kernel``) pair to one evaluation impl.

    The user-facing ``kernel`` switch and the low-level ``impl`` are
    mutually exclusive when both are explicit: silently preferring one
    could e.g. turn a requested ``impl="fp64"`` golden-reference run into
    FP32 with no trace in the report.
    """
    if kernel is not None:
        if impl is not None:
            raise ValueError(
                f"pass either impl={impl!r} or kernel={kernel!r}, not both")
        return resolve_kernel(kernel)
    return impl if impl is not None else default


# --------------------------------------------------------------------------
# batch packing
# --------------------------------------------------------------------------
def stack_states(states: Sequence[ParticleState]) -> ParticleState:
    """Pack independent runs (same N) into one leading-batch-axis state."""
    if not states:
        raise ValueError("need at least one state")
    ns = {s.pos.shape[0] for s in states}
    if len(ns) != 1:
        raise ValueError(f"all ensemble members must share N; got {ns}")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_states(batched: ParticleState) -> List[ParticleState]:
    b = batch_size(batched)
    return [jax.tree_util.tree_map(lambda x: x[i], batched) for i in range(b)]


def batch_size(batched: ParticleState) -> int:
    return batched.pos.shape[0]


def batched_total_energy(batched: ParticleState) -> jax.Array:
    """(B,) total energy per ensemble member.

    Mass-weighted, so zero-mass padding rows contribute nothing — padded and
    unpadded batches of the same runs report identical energies.
    """
    return jax.vmap(nbody.total_energy)(batched)


def batched_virial_ratio(batched: ParticleState) -> jax.Array:
    """(B,) virial ratio T/|U| per member (mass-weighted: padding-blind)."""
    t = jax.vmap(nbody.kinetic_energy)(batched)
    u = jax.vmap(nbody.potential_energy)(batched)
    tiny = jnp.asarray(jnp.finfo(t.dtype).tiny, t.dtype)  # fp32-safe clamp
    return t / jnp.maximum(jnp.abs(u), tiny)


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
def _inner_evaluator(order: int, eps: float, impl: str):
    if impl == "fp64":
        return make_evaluator(precision="fp64", order=order, eps=eps)
    if impl not in ENSEMBLE_IMPLS:
        raise ValueError(
            f"ensemble impl must be one of {ENSEMBLE_IMPLS} (the vmappable "
            f"evaluation paths); got {impl!r}")
    return make_evaluator(order=order, eps=eps, impl=impl)


def _mask_evaluator(ev, n_active):
    """Zero the evaluated derivatives of padding rows (>= ``n_active``).

    Sources with m = 0 already contribute zero force (kernel invariant);
    masking the *outputs* additionally freezes padding rows as targets, so
    they never drift into the active set and never tighten the per-run
    Aarseth timestep.  With ``n_active == N`` the mask is all-ones and the
    multiply is an exact identity.
    """

    def evaluate(pos, vel, mass) -> Evaluation:
        out = ev(pos, vel, mass)
        active = jnp.arange(pos.shape[0]) < n_active
        m3 = active.astype(out.acc.dtype)[:, None]
        return Evaluation(acc=out.acc * m3, jerk=out.jerk * m3,
                          snap=out.snap * m3,
                          pot=out.pot * active.astype(out.pot.dtype))

    return evaluate


def _constrain(tree, mesh):
    """Shard the leading (batch) axis of every leaf over the mesh."""
    if mesh is None:
        return tree

    def one(x):
        spec = P(BATCH_AXIS, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(one, tree)


@functools.lru_cache(maxsize=64)
def _engine(order: int, eps: float, impl: str, mesh):
    ev = _inner_evaluator(order, eps, impl)

    @jax.jit
    def init(batched: ParticleState, n_active) -> ParticleState:
        batched, n_active = _constrain((batched, n_active), mesh)
        out = jax.vmap(
            lambda s, na: hermite.initialize(s, _mask_evaluator(ev, na))
        )(batched, n_active)
        return _constrain(out, mesh)

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def run(batched: ParticleState, n_active, dt, n_steps: int
            ) -> ParticleState:
        batched, n_active = _constrain((batched, n_active), mesh)

        def body(s, _):
            s1 = jax.vmap(
                lambda m, na: hermite.step(m, dt.astype(m.dtype),
                                           _mask_evaluator(ev, na),
                                           order=order)
            )(s, n_active)
            return _constrain(s1, mesh), None

        out, _ = jax.lax.scan(body, batched, None, length=n_steps)
        return out

    return init, run


def _pad_batch(tree, p: int):
    """Pad B to a multiple of the device count by repeating the first run.

    Works on any pytree whose leaves carry the batch on the leading axis
    (a ParticleState, or a tuple of per-run carries).
    """
    b = jax.tree_util.tree_leaves(tree)[0].shape[0]
    if p <= 1 or b % p == 0:
        return tree, b
    pad = p - b % p
    padded = jax.tree_util.tree_map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)]),
        tree)
    return padded, b


def _batch_mesh(devices) -> Optional[object]:
    if devices is None:
        return None
    devices = list(devices)
    if len(devices) <= 1:
        return None
    return make_batch_mesh(devices, axis_name=BATCH_AXIS)


def _as_n_active(batched: ParticleState, n_active) -> jax.Array:
    """Normalize ``n_active`` to a (B,) int32 vector (default: all active)."""
    b, n = batched.pos.shape[0], batched.pos.shape[1]
    if n_active is None:
        return jnp.full((b,), n, jnp.int32)
    n_active = jnp.asarray(n_active, jnp.int32)
    if n_active.shape != (b,):
        raise ValueError(
            f"n_active must have shape ({b},) for a B={b} batch; "
            f"got {n_active.shape}")
    return n_active


def ensemble_initialize(
    batched: ParticleState,
    *,
    n_active=None,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    devices: Optional[Sequence[jax.Device]] = None,
) -> ParticleState:
    """Bootstrap derivatives for every ensemble member (batched t=0 pass)."""
    mesh = _batch_mesh(devices)
    init, _ = _engine(order, eps, impl, mesh)
    n_active = _as_n_active(batched, n_active)
    (padded, na), b = _pad_batch((batched, n_active),
                                 mesh.size if mesh else 1)
    out = init(padded, na)
    return jax.tree_util.tree_map(lambda x: x[:b], out)


def ensemble_run(
    batched: ParticleState,
    *,
    n_steps: int,
    dt: float,
    n_active=None,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    devices: Optional[Sequence[jax.Device]] = None,
) -> ParticleState:
    """Advance an *initialized* batched state by ``n_steps`` fixed-dt steps."""
    mesh = _batch_mesh(devices)
    _, run = _engine(order, eps, impl, mesh)
    n_active = _as_n_active(batched, n_active)
    (padded, na), b = _pad_batch((batched, n_active),
                                 mesh.size if mesh else 1)
    out = run(padded, na, jnp.asarray(dt, batched.pos.dtype), n_steps)
    return jax.tree_util.tree_map(lambda x: x[:b], out)


@functools.lru_cache(maxsize=64)
def _adaptive_engine(order: int, eps: float, impl: str, mesh,
                     eta: float, dt_max: float):
    """Per-run shared-adaptive (Aarseth) lockstep engine.

    Each run carries its own timestep: ``aarseth_dt`` is evaluated per
    ensemble member under vmap, rate-limited against the member's previous
    step, and clamped to its remaining time.  Members that have reached
    ``t_end`` keep stepping in lockstep (the batch is rectangular) but their
    state is frozen by a per-run select — wasted flops, never wrong physics.
    """
    ev = _inner_evaluator(order, eps, impl)

    def one_step(s, hp, na, t_end):
        remaining = t_end - s.time
        active = remaining > 0.0
        # padding rows carry zero derivatives (masked evaluator), so they
        # fall into aarseth_dt's num > 0 guard and never tighten the step
        h = hermite.aarseth_dt(s, eta=eta, dt_max=dt_max)
        # rate-limit dt changes (noise robustness; hp <= 0 marks "first step")
        h = jnp.where(hp > 0.0,
                      jnp.minimum(jnp.maximum(h, 0.5 * hp), 2.0 * hp), h)
        h = jnp.minimum(h, jnp.maximum(remaining, 1e-12))
        h_safe = jnp.where(active, h, jnp.ones_like(h))  # corrector / h^3
        s1 = hermite.step(s, h_safe.astype(s.dtype), _mask_evaluator(ev, na),
                          order=order)
        s1 = jax.tree_util.tree_map(
            lambda new, old: jnp.where(active, new, old), s1, s)
        return s1, jnp.where(active, h, hp), active

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def run(batched, h_prev, n_taken, n_active, t_end, n_steps: int):
        batched, n_active = _constrain((batched, n_active), mesh)

        def body(carry, _):
            s, hp, cnt = carry
            s1, hp1, active = jax.vmap(one_step, in_axes=(0, 0, 0, None))(
                s, hp, n_active, t_end)
            return (_constrain(s1, mesh), hp1,
                    cnt + active.astype(cnt.dtype)), None

        carry, _ = jax.lax.scan(body, (batched, h_prev, n_taken), None,
                                length=n_steps)
        return carry

    return run


def ensemble_run_adaptive(
    batched: ParticleState,
    *,
    t_end: float,
    n_steps: int,
    h_prev: Optional[jax.Array] = None,
    n_taken: Optional[jax.Array] = None,
    n_active=None,
    eta: float = 0.02,
    dt_max: float = 0.0625,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    devices: Optional[Sequence[jax.Device]] = None,
):
    """Advance an initialized batch by up to ``n_steps`` adaptive steps each.

    Returns ``(batched, h_prev, n_taken)``; call again with the returned
    carries until ``batched.time.min() >= t_end``.  ``n_taken`` counts the
    *productive* steps per run (frozen lockstep steps excluded).
    """
    mesh = _batch_mesh(devices)
    run = _adaptive_engine(order, eps, impl, mesh, eta, dt_max)
    dtype = batched.pos.dtype
    if h_prev is None:
        h_prev = jnp.zeros(batch_size(batched), dtype)
    if n_taken is None:
        n_taken = jnp.zeros(batch_size(batched), jnp.int32)
    n_active = _as_n_active(batched, n_active)
    carry, b = _pad_batch((batched, h_prev, n_taken, n_active),
                          mesh.size if mesh else 1)
    out, hp, cnt = run(*carry, jnp.asarray(t_end, dtype), n_steps)
    return tuple(jax.tree_util.tree_map(lambda x: x[:b], t)
                 for t in (out, hp, cnt))


def evolve_ensemble(
    states,
    *,
    n_steps: int,
    dt: float,
    n_active=None,
    order: int = 6,
    eps: float = 1e-7,
    impl: Optional[str] = None,
    kernel: Optional[str] = None,
    devices: Optional[Sequence[jax.Device]] = None,
    strategy: str = "replicated",
) -> ParticleState:
    """One-shot convenience: stack (if needed), initialize, evolve.

    ``strategy`` is validated against the known strategy names but — the runs
    being independent — only affects telemetry labeling, not the math.
    Pass at most one of ``impl`` (low-level path, default "xla") and
    ``kernel`` ("ref" | "pallas"); an explicit pair conflicts.
    """
    if strategy not in STRATEGIES and strategy != "single":
        raise ValueError(
            f"unknown strategy {strategy!r}; one of {('single',) + STRATEGIES}")
    impl = resolve_eval_impl(impl, kernel)
    batched = states if isinstance(states, ParticleState) else \
        stack_states(list(states))
    kw = dict(n_active=n_active, order=order, eps=eps, impl=impl,
              devices=devices)
    batched = ensemble_initialize(batched, **kw)
    return ensemble_run(batched, n_steps=n_steps, dt=dt, **kw)
