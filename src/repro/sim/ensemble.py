"""Batched ensemble engine: B independent simulations in one traced loop.

Independent runs are stacked on a leading batch axis of every
``ParticleState`` leaf and advanced in lockstep: the full Hermite
predict-evaluate-correct step is lifted over the batch with ``jax.vmap``,
the step loop is a single ``lax.scan``, and the batch axis carries a
sharding constraint over a 1-D device mesh (built by
``repro.core.strategies.make_batch_mesh``), so many small-N runs fill the
hardware the way one large-N run does.

Because the runs are independent there is *no cross-run communication*: all
of the paper's distribution strategies coincide on the batch axis (the
strategy label is accepted for CLI symmetry and recorded in telemetry).

**Kernels.** Per-run force evaluation routes through either the reference
all-pairs op (``kernel="ref"``, i.e. ``impl="xla"``), the tiled Pallas
kernel (``kernel="pallas"`` — compiled on TPU, ``interpret=True`` elsewhere;
``pallas_call`` is vmap-safe, the batch axis simply prepends a grid
dimension), or the FP64 golden reference (``impl="fp64"``).

**Steppers.** Three timestep modes share the engine: fixed dt
(``ensemble_run``), per-run shared-adaptive Aarseth lockstep
(``ensemble_run_adaptive``), and hierarchical block timesteps
(``ensemble_run_block``) — per-particle power-of-two levels inside each
member, only the active block evaluated per substep, measured per-run
force-evaluation and grid-tile counts returned for telemetry.  The block
stepper's ``compaction="gather"`` mode additionally gathers each event's
active targets into a dense block-aligned buffer (static capacity buckets,
``lax.switch``-dispatched) so the kernel grid *shrinks* to the live block
instead of masking it — bit-for-bit identical physics, far fewer tiles
launched (see ``core.evaluate.make_block_evaluator``).

**Masking (ragged batches).** Heterogeneous mixes are packed by
``repro.sim.scenarios.build_padded`` into a rectangular ``(B, N_max, ...)``
batch plus a per-run ``n_active`` vector.  Rows ``>= n_active[b]`` are
padding: zero mass makes them invisible as force *sources* (a kernel
invariant, property-tested), and the engine's per-member mask zeroes their
evaluated derivatives so they are inert as *targets* — frozen in place, with
no influence on the per-run Aarseth timestep (zero acc/jerk/snap falls into
the ``num > 0`` guard) nor on mass-weighted energy diagnostics.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import hermite, nbody
from repro.core.evaluate import (make_block_evaluator, make_evaluator,
                                 make_neighbor_block_evaluator,
                                 shared_cap_index)
from repro.core.hermite import Evaluation
from repro.core.nbody import ParticleState
from repro.core.strategies import (STRATEGIES, make_batch_mesh,
                                   make_fused_mesh)
from repro.kernels import nbody_force, neighbor, ops
from repro.obs import metrics as obs_metrics

BATCH_AXIS = "ensemble"
#: vmap-safe evaluation paths (the Pallas kernel batches by grid extension)
ENSEMBLE_IMPLS = ("xla", "fp64", "pallas", "pallas_interpret")
#: user-facing force-kernel switch: "ref" (all-pairs XLA op) | "pallas"
KERNELS = ("ref", "pallas")
#: stepper modes of the ensemble engine (see docs/ensembles.md)
STEPPERS = ("fixed", "adaptive", "block")


def resolve_kernel(kernel: Optional[str]) -> str:
    """Map the user-facing ``kernel`` switch to an evaluation ``impl``.

    ``"ref"`` is the blocked all-pairs XLA op; ``"pallas"`` is the tiled
    kernel — compiled where Mosaic can lower (TPU), interpreted elsewhere so
    the same kernel body is validated on CPU.
    """
    if kernel in (None, "ref"):
        return "xla"
    if kernel == "pallas":
        return ops.default_impl()
    raise ValueError(f"unknown kernel {kernel!r}; one of {KERNELS}")


def resolve_eval_impl(impl: Optional[str], kernel: Optional[str], *,
                      default: Optional[str] = "xla") -> Optional[str]:
    """Resolve the (``impl``, ``kernel``) pair to one evaluation impl.

    The user-facing ``kernel`` switch and the low-level ``impl`` are
    mutually exclusive when both are explicit: silently preferring one
    could e.g. turn a requested ``impl="fp64"`` golden-reference run into
    FP32 with no trace in the report.
    """
    if kernel is not None:
        if impl is not None:
            raise ValueError(
                f"pass either impl={impl!r} or kernel={kernel!r}, not both")
        return resolve_kernel(kernel)
    return impl if impl is not None else default


# --------------------------------------------------------------------------
# batch packing
# --------------------------------------------------------------------------
def stack_states(states: Sequence[ParticleState]) -> ParticleState:
    """Pack independent runs (same N) into one leading-batch-axis state."""
    if not states:
        raise ValueError("need at least one state")
    ns = {s.pos.shape[0] for s in states}
    if len(ns) != 1:
        raise ValueError(f"all ensemble members must share N; got {ns}")
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_states(batched: ParticleState) -> List[ParticleState]:
    b = batch_size(batched)
    return [jax.tree_util.tree_map(lambda x: x[i], batched) for i in range(b)]


def batch_size(batched: ParticleState) -> int:
    return batched.pos.shape[0]


def batched_total_energy(batched: ParticleState) -> jax.Array:
    """(B,) total energy per ensemble member.

    Mass-weighted, so zero-mass padding rows contribute nothing — padded and
    unpadded batches of the same runs report identical energies.
    """
    return jax.vmap(nbody.total_energy)(batched)


def batched_virial_ratio(batched: ParticleState) -> jax.Array:
    """(B,) virial ratio T/|U| per member (mass-weighted: padding-blind)."""
    t = jax.vmap(nbody.kinetic_energy)(batched)
    u = jax.vmap(nbody.potential_energy)(batched)
    tiny = jnp.asarray(jnp.finfo(t.dtype).tiny, t.dtype)  # fp32-safe clamp
    return t / jnp.maximum(jnp.abs(u), tiny)


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
def _inner_evaluator(order: int, eps: float, impl: str, dtype: str = "fp32"):
    if impl == "fp64" and dtype == "mixed":
        raise ValueError("impl='fp64' conflicts with dtype='mixed' — the "
                         "oracle path has no reduced-precision mode")
    if impl == "fp64" or dtype == "fp64":
        return make_evaluator(precision="fp64", order=order, eps=eps)
    if impl not in ENSEMBLE_IMPLS:
        raise ValueError(
            f"ensemble impl must be one of {ENSEMBLE_IMPLS} (the vmappable "
            f"evaluation paths); got {impl!r}")
    return make_evaluator(order=order, eps=eps, impl=impl, dtype=dtype)


def _mask_evaluator(ev, n_active):
    """Zero the evaluated derivatives of padding rows (>= ``n_active``).

    Sources with m = 0 already contribute zero force (kernel invariant);
    masking the *outputs* additionally freezes padding rows as targets, so
    they never drift into the active set and never tighten the per-run
    Aarseth timestep.  With ``n_active == N`` the mask is all-ones and the
    multiply is an exact identity.
    """

    def evaluate(pos, vel, mass) -> Evaluation:
        out = ev(pos, vel, mass)
        active = jnp.arange(pos.shape[0]) < n_active
        m3 = active.astype(out.acc.dtype)[:, None]
        return Evaluation(acc=out.acc * m3, jerk=out.jerk * m3,
                          snap=out.snap * m3,
                          pot=out.pot * active.astype(out.pot.dtype))

    return evaluate


def _constrain(tree, mesh):
    """Shard the leading (batch) axis of every leaf over the mesh.

    On a fused 2-D ``(ensemble, dev)`` mesh (:func:`_fused_mesh`) the
    second — particle — axis of ``(B, N, ...)`` leaves additionally shards
    over the ``"dev"`` axis whenever it divides evenly; leaves whose second
    axis does not (e.g. the neighbor carry's per-block window tables) keep
    the batch-only layout, which is always correct — the constraint is a
    layout hint, never semantics.
    """
    if mesh is None:
        return tree
    fused = len(mesh.axis_names) == 2
    p = mesh.shape["dev"] if fused else 1

    def one(x):
        axes = [BATCH_AXIS] + [None] * (x.ndim - 1)
        if fused and x.ndim >= 2 and x.shape[1] % p == 0:
            axes[1] = "dev"
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*axes)))

    return jax.tree_util.tree_map(one, tree)


def _count_engine_build(kind: str) -> None:
    """Emit one ``engine.cache_miss`` tick into the current metrics registry.

    Every engine constructor below is ``lru_cache``d, so its body only runs
    when a (config, mesh, groups) key has never been lowered before — the
    counter IS the recompile count the observability layer reports, with no
    tracing-internals spelunking.
    """
    reg = obs_metrics.registry()
    reg.counter("engine.cache_miss", unit="builds",
                help="engine constructions = fresh XLA lowerings").inc()
    reg.counter(f"engine.cache_miss.{kind}", unit="builds").inc()


@functools.lru_cache(maxsize=64)
def _engine(order: int, eps: float, impl: str, mesh, dtype: str):
    _count_engine_build("fixed")
    ev = _inner_evaluator(order, eps, impl, dtype)

    @jax.jit
    def init(batched: ParticleState, n_active) -> ParticleState:
        batched, n_active = _constrain((batched, n_active), mesh)
        out = jax.vmap(
            lambda s, na: hermite.initialize(s, _mask_evaluator(ev, na))
        )(batched, n_active)
        return _constrain(out, mesh)

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def run(batched: ParticleState, n_active, dt, n_steps: int
            ) -> ParticleState:
        batched, n_active = _constrain((batched, n_active), mesh)

        def body(s, _):
            s1 = jax.vmap(
                lambda m, na: hermite.step(m, dt.astype(m.dtype),
                                           _mask_evaluator(ev, na),
                                           order=order)
            )(s, n_active)
            return _constrain(s1, mesh), None

        out, _ = jax.lax.scan(body, batched, None, length=n_steps)
        return out

    return init, run


def _pad_batch(tree, p: int):
    """Pad B to a multiple of the device count by repeating the first run.

    Works on any pytree whose leaves carry the batch on the leading axis
    (a ParticleState, or a tuple of per-run carries).
    """
    b = jax.tree_util.tree_leaves(tree)[0].shape[0]
    if p <= 1 or b % p == 0:
        return tree, b
    pad = p - b % p
    padded = jax.tree_util.tree_map(
        lambda x: jnp.concatenate([x, jnp.repeat(x[:1], pad, axis=0)]),
        tree)
    return padded, b


def _batch_mesh(devices) -> Optional[object]:
    if devices is None:
        return None
    devices = list(devices)
    if len(devices) <= 1:
        return None
    return make_batch_mesh(devices, axis_name=BATCH_AXIS)


def _fused_mesh(devices, mesh_shape):
    """2-D ``(ensemble, dev)`` mesh for the fused engines (see
    :func:`repro.core.strategies.make_fused_mesh`; validates the device
    count against ``mesh_shape``)."""
    devs = list(devices) if devices is not None else jax.devices()
    return make_fused_mesh(devs, mesh_shape=tuple(int(x) for x in mesh_shape),
                           axis_names=(BATCH_AXIS, "dev"))


def _mesh_batch_extent(mesh) -> int:
    """How many ways the batch axis is sharded (the `_pad_batch` multiple)."""
    if mesh is None:
        return 1
    if len(mesh.axis_names) == 2:
        return mesh.shape[BATCH_AXIS]
    return mesh.size


def _as_n_active(batched: ParticleState, n_active) -> jax.Array:
    """Normalize ``n_active`` to a (B,) int32 vector (default: all active)."""
    b, n = batched.pos.shape[0], batched.pos.shape[1]
    if n_active is None:
        return jnp.full((b,), n, jnp.int32)
    n_active = jnp.asarray(n_active, jnp.int32)
    if n_active.shape != (b,):
        raise ValueError(
            f"n_active must have shape ({b},) for a B={b} batch; "
            f"got {n_active.shape}")
    return n_active


def _as_t_end(batched: ParticleState, t_end) -> jax.Array:
    """Normalize ``t_end`` to a (B,) vector in the state dtype.

    A scalar broadcasts to every member (bit-identical to the historical
    shared-deadline behaviour — the per-member subtraction ``t_end - time``
    sees the same value either way); a vector gives each member its own
    deadline, which is how the serving layer freezes retired slots without
    perturbing — or recompiling for — their batch-mates.
    """
    b = batch_size(batched)
    t = jnp.asarray(t_end, batched.pos.dtype)
    if t.ndim == 0:
        return jnp.full((b,), t, batched.pos.dtype)
    if t.shape != (b,):
        raise ValueError(
            f"t_end must be a scalar or shape ({b},) for a B={b} batch; "
            f"got {t.shape}")
    return t


def ensemble_initialize(
    batched: ParticleState,
    *,
    n_active=None,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    dtype: str = "fp32",
    devices: Optional[Sequence[jax.Device]] = None,
    mesh: Optional[Sequence[int]] = None,
) -> ParticleState:
    """Bootstrap derivatives for every ensemble member (batched t=0 pass).

    ``mesh=(B_shards, P_shards)`` lays the batch out on the fused 2-D mesh
    (see :func:`ensemble_run_block`); the bootstrap math itself is the
    vmapped evaluator either way — constraints only steer the layout.
    """
    mesh_obj = _fused_mesh(devices, mesh) if mesh is not None else \
        _batch_mesh(devices)
    init, _ = _engine(order, eps, impl, mesh_obj, dtype)
    n_active = _as_n_active(batched, n_active)
    (padded, na), b = _pad_batch((batched, n_active),
                                 _mesh_batch_extent(mesh_obj))
    out = init(padded, na)
    return jax.tree_util.tree_map(lambda x: x[:b], out)


def ensemble_run(
    batched: ParticleState,
    *,
    n_steps: int,
    dt: float,
    n_active=None,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    dtype: str = "fp32",
    devices: Optional[Sequence[jax.Device]] = None,
) -> ParticleState:
    """Advance an *initialized* batched state by ``n_steps`` fixed-dt steps."""
    mesh = _batch_mesh(devices)
    _, run = _engine(order, eps, impl, mesh, dtype)
    n_active = _as_n_active(batched, n_active)
    (padded, na), b = _pad_batch((batched, n_active),
                                 mesh.size if mesh else 1)
    out = run(padded, na, jnp.asarray(dt, batched.pos.dtype), n_steps)
    return jax.tree_util.tree_map(lambda x: x[:b], out)


@functools.lru_cache(maxsize=64)
def _adaptive_engine(order: int, eps: float, impl: str, mesh,
                     eta: float, dt_max: float, dtype: str):
    """Per-run shared-adaptive (Aarseth) lockstep engine.

    Each run carries its own timestep: ``aarseth_dt`` is evaluated per
    ensemble member under vmap, rate-limited against the member's previous
    step, and clamped to its remaining time.  Members that have reached
    ``t_end`` keep stepping in lockstep (the batch is rectangular) but their
    state is frozen by a per-run select — wasted flops, never wrong physics.
    """
    _count_engine_build("adaptive")
    ev = _inner_evaluator(order, eps, impl, dtype)

    def one_step(s, hp, na, t_end):
        remaining = t_end - s.time
        active = remaining > 0.0
        # padding rows carry zero derivatives (masked evaluator), so they
        # fall into aarseth_dt's num > 0 guard and never tighten the step
        h = hermite.aarseth_dt(s, eta=eta, dt_max=dt_max)
        # rate-limit dt changes (noise robustness; hp <= 0 marks "first step")
        h = jnp.where(hp > 0.0,
                      jnp.minimum(jnp.maximum(h, 0.5 * hp), 2.0 * hp), h)
        h = jnp.minimum(h, jnp.maximum(remaining, 1e-12))
        h_safe = jnp.where(active, h, jnp.ones_like(h))  # corrector / h^3
        s1 = hermite.step(s, h_safe.astype(s.dtype), _mask_evaluator(ev, na),
                          order=order)
        s1 = jax.tree_util.tree_map(
            lambda new, old: jnp.where(active, new, old), s1, s)
        return s1, jnp.where(active, h, hp), active

    @functools.partial(jax.jit, static_argnames=("n_steps",))
    def run(batched, h_prev, n_taken, n_active, t_end, n_steps: int):
        batched, n_active = _constrain((batched, n_active), mesh)

        def body(carry, _):
            s, hp, cnt = carry
            s1, hp1, active = jax.vmap(one_step, in_axes=(0, 0, 0, 0))(
                s, hp, n_active, t_end)
            return (_constrain(s1, mesh), hp1,
                    cnt + active.astype(cnt.dtype)), None

        carry, _ = jax.lax.scan(body, (batched, h_prev, n_taken), None,
                                length=n_steps)
        return carry

    return run


def ensemble_run_adaptive(
    batched: ParticleState,
    *,
    t_end: float,
    n_steps: int,
    h_prev: Optional[jax.Array] = None,
    n_taken: Optional[jax.Array] = None,
    n_active=None,
    eta: float = 0.02,
    dt_max: float = 0.0625,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    dtype: str = "fp32",
    devices: Optional[Sequence[jax.Device]] = None,
):
    """Advance an initialized batch by up to ``n_steps`` adaptive steps each.

    Returns ``(batched, h_prev, n_taken)``; call again with the returned
    carries until ``batched.time.min() >= t_end``.  ``n_taken`` counts the
    *productive* steps per run (frozen lockstep steps excluded).  ``t_end``
    is a shared scalar or a per-member ``(B,)`` vector (see
    :func:`_as_t_end`).
    """
    mesh = _batch_mesh(devices)
    run = _adaptive_engine(order, eps, impl, mesh, eta, dt_max, dtype)
    state_dtype = batched.pos.dtype
    if h_prev is None:
        h_prev = jnp.zeros(batch_size(batched), state_dtype)
    if n_taken is None:
        n_taken = jnp.zeros(batch_size(batched), jnp.int32)
    n_active = _as_n_active(batched, n_active)
    t_end_ = _as_t_end(batched, t_end)
    carry, b = _pad_batch((batched, h_prev, n_taken, n_active, t_end_),
                          mesh.size if mesh else 1)
    out, hp, cnt = run(*carry, n_steps)
    return tuple(jax.tree_util.tree_map(lambda x: x[:b], t)
                 for t in (out, hp, cnt))


# --------------------------------------------------------------------------
# hierarchical block-timestep engine (per-particle power-of-two levels)
# --------------------------------------------------------------------------
def _block_inner_evaluator(order: int, eps: float, impl: str,
                           compaction: str, block_i: int, block_j: int,
                           n_caps: Optional[int] = None,
                           dtype: str = "fp32"):
    kw = dict(order=order, eps=eps, compaction=compaction,
              block_i=block_i, block_j=block_j, n_caps=n_caps)
    if impl == "fp64" and dtype == "mixed":
        raise ValueError("impl='fp64' conflicts with dtype='mixed' — the "
                         "oracle path has no reduced-precision mode")
    if impl == "fp64" or dtype == "fp64":
        return make_block_evaluator(precision="fp64", **kw)
    if impl not in ENSEMBLE_IMPLS:
        raise ValueError(
            f"ensemble impl must be one of {ENSEMBLE_IMPLS} (the vmappable "
            f"evaluation paths); got {impl!r}")
    return make_block_evaluator(impl=impl, dtype=dtype, **kw)


def _neighbor_evaluators(n: int, eps: float, impl: str, block_i: int,
                         block_j: int, dtype: str):
    """Windowed near-pass evaluator pair for ``sources="neighbor"`` (same
    impl/precision routing as :func:`_block_inner_evaluator`)."""
    kw = dict(n=n, eps=eps, block_i=block_i, block_j=block_j)
    if impl == "fp64" and dtype == "mixed":
        raise ValueError("impl='fp64' conflicts with dtype='mixed' — the "
                         "oracle path has no reduced-precision mode")
    if impl == "fp64" or dtype == "fp64":
        return make_neighbor_block_evaluator(precision="fp64", **kw)
    if impl not in ENSEMBLE_IMPLS:
        raise ValueError(
            f"ensemble impl must be one of {ENSEMBLE_IMPLS} (the vmappable "
            f"evaluation paths); got {impl!r}")
    return make_neighbor_block_evaluator(impl=impl, dtype=dtype, **kw)


def _window_pairs(mask, win_cnt, block_i: int, block_j: int, out_dtype):
    """(B,) gathered interaction rows of one neighbor event: each masked
    target sweeps its block's ``win_cnt * block_j`` gathered source rows —
    the measured ``n_pairs`` cost the scheme shrinks from ``active * N``."""
    b, n = mask.shape
    nbt = win_cnt.shape[1]
    pad = nbt * block_i - n
    per_block = jnp.sum(
        jnp.pad(mask, ((0, 0), (0, pad))).reshape(b, nbt, block_i), axis=2)
    return (jnp.sum(per_block * win_cnt, axis=1).astype(out_dtype)
            * block_j)


def spatial_sort_state(state: ParticleState, n_active=None, *,
                       leaf: int = 32) -> ParticleState:
    """Spatial sort of one run's rows (padding rows stay last).

    The neighbor scheme windows *contiguous index blocks*, so spatial
    locality of adjacent rows is what keeps the per-block bounding spheres
    — and with them the gathered windows — tight.  Rows are laid out by
    balanced orthogonal recursive bisection (``kernels.neighbor.kd_perm``;
    ``leaf`` should divide the kernel block sizes), whose aligned blocks
    are compact cells even in a heavy halo.  The physics is
    permutation-invariant; entry points apply this once at build/admission
    time and never mid-run (windows are rebuilt at refreshes, so slowly
    decaying locality degrades only the *cost*, never the result).
    """
    n = state.pos.shape[0]
    valid = jnp.arange(n) < (n if n_active is None else n_active)
    perm = neighbor.kd_perm(state.pos, valid, leaf=leaf)
    return jax.tree_util.tree_map(
        lambda x: x[perm] if getattr(x, "ndim", 0) >= 1 else x, state)


def spatial_sort_batched(batched: ParticleState, n_active=None, *,
                         leaf: int = 32) -> ParticleState:
    """Per-member :func:`spatial_sort_state` over a batched state."""
    na = _as_n_active(batched, n_active)
    return jax.vmap(
        functools.partial(spatial_sort_state, leaf=leaf))(batched, na)


# --- one block event, member view (shared by the vmapped ensemble engine
# --- and the single-run strategy engine; statics bound via functools.partial)
def _macro_levels(s, dt_macro, *, eta, n_levels: int):
    """Fresh levels for a member synchronized at its macro start."""
    dt_i = hermite.aarseth_dt_particles(s, eta=eta, dt_max=dt_macro)
    return hermite.quantize_block_levels(dt_i, dt_max=dt_macro,
                                         n_levels=n_levels)


def _event_init(s, na, t_end, *, eta, dt_max, n_levels: int):
    del na
    dtype = s.pos.dtype
    remaining = t_end - s.time
    dt_macro = jnp.minimum(jnp.asarray(dt_max, dtype),
                           jnp.maximum(remaining, 1e-12))
    levels = _macro_levels(s, dt_macro, eta=eta, n_levels=n_levels)
    t_last = jnp.zeros(s.pos.shape[0], jnp.int32)
    return t_last, levels, dt_macro


# One event is split in three stages so the compaction layer can pick its
# capacity bucket(s) *between* the per-member vmaps (the ensemble engine) or
# inside the per-shard switch (the strategy engine).
def _event_pre(s, t_last, levels, dt_macro, na, t_end, *, n_sub: int):
    dtype = s.pos.dtype
    live = (t_end - s.time) > 0.0
    real = jnp.arange(s.pos.shape[0]) < na
    period = jnp.asarray(n_sub, jnp.int32) >> levels
    cand = t_last + period
    t_next = jnp.min(jnp.where(real, cand, n_sub))
    active = real & (cand == t_next)
    dt_fine = dt_macro / n_sub
    h = ((t_next - t_last).astype(dtype) * dt_fine)[:, None]

    xp, vp = hermite.predict(s, h)
    ap = hermite.predict_acc(s, h)
    # active targets first (argsort of the negated mask); row order
    # within the gathered buffer is irrelevant to the row-local kernel
    # math, the permutation only densifies the launch
    perm = jnp.argsort(~active, stable=True)
    return live, t_next, active, h, xp, vp, ap, perm


def _event_post(s, ev, live, t_next, active, h, t_last, levels,
                dt_macro, na, t_end, *, n_sub: int, eta, dt_max,
                n_levels: int, order: int):
    dtype = s.pos.dtype
    period = jnp.asarray(n_sub, jnp.int32) >> levels
    # an active particle last corrected exactly its own step ago, so the
    # prediction horizon IS the corrector interval
    x1, v1, crk = hermite.correct(s, ev, h, order=order)
    m3 = active[:, None]
    st1 = ParticleState(
        pos=jnp.where(m3, x1, s.pos),
        vel=jnp.where(m3, v1, s.vel),
        acc=jnp.where(m3, ev.acc.astype(dtype), s.acc),
        jerk=jnp.where(m3, ev.jerk.astype(dtype), s.jerk),
        snap=jnp.where(m3, ev.snap.astype(dtype), s.snap),
        crackle=jnp.where(m3, crk, s.crackle),
        mass=s.mass,
        pot=jnp.where(active, ev.pot.astype(s.mass.dtype), s.pot),
        time=s.time,
    )
    t_last1 = jnp.where(active, t_next, t_last)

    # level update from the freshly corrected derivatives: finer at will
    # (always commensurate), coarser one level at doubled-period ticks
    dt_i = hermite.aarseth_dt_particles(st1, eta=eta, dt_max=dt_macro)
    want = hermite.quantize_block_levels(dt_i, dt_max=dt_macro,
                                         n_levels=n_levels)
    can_coarsen = (t_next % (period << 1)) == 0
    lev1 = jnp.where(active & (want > levels), want,
                     jnp.where(active & (want < levels) & can_coarsen,
                               levels - 1, levels))

    # macro boundary: advance member time, requantize, reset the grid
    sync = t_next == n_sub
    time1 = jnp.where(sync, s.time + dt_macro, s.time)
    st1 = dataclasses.replace(st1, time=time1)
    remaining = t_end - time1
    dt_macro1 = jnp.where(
        sync, jnp.minimum(jnp.asarray(dt_max, dtype),
                          jnp.maximum(remaining, 1e-12)), dt_macro)
    lev1 = jnp.where(sync, _macro_levels(st1, dt_macro1, eta=eta,
                                         n_levels=n_levels), lev1)
    t_last1 = jnp.where(sync, 0, t_last1)

    # members past t_end freeze whole (lockstep batch stays rectangular)
    st1, t_last1, lev1, dt_macro1 = jax.tree_util.tree_map(
        lambda new, old: jnp.where(live, new, old),
        (st1, t_last1, lev1, dt_macro1), (s, t_last, levels, dt_macro))
    dp = jnp.where(live, jnp.sum(active).astype(dtype) * na, 0.0)
    return st1, t_last1, lev1, dt_macro1, dp, live


class NeighborCarry(NamedTuple):
    """Per-batch carry of the Ahmad-Cohen neighbor scheme.

    ``win_idx``/``win_cnt`` are the current neighbor windows (per target
    block, see ``kernels.neighbor.build_windows``); ``acc_far``/``jerk_far``
    /``snap_far``/``pot_far`` the far-field Taylor coefficients captured at
    the last refresh (``far = full - near`` at the refresh anchor, predicted
    between refreshes as ``a_far(h) = A + h J + h^2/2 S``); ``t_ref`` the
    ``(B,)`` refresh anchor tick (``-1`` = never refreshed, forces a refresh
    at the member's next event); ``n_refresh``/``n_overflow`` accumulate
    refresh events and window-overflow fallbacks (a refresh whose widest
    window fit no bucket below the full-extent one) for telemetry.
    """

    win_idx: jax.Array
    win_cnt: jax.Array
    acc_far: jax.Array
    jerk_far: jax.Array
    snap_far: jax.Array
    pot_far: jax.Array
    t_ref: jax.Array
    n_refresh: jax.Array
    n_overflow: jax.Array


class BlockCarry(NamedTuple):
    """Opaque per-batch carry of the block engine (pass back unchanged).

    ``t_last``/``levels`` are ``(B, N)`` integer ticks / block levels,
    ``dt_macro`` the ``(B,)`` current macro length, ``n_pairs`` the ``(B,)``
    accumulated pairwise force evaluations (per Hermite pass), ``n_events``
    the ``(B,)`` productive event count, ``n_tiles`` the ``(B,)`` accumulated
    kernel grid tiles launched (both Hermite passes) — the count compaction
    shrinks while ``n_pairs`` stays the same.

    ``bucket_hits`` is the capacity-bucket switch hit distribution:
    ``(B, n_caps)`` counts of how often each member's event dispatched each
    bucket of the *full* capacity schedule (restricted group schedules are
    prefixes, so indices align).  All zeros without ``compaction="gather"``;
    the strategy engine carries an empty ``(0,)`` vector (its switch lives
    inside the shards — see ``grid_tiles_per_shard`` for the per-chip view).

    ``nbr`` is the Ahmad-Cohen :class:`NeighborCarry` under
    ``sources="neighbor"`` and ``None`` (an empty pytree node — existing
    carries keep their treedef) under the default full-source evaluation.
    """

    t_last: jax.Array
    levels: jax.Array
    dt_macro: jax.Array
    n_pairs: jax.Array
    n_events: jax.Array
    n_tiles: jax.Array
    bucket_hits: jax.Array
    nbr: Optional[NeighborCarry] = None


#: per-member capacity-bucket dispatch modes of the block engine
BUCKET_MODES = ("member", "shared")


def _bucket_groups(n: int, n_active, block_i: int, block_j: int,
                   compaction: str, bucket_mode: str) -> tuple:
    """Static pre-lowered bucket groups of a (possibly mixed) batch.

    Members are grouped by the ceiling bucket of their *static* ``n_active``
    — the bucket a member's per-event active count can never exceed.  Each
    group dispatches its own unbatched ``lax.switch`` over a capacity
    schedule truncated at that ceiling (``ops.CapacityPlan.restrict``), so a
    quiescent small member in a mixed batch never launches — nor even
    lowers — the widest member's buckets.  Returns a tuple of
    ``(member_indices, n_caps)`` pairs partitioning ``range(B)``; with
    ``bucket_mode="shared"`` (or without compaction) the whole batch is one
    group over the full schedule — exactly the original batch-shared
    dispatch, which a homogeneous batch also reduces to in ``"member"``
    mode (one ceiling => one group).
    """
    if bucket_mode not in BUCKET_MODES:
        raise ValueError(
            f"bucket_mode must be one of {BUCKET_MODES}; got {bucket_mode!r}")
    na = np.asarray(n_active)
    b = na.shape[0]
    plan = ops.CapacityPlan(n, n, block_i, block_j)
    if compaction != "gather" or bucket_mode == "shared":
        return ((tuple(range(b)), len(plan.caps)),)
    by: dict = {}
    for member, a in enumerate(na):
        by.setdefault(len(plan.restrict(int(a)).caps), []).append(member)
    return tuple(sorted((tuple(ms), n_caps) for n_caps, ms in by.items()))


@functools.lru_cache(maxsize=64)
def _block_engine(order: int, eps: float, impl: str, mesh,
                  eta: float, dt_max: float, n_levels: int,
                  compaction: str, block_i: int, block_j: int,
                  groups: tuple, dtype: str, sources: str = "full",
                  radius: float = 0.25, refresh_levels: int = 2):
    """Hierarchical block-timestep engine (Aarseth dt -> power-of-two levels).

    Time is organized in **macro-steps** of ``dt_macro = min(dt_max,
    remaining)``, subdivided on an integer grid of ``2**(n_levels-1)`` fine
    ticks; a particle at level ``l`` steps every ``2**(n_levels-1-l)`` ticks.
    The engine is **event-driven**: each iteration jumps straight to the next
    *occupied* activation tick (``min_i(t_last_i + period_i)``), so deep
    hierarchies cost wall time proportional to the events that actually
    happen, not to the full substep count — exactly the economics of the
    paper's kernel-bound force phase, where skipping inactive targets is the
    whole point.

    At each event the **active block** (particles whose step completes at
    that tick, composed with the ``n_active`` padding mask) is
    predicted-evaluated-corrected over its own elapsed step; everyone else is
    Taylor-predicted to the event time as force *sources* (including
    predicted accelerations for the snap pass).  After correction a particle
    may move to a finer level immediately (always commensurate) or one level
    coarser when the event tick is a multiple of the doubled period — the
    classic Aarseth promotion rule, which is what lets hardening binaries
    chase their shrinking timestep mid-macro.  The macro boundary is a full
    synchronization point: every particle is active there, levels are
    requantized from scratch, and per-member diagnostics (energy, virial)
    are exact.

    ``sources="neighbor"`` is the **Ahmad-Cohen split** of the same event
    loop: the force on each event's active block is the *near* sum over its
    target blocks' gathered neighbor windows plus a Taylor-*predicted* far
    field.  Far coefficients are captured at **refresh events** — the full
    evaluation minus the near sum over the freshly built windows, both at
    the refresh anchor's predicted positions — and a member refreshes when
    ``refresh_levels`` irregular levels have elapsed since its anchor
    (``t_next - t_ref >= n_sub >> refresh_levels``), at every macro
    synchronization, and at its first event.  Windows come from
    ``kernels.neighbor.build_windows`` (bounding-sphere test with
    ``radius``; no pair within the radius is ever dropped) and dispatch
    over the plan's ``source_caps`` schedule, whose last bucket is the full
    source extent — overflow falls back to the exact full window, never to
    truncation.  Refresh-event members get the full evaluation itself
    (prediction horizon zero), so macro boundaries remain exact
    synchronization points.
    """
    _count_engine_build("block")
    if compaction == "gather":
        # switch branches lowered across the pre-lowered bucket groups: the
        # denominator of the recompile accounting (engine.cache_miss ticks
        # once however many branches one build lowers)
        obs_metrics.registry().counter(
            "engine.bucket_branches", unit="branches",
            help="kernel switch branches lowered across bucket groups"
        ).inc(sum(n_caps for _, n_caps in groups))
    n_sub = 2 ** (n_levels - 1)
    n_passes = 2 if order >= 6 else 1
    member_init = functools.partial(_event_init, eta=eta, dt_max=dt_max,
                                    n_levels=n_levels)
    member_pre = functools.partial(_event_pre, n_sub=n_sub)
    member_post = functools.partial(_event_post, n_sub=n_sub, eta=eta,
                                    dt_max=dt_max, n_levels=n_levels,
                                    order=order)
    if compaction != "gather":
        bev = _block_inner_evaluator(order, eps, impl, compaction,
                                     block_i, block_j, dtype=dtype)

    @functools.partial(jax.jit, static_argnames=("n_events",))
    def run(batched, carry: BlockCarry, n_active, t_end, n_events: int):
        batched, n_active = _constrain((batched, n_active), mesh)
        n = batched.pos.shape[1]
        # counter dtype: host precision when x64 is on (exact integer adds
        # far past float32's 2**24 window), silently float32 otherwise
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
        if compaction == "gather":
            plan = ops.CapacityPlan(n, n, block_i, block_j,
                                    n_passes=n_passes, dtype=dtype)
            # one evaluator + switch per pre-lowered bucket group: members
            # grouped by their n_active ceiling dispatch over a schedule
            # truncated there (lax.switch needs its operand unbatched under
            # vmap to stay a real branch, so the index is shared *within*
            # each group — the max live active count of the group's members)
            group_data = [
                (np.asarray(members, np.intp),
                 plan.restrict(plan.caps[min(n_caps, len(plan.caps)) - 1]),
                 _block_inner_evaluator(order, eps, impl, compaction,
                                        block_i, block_j, n_caps,
                                        dtype=dtype))
                for members, n_caps in groups]
            inv = np.argsort(np.concatenate([m for m, _, _ in group_data]))
        else:
            # the masked dense launch always enqueues the full grid, however
            # many i-blocks pl.when predicates away
            full_tiles = nbody_force.grid_tiles(n, n, block_i, block_j) \
                * n_passes

        def body(acc, _):
            s, c = acc
            with jax.named_scope("event.pre"):
                live, t_next, active, h, xp, vp, ap, perm = jax.vmap(
                    member_pre, in_axes=(0, 0, 0, 0, 0, 0))(
                        s, c.t_last, c.levels, c.dt_macro, n_active, t_end)
            hits_event = None
            if compaction == "gather":
                n_act = jnp.sum(active, axis=1).astype(jnp.int32)
                n_caps_full = c.bucket_hits.shape[1]
                evs, tiles_parts, hits_parts = [], [], []
                for gi, (members, gplan, gbev) in enumerate(group_data):
                    with jax.named_scope(f"event.bucket_switch.g{gi}"):
                        cap_idx = shared_cap_index(gplan, jnp.where(
                            live[members], n_act[members], 0))
                        evs.append(jax.vmap(
                            gbev, in_axes=(0, 0, 0, 0, 0, 0, None))(
                                xp[members], vp[members], ap[members],
                                s.mass[members], active[members],
                                perm[members], cap_idx))
                    tiles_parts.append(jnp.broadcast_to(
                        gplan.tiles(cap_idx).astype(count_dtype),
                        (len(members),)))
                    hits_parts.append(jnp.broadcast_to(
                        jax.nn.one_hot(cap_idx, n_caps_full,
                                       dtype=count_dtype),
                        (len(members), n_caps_full)))
                ev = jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs)[inv], *evs)
                tiles_event = jnp.concatenate(tiles_parts)[inv]
                hits_event = jnp.concatenate(hits_parts)[inv]
            else:
                with jax.named_scope("event.force"):
                    ev = jax.vmap(bev)(xp, vp, ap, s.mass, active)
                tiles_event = jnp.asarray(full_tiles, count_dtype)
            with jax.named_scope("event.post"):
                s1, t_last, levels, dt_macro, dp, live = jax.vmap(
                    member_post,
                    in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))(
                        s, ev, live, t_next, active, h, c.t_last, c.levels,
                        c.dt_macro, n_active, t_end)
            c1 = BlockCarry(t_last=t_last, levels=levels, dt_macro=dt_macro,
                            n_pairs=c.n_pairs + dp,
                            n_events=c.n_events + live.astype(jnp.int32),
                            n_tiles=c.n_tiles + jnp.where(live, tiles_event,
                                                          0.0),
                            bucket_hits=c.bucket_hits
                            if hits_event is None else c.bucket_hits
                            + jnp.where(live[:, None], hits_event, 0.0))
            return (_constrain(s1, mesh), c1), None

        step_body = body
        if sources == "neighbor":
            near1, near2 = _neighbor_evaluators(n, eps, impl, block_i,
                                                block_j, dtype)
            nplan = ops.CapacityPlan(n, n, block_i, block_j,
                                     n_passes=n_passes, dtype=dtype,
                                     sources="neighbor")
            src_caps = nplan.source_caps
            refresh_period = max(1, n_sub >> refresh_levels)
            state_dtype = batched.pos.dtype

            def neighbor_body(acc, _):
                s, c = acc
                nb = c.nbr
                with jax.named_scope("event.pre"):
                    live, t_next, active, h, xp, vp, ap, _ = jax.vmap(
                        member_pre, in_axes=(0, 0, 0, 0, 0, 0))(
                            s, c.t_last, c.levels, c.dt_macro, n_active,
                            t_end)
                need = live & ((nb.t_ref < 0)
                               | (t_next - nb.t_ref >= refresh_period)
                               | (t_next == n_sub))
                real = jnp.arange(n)[None, :] < n_active[:, None]
                cd = count_dtype
                zero = jnp.zeros((), cd)

                def near_total(mask, win_idx, win_cnt, w_idx):
                    """Near(windows) + NM08-predicted far, every member.

                    The far anchor never moves inside this event (the
                    refresh branch *replaces* it), so the same prediction
                    serves both the acc operands and the returned
                    Evaluation; members whose result the caller discards
                    (refreshing ones) just ride the vmap.
                    """
                    a_n, j_n, p_n = jax.vmap(
                        near1, in_axes=(0, 0, 0, 0, 0, 0, None))(
                            xp, vp, s.mass, mask, win_idx, win_cnt, w_idx)
                    hf = ((t_next - jnp.maximum(nb.t_ref, 0))
                          .astype(state_dtype) * c.dt_macro / n_sub)
                    h1 = hf[:, None, None]
                    a_far = (nb.acc_far + h1 * nb.jerk_far
                             + (0.5 * h1 * h1) * nb.snap_far)
                    acc_t = a_n.astype(state_dtype) + a_far
                    if order >= 6:
                        acc_s = jnp.where(mask[..., None], acc_t, ap)
                        s_n = jax.vmap(
                            near2, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None))(
                                xp, vp, acc_t, acc_s, s.mass, mask,
                                win_idx, win_cnt, w_idx)
                        snp = s_n.astype(state_dtype) + nb.snap_far
                    else:
                        snp = jnp.zeros_like(acc_t)
                    return Evaluation(
                        acc=acc_t,
                        jerk=(j_n.astype(state_dtype) + nb.jerk_far
                              + h1 * nb.snap_far),
                        snap=snp,
                        pot=p_n.astype(state_dtype) + nb.pot_far)

                zi = jnp.zeros_like(nb.t_ref)
                # the gathered window width of one event is shared by every
                # launched target block, so size it over the blocks that
                # hold *active* targets: the frequently stepping core has
                # tight windows, while a sparse halo block's full-extent
                # window only widens the (rare) events that step it — the
                # Ahmad-Cohen economics at block granularity
                npad_i = nb.win_cnt.shape[1] * block_i - n
                act_blk = jnp.any(jnp.pad(active, ((0, 0), (0, npad_i)))
                                  .reshape(active.shape[0], -1, block_i),
                                  axis=2)

                def no_refresh(_):
                    wmax = jnp.max(jnp.where(live[:, None] & act_blk,
                                             nb.win_cnt, 0))
                    w_idx = nplan.source_bucket(wmax * block_j)
                    ev = near_total(active, nb.win_idx, nb.win_cnt, w_idx)
                    dp = jnp.where(live, _window_pairs(
                        active, nb.win_cnt, block_i, block_j, cd), zero)
                    tiles = jnp.where(
                        live, nplan.window_tiles(w_idx).astype(cd), zero)
                    return (ev, nb.win_idx, nb.win_cnt, nb.acc_far,
                            nb.jerk_far, nb.snap_far, nb.pot_far, nb.t_ref,
                            zi, zi, dp, tiles)

                def do_refresh(_):
                    # members keeping their anchor still need this event's
                    # near force over their OLD windows (bucket sized over
                    # them alone — an all-refresh event launches the
                    # cheapest bucket and discards it)
                    keep = live & ~need
                    wmax_o = jnp.max(jnp.where(keep[:, None] & act_blk,
                                               nb.win_cnt, 0))
                    w_old = nplan.source_bucket(wmax_o * block_j)
                    ev_o = near_total(active, nb.win_idx, nb.win_cnt, w_old)
                    # refresh anchor: full force at the event's predicted
                    # positions; new windows from the same positions; far =
                    # full - near with IDENTICAL acc operands in both
                    with jax.named_scope("event.neighbor_refresh"):
                        ev_f = jax.vmap(bev)(xp, vp, ap, s.mass, real)
                    win_idx_n, win_cnt_n = jax.vmap(
                        lambda p_, v_: neighbor.build_windows(
                            p_, v_, block_i=block_i, block_j=block_j,
                            radius=radius))(xp, real)
                    wmax_n = jnp.max(jnp.where(need[:, None],
                                               win_cnt_n, 0))
                    w_new = nplan.source_bucket(wmax_n * block_j)
                    a_nn, j_nn, p_nn = jax.vmap(
                        near1, in_axes=(0, 0, 0, 0, 0, 0, None))(
                            xp, vp, s.mass, real, win_idx_n, win_cnt_n,
                            w_new)
                    af = ev_f.acc.astype(state_dtype)
                    jf = ev_f.jerk.astype(state_dtype)
                    pf = ev_f.pot.astype(state_dtype)
                    if order >= 6:
                        acc_s = jnp.where(real[..., None], af, ap)
                        s_nn = jax.vmap(
                            near2, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None))(
                                xp, vp, af, acc_s, s.mass, real,
                                win_idx_n, win_cnt_n, w_new)
                        sf = ev_f.snap.astype(state_dtype)
                        snapf_n = sf - s_nn.astype(state_dtype)
                        snap_ev = jnp.where(need[:, None, None], sf,
                                            ev_o.snap)
                    else:
                        snapf_n = jnp.zeros_like(af)
                        snap_ev = jnp.zeros_like(af)
                    sel3, sel2 = need[:, None, None], need[:, None]
                    ev = Evaluation(
                        acc=jnp.where(sel3, af, ev_o.acc),
                        jerk=jnp.where(sel3, jf, ev_o.jerk),
                        snap=snap_ev,
                        pot=jnp.where(sel2, pf, ev_o.pot))
                    tref = jnp.where(
                        need, jnp.where(t_next == n_sub, 0, t_next),
                        nb.t_ref)
                    if len(src_caps) > 1:
                        rows = jnp.max(win_cnt_n, axis=1) * block_j
                        dov = (need & (rows > src_caps[-2])).astype(
                            jnp.int32)
                    else:
                        dov = zi  # one bucket == the full window already
                    na_f = n_active.astype(cd)
                    dp = jnp.where(live, jnp.where(
                        need,
                        na_f * na_f + _window_pairs(real, win_cnt_n,
                                                    block_i, block_j, cd),
                        _window_pairs(active, nb.win_cnt, block_i, block_j,
                                      cd)), zero)
                    tiles = jnp.where(live, jnp.where(
                        need,
                        jnp.asarray(full_tiles, cd)
                        + nplan.window_tiles(w_new).astype(cd),
                        nplan.window_tiles(w_old).astype(cd)), zero)
                    return (
                        ev,
                        jnp.where(sel3, win_idx_n, nb.win_idx),
                        jnp.where(sel2, win_cnt_n, nb.win_cnt),
                        jnp.where(sel3, af - a_nn.astype(state_dtype),
                                  nb.acc_far),
                        jnp.where(sel3, jf - j_nn.astype(state_dtype),
                                  nb.jerk_far),
                        jnp.where(sel3, snapf_n, nb.snap_far),
                        jnp.where(sel2, pf - p_nn.astype(state_dtype),
                                  nb.pot_far),
                        tref, need.astype(jnp.int32), dov, dp, tiles)

                with jax.named_scope("event.neighbor"):
                    (ev, wi, wc, accf, jerkf, snapf, potf, tref, dref,
                     dov, dp, tiles) = jax.lax.cond(
                        jnp.any(need), do_refresh, no_refresh, None)
                with jax.named_scope("event.post"):
                    s1, t_last, levels, dt_macro, _, live = jax.vmap(
                        member_post,
                        in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))(
                            s, ev, live, t_next, active, h, c.t_last,
                            c.levels, c.dt_macro, n_active, t_end)
                c1 = BlockCarry(
                    t_last=t_last, levels=levels, dt_macro=dt_macro,
                    n_pairs=c.n_pairs + dp,
                    n_events=c.n_events + live.astype(jnp.int32),
                    n_tiles=c.n_tiles + tiles,
                    bucket_hits=c.bucket_hits,
                    nbr=NeighborCarry(
                        win_idx=wi, win_cnt=wc, acc_far=accf,
                        jerk_far=jerkf, snap_far=snapf, pot_far=potf,
                        t_ref=tref, n_refresh=nb.n_refresh + dref,
                        n_overflow=nb.n_overflow + dov))
                return (_constrain(s1, mesh), c1), None

            step_body = neighbor_body

        (batched, carry), _ = jax.lax.scan(step_body, (batched, carry),
                                           None, length=n_events)
        return batched, carry

    @jax.jit
    def init(batched, n_active, t_end):
        t_last, levels, dt_macro = jax.vmap(
            member_init, in_axes=(0, 0, 0))(batched, n_active, t_end)
        b, n = t_last.shape
        # counters accumulate at host precision (exact integer adds far past
        # float32's 2**24 window; silently float32 when x64 is disabled)
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
        n_caps = len(ops.CapacityPlan(n, n, block_i, block_j).caps)
        nbr = None
        if sources == "neighbor":
            # t_ref = -1 forces a refresh at every member's first event, so
            # the zeroed windows/coefficients here are never consumed
            sd = batched.pos.dtype
            nbt, nsb = -(-n // block_i), -(-n // block_j)
            nbr = NeighborCarry(
                win_idx=jnp.zeros((b, nbt, nsb), jnp.int32),
                win_cnt=jnp.zeros((b, nbt), jnp.int32),
                acc_far=jnp.zeros((b, n, 3), sd),
                jerk_far=jnp.zeros((b, n, 3), sd),
                snap_far=jnp.zeros((b, n, 3), sd),
                pot_far=jnp.zeros((b, n), sd),
                t_ref=jnp.full((b,), -1, jnp.int32),
                n_refresh=jnp.zeros((b,), jnp.int32),
                n_overflow=jnp.zeros((b,), jnp.int32))
        return BlockCarry(
            t_last=t_last, levels=levels, dt_macro=dt_macro,
            n_pairs=jnp.zeros(b, count_dtype),
            n_events=jnp.zeros(b, jnp.int32),
            n_tiles=jnp.zeros(b, count_dtype),
            bucket_hits=jnp.zeros((b, n_caps), count_dtype),
            nbr=nbr)

    return init, run


@functools.lru_cache(maxsize=64)
def _fused_block_engine(mesh, order: int, eps: float, impl: str,
                        eta: float, dt_max: float, n_levels: int,
                        compaction: str, block_i: int, block_j: int,
                        dtype: str):
    """Block-timestep engine over a fused 2-D ``(ensemble, dev)`` mesh: B
    members x P domain shards in ONE shard_mapped force evaluation
    (:func:`repro.core.strategies.make_fused_block_evaluator`).

    The event schedule is the vmapped ensemble engine's, verbatim
    (:func:`_event_pre` / :func:`_event_post`), so trajectories are
    bit-identical to the 1-D batch-sharded run of the same members under
    any extent-independent kernel (the Pallas grid; XLA CPU's dense
    reduction is extent-reassociated, matching the 1-D ``mesh_sharded``
    strategy bitwise instead).  Capacity buckets are sized **host-side**
    (ROADMAP 5c): each member's per-shard bound is the analytic
    ``hermite.block_level_occupancy`` of its contiguous level chunks at the
    event tick's threshold level — no runtime gather of the activity mask
    feeds the bucket switch, and the bound is exact for a
    schedule-consistent carry (over-wide never under-wide, so physics is
    bit-for-bit either way).
    """
    from repro.core.strategies import make_fused_block_evaluator

    _count_engine_build("block_fused")
    bdev, p = mesh.devices.shape
    bev = make_fused_block_evaluator(
        (bdev, p), devices=list(mesh.devices.reshape(-1)), eps=eps,
        order=order, impl=impl, block_i=block_i, block_j=block_j,
        compaction=compaction, dtype=dtype)
    n_sub = 2 ** (n_levels - 1)
    member_init = functools.partial(_event_init, eta=eta, dt_max=dt_max,
                                    n_levels=n_levels)
    member_pre = functools.partial(_event_pre, n_sub=n_sub)
    member_post = functools.partial(_event_post, n_sub=n_sub, eta=eta,
                                    dt_max=dt_max, n_levels=n_levels,
                                    order=order)

    @functools.partial(jax.jit, static_argnames=("n_events",))
    def run(batched, carry: BlockCarry, n_active, t_end, n_events: int):
        batched, n_active = _constrain((batched, n_active), mesh)
        n = batched.pos.shape[1]
        n_pad = -(-n // p) * p
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)

        def member_bound(lev, na, tn):
            # host-side tile scheduling: the analytic occupancy bound of
            # each contiguous N/P level chunk at the tick's threshold
            # level, padding rows masked out
            thr = hermite.tick_threshold_level(tn, n_levels=n_levels)
            real = jnp.arange(n_pad) < na
            lev_p = jnp.pad(lev, (0, n_pad - n))
            return jax.vmap(
                lambda lv, mk: hermite.block_level_occupancy(
                    lv, n_levels=n_levels, mask=mk)[thr]
            )(lev_p.reshape(p, -1), real.reshape(p, -1))

        def body(acc, _):
            s, c = acc
            with jax.named_scope("event.pre"):
                live, t_next, active, h, xp, vp, ap, _ = jax.vmap(
                    member_pre, in_axes=(0, 0, 0, 0, 0, 0))(
                        s, c.t_last, c.levels, c.dt_macro, n_active, t_end)
            with jax.named_scope("event.force"):
                bound = jax.vmap(member_bound)(c.levels, n_active, t_next)
                bound = jnp.where(live[:, None], bound, 0)
                ev, tiles = bev(xp, vp, ap, s.mass, active, bound)
            with jax.named_scope("event.post"):
                s1, t_last, levels, dt_macro, dp, live = jax.vmap(
                    member_post, in_axes=(0,) * 11)(
                        s, ev, live, t_next, active, h, c.t_last, c.levels,
                        c.dt_macro, n_active, t_end)
            tiles_m = jnp.sum(tiles, axis=1).astype(count_dtype)
            c1 = BlockCarry(t_last=t_last, levels=levels, dt_macro=dt_macro,
                            n_pairs=c.n_pairs + dp,
                            n_events=c.n_events + live.astype(jnp.int32),
                            n_tiles=c.n_tiles + jnp.where(live, tiles_m,
                                                          0.0),
                            # the shared switch lives inside the shards (one
                            # bucket per shard, not per member) — no
                            # batch-level hit distribution to report
                            bucket_hits=c.bucket_hits)
            return (_constrain(s1, mesh), c1), None

        (batched, carry), _ = jax.lax.scan(body, (batched, carry), None,
                                           length=n_events)
        return batched, carry

    @jax.jit
    def init(batched, n_active, t_end):
        t_last, levels, dt_macro = jax.vmap(
            member_init, in_axes=(0, 0, 0))(batched, n_active, t_end)
        b, n = t_last.shape
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
        n_caps = len(ops.CapacityPlan(n, n, block_i, block_j).caps)
        return BlockCarry(
            t_last=t_last, levels=levels, dt_macro=dt_macro,
            n_pairs=jnp.zeros(b, count_dtype),
            n_events=jnp.zeros(b, jnp.int32),
            n_tiles=jnp.zeros(b, count_dtype),
            bucket_hits=jnp.zeros((b, n_caps), count_dtype),
            nbr=None)

    return init, run


def ensemble_run_block(
    batched: ParticleState,
    *,
    t_end: float,
    n_events: int = 64,
    dt_max: float = 0.0625,
    n_levels: int = 8,
    carry: Optional[BlockCarry] = None,
    n_active=None,
    eta: float = 0.02,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    dtype: str = "fp32",
    compaction: str = "none",
    bucket_mode: str = "member",
    block_i: Optional[int] = None,
    block_j: Optional[int] = None,
    sources: str = "full",
    neighbor_radius: float = 0.25,
    refresh_levels: int = 2,
    devices: Optional[Sequence[jax.Device]] = None,
    mesh: Optional[Sequence[int]] = None,
):
    """Advance an initialized batch by up to ``n_events`` block events each.

    Returns ``(batched, carry)``; call again with the returned carry until
    ``batched.time.min() >= t_end`` (a member's ``time`` advances at its
    macro boundaries).  ``t_end`` is a shared scalar or a per-member ``(B,)``
    vector (see :func:`_as_t_end`) — a member whose deadline has passed
    freezes whole while its batch-mates keep integrating.  ``carry.n_pairs`` accumulates the per-run pairwise
    force evaluations actually performed (per Hermite pass) — the measured
    cost telemetry reports; ``carry.n_events`` counts productive events;
    ``carry.n_tiles`` the kernel grid tiles launched per member (both
    passes).

    ``compaction="gather"`` gathers each event's active targets into a
    dense block-aligned buffer sized from a static capacity schedule and
    launches the kernels on the shrunk ``ceil(cap/BI) x N/BJ`` grid
    (bit-for-bit the masked dense result).  ``bucket_mode`` controls how a
    batch shares capacity buckets: ``"member"`` (default) groups members by
    their static ``n_active`` ceiling into pre-lowered bucket groups (see
    :func:`_bucket_groups`), so a mixed batch's quiescent members stop
    paying for its widest member's grid; ``"shared"`` is the original
    batch-shared bucket (one group, the baseline the heterogeneous-bucket
    regression test measures against).  Both modes are bit-for-bit
    identical physics — only the launch schedule differs.
    ``block_i``/``block_j`` override the kernel tile shape (default: the
    kernel's own); the compaction win is bounded by ``N / block_i``, so
    small-N runs want a smaller ``block_i`` than the all-pairs default.

    ``sources="neighbor"`` switches the force evaluation to the
    Ahmad-Cohen near/far split (see :func:`_block_engine`):
    ``neighbor_radius`` is the bounding-sphere window radius in simulation
    length units, ``refresh_levels`` how many levels below the macro the
    far-field refresh cadence sits (refresh every ``n_sub >>
    refresh_levels`` ticks).  The batch should be Morton-sorted first
    (:func:`spatial_sort_batched`; the convenience entry points do it) so
    index blocks are spatially tight.  ``sources="full"`` is bit-identical
    to the pre-neighbor engine.

    ``mesh=(B_shards, P_shards)`` fuses batch and domain sharding over
    ``B_shards * P_shards`` devices (the ``--mesh BxP`` CLI axis).  With
    ``sources="full"`` the force evaluation runs through ONE shard_map over
    the 2-D mesh (:func:`_fused_block_engine`): each device holds
    ``B/B_shards`` members x ``N/P_shards`` target rows, capacity buckets
    are sized host-side from the analytic ``block_level_occupancy`` bound
    and shared per shard (``bucket_mode`` does not apply — the switch lives
    inside the shards).  With ``sources="neighbor"`` the vmapped engine
    keeps running and the 2-D mesh rides as a sharding *constraint* on the
    ``(B, N)`` state leaves — GSPMD partitions each member's windowed
    kernels along ``dev``, which is what lets several large-N
    neighbor-scheme members share one device group's memory.  ``mesh=None``
    (default) is the 1-D batch-sharded layout, unchanged.
    """
    if n_levels < 1:
        raise ValueError(f"n_levels={n_levels} must be >= 1")
    if sources not in ops.SOURCES:
        raise ValueError(
            f"sources must be one of {ops.SOURCES}; got {sources!r}")
    if sources == "neighbor" and compaction != "none":
        raise ValueError(
            "sources='neighbor' gathers its own per-block source windows; "
            "it composes with compaction='none' only")
    if refresh_levels < 0:
        raise ValueError(f"refresh_levels={refresh_levels} must be >= 0")
    # an unknown compaction mode fails in make_block_evaluator (same
    # ValueError) when the engine is first built — no duplicate check here
    mesh_obj = _fused_mesh(devices, mesh) if mesh is not None else \
        _batch_mesh(devices)
    bext = _mesh_batch_extent(mesh_obj)
    n_active = _as_n_active(batched, n_active)
    t_end_ = _as_t_end(batched, t_end)
    if carry is None:
        (padded, na, t_end_), b = _pad_batch((batched, n_active, t_end_),
                                             bext)
    else:
        (padded, na, t_end_, carry), b = _pad_batch(
            (batched, n_active, t_end_, carry), bext)
    bi = block_i or nbody_force.DEFAULT_BLOCK_I
    bj = block_j or nbody_force.DEFAULT_BLOCK_J
    if mesh is not None and sources == "full":
        init, run = _fused_block_engine(
            mesh_obj, order, eps, impl, eta, dt_max, n_levels, compaction,
            bi, bj, dtype)
    else:
        # groups come from the *padded* batch (padding repeats the first
        # run, so it lands in that run's group); n_active must be concrete
        # here — these entry points run host-side loops anyway
        groups = _bucket_groups(padded.pos.shape[1], na, bi, bj, compaction,
                                bucket_mode)
        init, run = _block_engine(
            order, eps, impl, mesh_obj, eta, dt_max, n_levels, compaction,
            bi, bj, groups, dtype, sources, float(neighbor_radius),
            refresh_levels)
    if carry is None:
        carry = init(padded, na, t_end_)
    out, carry = run(padded, carry, na, t_end_, n_events)
    return tuple(jax.tree_util.tree_map(lambda x: x[:b], t)
                 for t in (out, carry))


def block_admit_member(carry: BlockCarry, member: ParticleState, slot: int,
                       t_end, *, eta: float = 0.02, dt_max: float = 0.0625,
                       n_levels: int = 8) -> BlockCarry:
    """Splice a freshly admitted member's block carry into ``slot``.

    The serving layer backfills a retired slot by writing the new member's
    *initialized* ``(N,)`` state into the batch and resetting that slot's
    carry: fresh levels/ticks from the member's own Aarseth dt distribution
    (:func:`_event_init`, the same bootstrap ``init`` runs batch-wide) and
    zeroed per-member counters, so the retiring run's telemetry never bleeds
    into its successor's.  Every other slot's carry leaves are untouched —
    batch-mates stay bit-identical (the backfill invariance test pins this).
    ``eta``/``dt_max``/``n_levels`` must match the engine the pod runs.
    """
    t_end_ = jnp.asarray(t_end, member.pos.dtype)
    t_last, levels, dt_macro = _event_init(
        member, member.pos.shape[0], t_end_, eta=eta, dt_max=dt_max,
        n_levels=n_levels)
    nbr = carry.nbr
    if nbr is not None:
        # t_ref = -1 forces the new member to refresh (and rebuild its
        # windows) at its first event; the retiring run's far field and
        # neighbor telemetry never bleed into its successor
        nbr = NeighborCarry(
            win_idx=nbr.win_idx.at[slot].set(0),
            win_cnt=nbr.win_cnt.at[slot].set(0),
            acc_far=nbr.acc_far.at[slot].set(0),
            jerk_far=nbr.jerk_far.at[slot].set(0),
            snap_far=nbr.snap_far.at[slot].set(0),
            pot_far=nbr.pot_far.at[slot].set(0),
            t_ref=nbr.t_ref.at[slot].set(-1),
            n_refresh=nbr.n_refresh.at[slot].set(0),
            n_overflow=nbr.n_overflow.at[slot].set(0))
    return BlockCarry(
        t_last=carry.t_last.at[slot].set(t_last),
        levels=carry.levels.at[slot].set(levels),
        dt_macro=carry.dt_macro.at[slot].set(dt_macro),
        n_pairs=carry.n_pairs.at[slot].set(0),
        n_events=carry.n_events.at[slot].set(0),
        n_tiles=carry.n_tiles.at[slot].set(0),
        bucket_hits=carry.bucket_hits.at[slot].set(0)
        if carry.bucket_hits.ndim == 2 else carry.bucket_hits,
        nbr=nbr)


def evolve_ensemble_block(
    states,
    *,
    t_end: float,
    dt_max: float = 0.0625,
    n_levels: int = 8,
    n_active=None,
    eta: float = 0.02,
    order: int = 6,
    eps: float = 1e-7,
    impl: Optional[str] = None,
    kernel: Optional[str] = None,
    dtype: str = "fp32",
    compaction: str = "none",
    bucket_mode: str = "member",
    block_i: Optional[int] = None,
    block_j: Optional[int] = None,
    sources: str = "full",
    neighbor_radius: float = 0.25,
    refresh_levels: int = 2,
    devices: Optional[Sequence[jax.Device]] = None,
    mesh: Optional[Sequence[int]] = None,
    n_events: int = 256,
    max_chunks: int = 100_000,
):
    """One-shot block-timestep convenience: stack, initialize, evolve to
    ``t_end``.  Returns ``(batched, carry)`` (see
    :func:`ensemble_run_block`; ``mesh=(B_shards, P_shards)`` selects the
    fused 2-D layout).  ``sources="neighbor"`` ORB-sorts the
    batch (``spatial_sort_batched``) before the bootstrap so the neighbor
    windows see spatially tight index blocks; the returned batch is in
    that sorted order."""
    impl = resolve_eval_impl(impl, kernel)
    batched = states if isinstance(states, ParticleState) else \
        stack_states(list(states))
    if sources == "neighbor":
        bi = block_i or nbody_force.DEFAULT_BLOCK_I
        bj = block_j or nbody_force.DEFAULT_BLOCK_J
        batched = spatial_sort_batched(batched, n_active,
                                       leaf=math.gcd(bi, bj))
    kw = dict(n_active=n_active, order=order, eps=eps, impl=impl,
              dtype=dtype, devices=devices, mesh=mesh)
    batched = ensemble_initialize(batched, **kw)
    carry = None
    for _ in range(max_chunks):
        batched, carry = ensemble_run_block(
            batched, t_end=t_end, n_events=n_events, dt_max=dt_max,
            n_levels=n_levels, carry=carry, eta=eta, compaction=compaction,
            bucket_mode=bucket_mode, block_i=block_i, block_j=block_j,
            sources=sources, neighbor_radius=neighbor_radius,
            refresh_levels=refresh_levels, **kw)
        if float(jnp.min(batched.time)) >= t_end:
            break
    return batched, carry


# --------------------------------------------------------------------------
# single-run block stepper under a multi-device distribution strategy
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def _strategy_block_engine(strategy: str, n_devices: int,
                           chips_per_card: int, order: int, eps: float,
                           impl: str, eta: float, dt_max: float,
                           n_levels: int, compaction: str,
                           block_i: int, block_j: int, dtype: str,
                           sources: str = "full"):
    """Block-timestep engine whose force evaluation is *distributed* over a
    device mesh instead of vmapped over a batch: one run, its domain sharded
    by one of the paper's strategies, each shard compacting its own local
    active targets (``core.strategies.make_strategy_block_evaluator``).

    Reuses the exact per-event logic of the ensemble engine
    (:func:`_event_pre` / :func:`_event_post`), so the event schedule — and
    with it the committed block golden trajectory — is identical; only the
    evaluator (and the per-*shard* tile accounting in the carry) differs.

    Capacity buckets are sized **host-side** (ROADMAP 5c): each event's
    per-shard launch extent comes from the analytic
    ``block_level_occupancy`` bound at the tick's threshold level — no
    runtime gather of the activity mask feeds the bucket switch.  A
    particle at level ``l`` steps at exactly the multiples of its period
    (promotion is commensurate, demotion lands on doubled-period ticks),
    so the tick's active set IS ``{level >= threshold}`` and the bound
    equals the measured count — identical buckets, tiles, and physics
    (``test_obs_metrics.py`` pins ``launched <= bound-sized <= dense``).
    """
    from repro.core.strategies import make_strategy_block_evaluator

    _count_engine_build("block_strategy")
    devs = jax.devices()[:n_devices]
    bev = make_strategy_block_evaluator(
        strategy, devices=devs, chips_per_card=chips_per_card, eps=eps,
        order=order, impl=impl, block_i=block_i, block_j=block_j,
        compaction=compaction, dtype=dtype, sources=sources)
    n_sub = 2 ** (n_levels - 1)
    event_init = functools.partial(_event_init, eta=eta, dt_max=dt_max,
                                   n_levels=n_levels)
    event_pre = functools.partial(_event_pre, n_sub=n_sub)
    event_post = functools.partial(_event_post, n_sub=n_sub, eta=eta,
                                   dt_max=dt_max, n_levels=n_levels,
                                   order=order)

    @functools.partial(jax.jit, static_argnames=("n_events",))
    def run(state, carry: BlockCarry, t_end, n_events: int):
        n = state.pos.shape[0]
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)

        def body(acc, _):
            s, c = acc
            with jax.named_scope("event.pre"):
                live, t_next, active, h, xp, vp, ap, _ = event_pre(
                    s, c.t_last, c.levels, c.dt_macro, n, t_end)
            # the shard-local permutations live inside the shards — the
            # global argsort from event_pre is not used here
            with jax.named_scope("event.force"):
                # host-side bucket sizing: padded rows carry level -1, so
                # each shard's contiguous chunk counts only real particles
                # at or above the tick's threshold level
                thr = hermite.tick_threshold_level(t_next,
                                                   n_levels=n_levels)
                n_pad = -(-n // n_devices) * n_devices
                lev_pad = jnp.pad(c.levels, (0, n_pad - n),
                                  constant_values=-1)
                bound = jax.vmap(
                    lambda lv: hermite.block_level_occupancy(
                        lv, n_levels=n_levels)[thr]
                )(lev_pad.reshape(n_devices, -1))
                ev, tiles = bev(xp, vp, ap, s.mass, active, bound)
            with jax.named_scope("event.post"):
                s1, t_last, levels, dt_macro, dp, live = event_post(
                    s, ev, live, t_next, active, h, c.t_last, c.levels,
                    c.dt_macro, n, t_end)
            c1 = BlockCarry(t_last=t_last, levels=levels, dt_macro=dt_macro,
                            n_pairs=c.n_pairs + dp,
                            n_events=c.n_events + live.astype(jnp.int32),
                            n_tiles=c.n_tiles + jnp.where(
                                live, tiles, 0).astype(count_dtype),
                            bucket_hits=c.bucket_hits)
            return (s1, c1), None

        (state, carry), _ = jax.lax.scan(body, (state, carry), None,
                                         length=n_events)
        return state, carry

    @jax.jit
    def init(state, t_end):
        t_last, levels, dt_macro = event_init(state, state.pos.shape[0],
                                              t_end)
        count_dtype = jax.dtypes.canonicalize_dtype(jnp.float64)
        return BlockCarry(
            t_last=t_last, levels=levels, dt_macro=dt_macro,
            n_pairs=jnp.zeros((), count_dtype),
            n_events=jnp.zeros((), jnp.int32),
            n_tiles=jnp.zeros(n_devices, count_dtype),
            # the per-shard switch lives inside the shards; no batch-level
            # bucket distribution to report (see grid_tiles_per_shard)
            bucket_hits=jnp.zeros((0,), count_dtype))

    return init, run


def _n_devices(devices) -> int:
    if devices is None:
        return len(jax.devices())
    if isinstance(devices, int):
        return devices
    return len(list(devices))


def strategy_run_block(
    state: ParticleState,
    *,
    t_end: float,
    n_events: int = 64,
    dt_max: float = 0.0625,
    n_levels: int = 8,
    carry: Optional[BlockCarry] = None,
    eta: float = 0.02,
    order: int = 6,
    eps: float = 1e-7,
    impl: str = "xla",
    dtype: str = "fp32",
    strategy: str = "replicated",
    chips_per_card: int = 2,
    compaction: str = "none",
    block_i: Optional[int] = None,
    block_j: Optional[int] = None,
    sources: str = "full",
    devices=None,
):
    """Advance ONE initialized run by up to ``n_events`` block events, the
    force evaluation distributed by ``strategy`` over ``devices`` (an int
    count, a device sequence, or None for all visible devices).
    ``sources`` is validated by the strategy evaluator — the sharded
    strategies evaluate full sources only (``"neighbor"`` runs on the
    ensemble engine, strategy ``"single"``).

    Returns ``(state, carry)`` like :func:`ensemble_run_block`, except the
    carry's scalar leaves are unbatched and ``carry.n_tiles`` is the
    ``(P,)`` vector of kernel grid tiles *each shard* enqueued — with
    ``compaction="gather"`` every shard gathers its local active targets
    and launches ``ceil(cap_local/BI) x N/BJ`` tiles, so the vector shows
    which chips' launch schedules the active set actually touched.
    """
    if n_levels < 1:
        raise ValueError(f"n_levels={n_levels} must be >= 1")
    init, run = _strategy_block_engine(
        strategy, _n_devices(devices), chips_per_card, order, eps, impl,
        eta, dt_max, n_levels, compaction,
        block_i or nbody_force.DEFAULT_BLOCK_I,
        block_j or nbody_force.DEFAULT_BLOCK_J, dtype, sources)
    t_end_ = jnp.asarray(t_end, state.pos.dtype)
    if carry is None:
        carry = init(state, t_end_)
    return run(state, carry, t_end_, n_events)


def evolve_strategy_block(
    state: ParticleState,
    *,
    t_end: float,
    strategy: str = "replicated",
    dt_max: float = 0.0625,
    n_levels: int = 8,
    eta: float = 0.02,
    order: int = 6,
    eps: float = 1e-7,
    impl: Optional[str] = None,
    kernel: Optional[str] = None,
    dtype: str = "fp32",
    chips_per_card: int = 2,
    compaction: str = "none",
    block_i: Optional[int] = None,
    block_j: Optional[int] = None,
    devices=None,
    n_events: int = 64,
    max_chunks: int = 100_000,
):
    """One-shot strategy-distributed block run: initialize (with the same
    strategy's lockstep evaluator), evolve to ``t_end``.  Returns
    ``(state, carry)`` (see :func:`strategy_run_block`)."""
    from repro.core.strategies import make_strategy_evaluator

    impl = resolve_eval_impl(impl, kernel)
    ndev = _n_devices(devices)
    ev = make_strategy_evaluator(
        strategy, devices=jax.devices()[:ndev],
        chips_per_card=chips_per_card, eps=eps, order=order, impl=impl,
        block_i=block_i or nbody_force.DEFAULT_BLOCK_I,
        block_j=block_j or nbody_force.DEFAULT_BLOCK_J, dtype=dtype)
    state = hermite.initialize(state, ev)
    carry = None
    for _ in range(max_chunks):
        state, carry = strategy_run_block(
            state, t_end=t_end, n_events=n_events, dt_max=dt_max,
            n_levels=n_levels, carry=carry, eta=eta, order=order, eps=eps,
            impl=impl, dtype=dtype, strategy=strategy,
            chips_per_card=chips_per_card,
            compaction=compaction, block_i=block_i, block_j=block_j,
            devices=ndev)
        if float(state.time) >= t_end:
            break
    return state, carry


def evolve_ensemble(
    states,
    *,
    n_steps: int,
    dt: float,
    n_active=None,
    order: int = 6,
    eps: float = 1e-7,
    impl: Optional[str] = None,
    kernel: Optional[str] = None,
    dtype: str = "fp32",
    devices: Optional[Sequence[jax.Device]] = None,
    strategy: str = "replicated",
) -> ParticleState:
    """One-shot convenience: stack (if needed), initialize, evolve.

    ``strategy`` is validated against the known strategy names but — the runs
    being independent — only affects telemetry labeling, not the math.
    Pass at most one of ``impl`` (low-level path, default "xla") and
    ``kernel`` ("ref" | "pallas"); an explicit pair conflicts.
    """
    if strategy not in STRATEGIES and strategy != "single":
        raise ValueError(
            f"unknown strategy {strategy!r}; one of {('single',) + STRATEGIES}")
    impl = resolve_eval_impl(impl, kernel)
    batched = states if isinstance(states, ParticleState) else \
        stack_states(list(states))
    kw = dict(n_active=n_active, order=order, eps=eps, impl=impl,
              dtype=dtype, devices=devices)
    batched = ensemble_initialize(batched, **kw)
    return ensemble_run(batched, n_steps=n_steps, dt=dt, **kw)
