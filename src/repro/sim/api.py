"""Public simulation API: composable build -> step -> collect runners.

The seed's driver exposed one monolithic ``run(cfg)`` whose private
``_run_single/_run_ensemble/_run_mixed/_run_block_strategy`` dispatch could
only be consumed whole.  This module factors that dispatch into a registry
of :class:`Runner` objects, each splitting its run into three composable
calls:

* ``build(cfg) -> RunHandle`` — construct initial conditions, evaluator and
  telemetry recorder, bootstrap derivatives, record the t=0 snapshot;
* ``step(handle) -> bool`` — advance one diagnostics chunk (the engine's
  macro-step boundary); returns True once the run has finished;
* ``collect(handle) -> RunReport`` — final diagnostics and the versioned
  telemetry report.

:func:`run` recomposes the three into the historical one-shot entry (the
CLI and benchmarks call it via the ``repro.sim.driver`` shim, byte-identical
telemetry); the serving layer (``repro.serve.sim_engine``) is the consumer
the split exists for — a server admits/advances/retires *many* interleaved
runs and cannot give any single one a private blocking loop.

Dispatch is data-driven: :data:`RUNNERS` maps a kind name to its runner, and
:func:`resolve_kind` picks the first registered runner whose ``matches``
accepts the config (registration order is the priority order, mirroring the
seed's if/elif chain exactly).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hermite, nbody
from repro.core.evaluate import make_evaluator
from repro.core.strategies import STRATEGIES, make_strategy_evaluator
from repro.kernels import nbody_force, ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.sim import ensemble as ens
from repro.sim import scenarios, telemetry
from repro.sim.telemetry import RunReport

MAX_STEPS = 200_000


@dataclasses.dataclass(frozen=True)
class SimConfig:
    scenario: str = "plummer"
    n: int = 256
    seed: int = 0
    ensemble: int = 1
    t_end: float = 1.0
    dt: Optional[float] = None       # fixed step (stepper="fixed")
    stepper: Optional[str] = None    # "fixed" | "adaptive" | "block"
    #   (None infers: "fixed" when dt is given, else "adaptive")
    dt_max: float = 0.0625           # coarsest step (adaptive + block)
    n_levels: Optional[int] = 8      # block hierarchy depth (None => auto:
    #   per-member from the initial Aarseth dt distribution, clamped [1, 8])
    compaction: str = "none"         # "none" | "gather" (block stepper only)
    bucket_mode: str = "member"      # "member" (per-member capacity bucket
    #   groups) | "shared" (batch-shared bucket baseline); gather mode only
    block_i: Optional[int] = None    # kernel tile shape override (block
    block_j: Optional[int] = None    #   stepper; None => kernel defaults)
    sources: str = "full"            # "full" | "neighbor" (Ahmad-Cohen
    #   near/far split; block stepper only — see docs/ensembles.md)
    mesh: Optional[Tuple[int, int]] = None  # fused (batch, domain) device
    #   grid (block stepper; product must equal devices — --mesh BxP)
    neighbor_radius: float = 0.25    # AC window radius (simulation length)
    refresh_levels: int = 2          # far-field refresh: levels below macro
    eta: float = 0.02
    order: int = 6
    strategy: str = "single"
    devices: int = 1
    impl: Optional[str] = None
    kernel: Optional[str] = None     # "ref" | "pallas" (excludes impl)
    dtype: str = "fp32"              # "fp64" | "fp32" | "mixed" precision axis
    mix: Optional[Tuple[Tuple[str, int], ...]] = None  # heterogeneous batch
    pad: Optional[int] = None        # padded N_max (None => auto = max N)
    eps: float = 1e-7
    diag_every: int = 16             # steps between diagnostics snapshots
    scenario_params: Mapping[str, Any] = \
        dataclasses.field(default_factory=dict)
    validate_ic: bool = True
    out: Optional[str] = None        # JSON report path (None => don't write)
    trace: Optional[str] = None      # Chrome-trace/Perfetto JSON path
    #   (None => zero-overhead NullTracer; see repro.obs.trace)
    metrics_interval: int = 0        # chunks between in-run metrics-registry
    #   snapshots attached to the diagnostics series (0 => final only)

    def resolved_stepper(self) -> str:
        """Resolve (stepper, dt) to one of ``ensemble.STEPPERS``.

        An explicit ``stepper`` must be consistent with ``dt``: fixed mode
        needs a step, the adaptive/block modes choose their own (``dt_max``
        caps them) — a silently ignored ``dt`` would misreport the run.
        """
        stepper = self.stepper or ("fixed" if self.dt is not None
                                   else "adaptive")
        if stepper not in ens.STEPPERS:
            raise ValueError(
                f"unknown stepper {stepper!r}; one of {ens.STEPPERS}")
        if stepper == "fixed" and self.dt is None:
            raise ValueError("stepper='fixed' needs an explicit dt")
        if stepper != "fixed" and self.dt is not None:
            raise ValueError(
                f"stepper={stepper!r} chooses its own timestep; dt={self.dt} "
                "would be ignored (use dt_max to cap it)")
        if self.compaction != "none" and stepper != "block":
            raise ValueError(
                f"compaction={self.compaction!r} only applies to the block "
                "stepper (the lockstep modes evaluate every target)")
        if self.bucket_mode not in ens.BUCKET_MODES:
            raise ValueError(
                f"bucket_mode must be one of {ens.BUCKET_MODES}; "
                f"got {self.bucket_mode!r}")
        if self.bucket_mode != "member" and self.compaction != "gather":
            raise ValueError(
                f"bucket_mode={self.bucket_mode!r} selects the capacity-"
                "bucket dispatch of compaction='gather'; without gather "
                "there are no buckets to share")
        if (self.block_i or self.block_j) and stepper != "block":
            raise ValueError(
                "block_i/block_j tile overrides only reach the block "
                f"stepper's kernels; stepper={stepper!r} would silently "
                "run at the kernel defaults")
        if self.sources not in ops.SOURCES:
            raise ValueError(
                f"sources must be one of {ops.SOURCES}; "
                f"got {self.sources!r}")
        if self.sources == "neighbor":
            if stepper != "block":
                raise ValueError(
                    "sources='neighbor' is the Ahmad-Cohen split of the "
                    f"block stepper's event loop; stepper={stepper!r} has "
                    "no regular/irregular levels to split")
            if self.compaction != "none":
                raise ValueError(
                    "sources='neighbor' gathers its own per-block source "
                    "windows; it composes with compaction='none' only")
            if self.strategy != "single":
                raise ValueError(
                    "sources='neighbor' runs on the vmapped batch engine "
                    f"only; strategy={self.strategy!r} shards full sources "
                    "(see docs/ensembles.md)")
            if self.mix is not None:
                raise ValueError(
                    "sources='neighbor' shares one window-capacity bucket "
                    "across the batch; a mixed-N ensemble would let its "
                    "widest member size every member's gather")
        if self.refresh_levels < 0:
            raise ValueError(
                f"refresh_levels={self.refresh_levels} must be >= 0")
        if self.mesh is not None:
            if stepper != "block":
                raise ValueError(
                    "mesh=(B, P) fuses batch and domain sharding of the "
                    f"block engine; stepper={stepper!r} has no domain-"
                    "sharded force pass to fuse")
            if len(self.mesh) != 2 or any(int(e) < 1 for e in self.mesh):
                raise ValueError(
                    f"mesh={self.mesh!r} must be two positive extents "
                    "(B_shards, P_shards)")
            if self.mesh[0] * self.mesh[1] != self.devices:
                raise ValueError(
                    f"mesh={tuple(self.mesh)} covers "
                    f"{self.mesh[0] * self.mesh[1]} devices; --devices says "
                    f"{self.devices} (the fused grid must tile the device "
                    "list exactly)")
            if self.strategy != "single":
                raise ValueError(
                    "mesh=(B, P) supplies the domain sharding itself; "
                    f"strategy={self.strategy!r} would shard the same axis "
                    "twice")
            if self.bucket_mode != "member":
                raise ValueError(
                    "the fused mesh engine sizes one capacity bucket per "
                    f"(batch, domain) shard; bucket_mode="
                    f"{self.bucket_mode!r} selects the vmapped engine's "
                    "dispatch and would be silently ignored")
        if self.n_levels is None and stepper != "block":
            raise ValueError(
                "n_levels=None (--levels auto) sizes the block hierarchy; "
                f"stepper={stepper!r} has no levels to size")
        return stepper

    def meta(self) -> Dict[str, Any]:
        meta = {
            "scenario": self.scenario, "n": self.n, "seed": self.seed,
            "ensemble": self.ensemble, "strategy": self.strategy,
            "t_end": self.t_end, "dt": self.dt, "order": self.order,
            "stepper": self.resolved_stepper(),
            "dtype": self.dtype,
            "params": dict(self.scenario_params),
        }
        if meta["stepper"] == "block":
            meta["dt_max"] = self.dt_max
            meta["n_levels"] = self.n_levels    # None until auto-resolved
            meta["compaction"] = self.compaction
            if self.compaction == "gather":
                meta["bucket_mode"] = self.bucket_mode
            meta["sources"] = self.sources
            if self.mesh is not None:
                meta["mesh"] = list(self.mesh)
            if self.sources == "neighbor":
                meta["neighbor_radius"] = self.neighbor_radius
                meta["refresh_levels"] = self.refresh_levels
        if meta["stepper"] == "adaptive":
            meta["dt_max"] = self.dt_max
        if self.mix is not None:
            meta["scenario"] = "mixed"
            meta["mix"] = [list(m) for m in self.mix]
            meta["pad"] = self.pad
            # the dataclass default n is meaningless for a mix; report the
            # requested N_max so meta agrees with the batch's n_bodies
            meta["n"] = self.pad if self.pad is not None \
                else max(n for _, n in self.mix)
        if self.kernel is not None:
            meta["kernel"] = self.kernel
        return meta


def validate_config(cfg: SimConfig) -> str:
    """Cross-field validation shared by :func:`run` and every ``build``.

    Returns the resolved stepper (the last check, so the error precedence
    matches the seed driver exactly).
    """
    if cfg.ensemble < 1:
        raise ValueError(f"ensemble={cfg.ensemble} must be >= 1")
    if cfg.metrics_interval < 0:
        raise ValueError(
            f"metrics_interval={cfg.metrics_interval} must be >= 0")
    if cfg.dtype not in ops.DTYPES:
        raise ValueError(
            f"dtype must be one of {ops.DTYPES}; got {cfg.dtype!r}")
    if cfg.dtype == "fp64" and (cfg.kernel is not None
                                or cfg.impl not in (None, "fp64")):
        raise ValueError(
            "dtype='fp64' runs the pure-jnp oracle (no kernel); an explicit "
            f"kernel={cfg.kernel!r}/impl={cfg.impl!r} would be silently "
            "ignored")
    if cfg.impl == "fp64" and cfg.dtype == "mixed":
        raise ValueError(
            "impl='fp64' (golden reference) conflicts with dtype='mixed' "
            "(reduced-precision kernel mode)")
    return cfg.resolved_stepper()


def _device_list(cfg: SimConfig):
    devs = jax.devices()
    if cfg.devices > len(devs):
        raise ValueError(
            f"requested {cfg.devices} devices, only {len(devs)} visible "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count before "
            "importing jax — the sim_run CLI does this)")
    return devs[: cfg.devices]


def _build_states(cfg: SimConfig):
    return [
        scenarios.make(cfg.scenario, cfg.n, seed=cfg.seed + i,
                       validate=cfg.validate_ic, **dict(cfg.scenario_params))
        for i in range(cfg.ensemble)
    ]


def _chunk_spans(tracer, t0_us: float, dur_us: float, *, chunk: int,
                 events: int, tiles: Optional[float] = None,
                 max_children: int = 256) -> None:
    """One measured ``macro-step`` span per engine chunk, synthetically
    subdivided into ``event`` -> ``kernel-launch`` children.

    The per-event work runs inside ``lax.scan`` under ``jit`` — untimeable
    from the host — so the chunk aggregate (wall, event count, launched
    tiles) is *measured* and only the even subdivision is synthetic, flagged
    ``{"synthetic": true}`` on every reconstructed child.
    """
    if not tracer.enabled:
        return
    args = {"chunk": chunk, "events": int(events)}
    if tiles is not None:
        args["tiles"] = float(tiles)
    tracer.add_span("macro-step", t0_us, dur_us, args=args)
    n = min(int(events), max_children)
    if n <= 0:
        return
    child = dur_us / n
    per = {"synthetic": True, "events": int(events) // n}
    if tiles is not None:
        per["tiles"] = float(tiles) / n
    for i in range(n):
        s = t0_us + i * child
        tracer.add_span("event", s, child * 0.999, args=per)
        if tiles is not None:
            tracer.add_span("kernel-launch", s + 0.1 * child, 0.8 * child,
                            args=per)


def _mix_params(cfg: SimConfig) -> Dict[str, Dict[str, Any]]:
    """Distribute flat CLI params over the mix: each scenario takes the keys
    its registry spec accepts; a key no scenario accepts raises (same
    contract as the homogeneous path, where build() rejects it)."""
    flat = dict(cfg.scenario_params)
    out: Dict[str, Dict[str, Any]] = {}
    claimed = set()
    for name, _ in cfg.mix:
        spec = scenarios.get_spec(name)
        kw = {k: v for k, v in flat.items() if k in spec.defaults}
        claimed.update(kw)
        if kw:
            out[name] = kw
    orphans = set(flat) - claimed
    if orphans:
        raise scenarios.ScenarioError(
            f"parameter(s) {sorted(orphans)} not accepted by any scenario "
            f"in the mix {[name for name, _ in cfg.mix]}")
    return out


def _auto_levels(cfg: SimConfig, batched) -> list:
    """Per-member block hierarchy depth from the initial (post-initialize)
    Aarseth dt distribution, clamped to [1, 8] (``--levels auto``)."""
    dt_i = jax.vmap(
        lambda s: hermite.aarseth_dt_particles(s, eta=cfg.eta,
                                               dt_max=cfg.dt_max))(batched)
    depth = jax.vmap(
        lambda d: hermite.auto_n_levels(d, dt_max=cfg.dt_max))(dt_i)
    return [int(d) for d in np.asarray(depth)]


# --------------------------------------------------------------------------
# runner surface
# --------------------------------------------------------------------------
class RunHandle:
    """Mutable in-flight state of one run between :meth:`Runner.step` calls.

    Owned by the runner that built it; runners attach whatever stepper state
    they carry between chunks (engine carries, counters, the recorder) as
    plain attributes.  ``finished`` flips once the run needs no more steps.
    """

    def __init__(self, cfg: SimConfig, kind: str):
        self.cfg = cfg
        self.kind = kind
        self.recorder: Optional[telemetry.TelemetryRecorder] = None
        self.finished = False


class Runner:
    """One run mode: the build/step/collect triple behind a registry kind."""

    kind: str = ""

    def matches(self, cfg: SimConfig) -> bool:
        raise NotImplementedError

    def build(self, cfg: SimConfig) -> RunHandle:
        raise NotImplementedError

    def step(self, handle: RunHandle) -> bool:
        raise NotImplementedError

    def collect(self, handle: RunHandle) -> RunReport:
        raise NotImplementedError


RUNNERS: Dict[str, Runner] = {}


def register_runner(runner: Runner) -> Runner:
    """Register a runner under its ``kind``; registration order is the
    dispatch priority order of :func:`resolve_kind`."""
    if not runner.kind:
        raise ValueError("runner needs a non-empty kind")
    RUNNERS[runner.kind] = runner
    return runner


def resolve_kind(cfg: SimConfig) -> str:
    """Pick the registered kind for a config (first ``matches`` wins)."""
    validate_config(cfg)
    for kind, runner in RUNNERS.items():
        if runner.matches(cfg):
            return kind
    raise ValueError(f"no registered runner accepts {cfg!r}")


def get_runner(kind: str) -> Runner:
    try:
        return RUNNERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown runner kind {kind!r}; "
            f"registered: {tuple(RUNNERS)}") from None


# --------------------------------------------------------------------------
# single run (per-step telemetry, any strategy, adaptive or fixed dt)
# --------------------------------------------------------------------------
class SingleRunner(Runner):
    kind = "single"

    def matches(self, cfg: SimConfig) -> bool:
        return cfg.mix is None and cfg.ensemble == 1 \
            and cfg.resolved_stepper() != "block"

    def build(self, cfg: SimConfig) -> RunHandle:
        validate_config(cfg)
        h = RunHandle(cfg, self.kind)
        state = _build_states(cfg)[0]
        # None lets make_evaluator pick the backend default; an explicit
        # impl+kernel pair is a conflict (e.g. fp64 vs a kernel switch)
        impl = ens.resolve_eval_impl(cfg.impl, cfg.kernel, default=None)
        if cfg.strategy == "single":
            if impl == "fp64" or cfg.dtype == "fp64":
                # golden reference: a precision, not a kernel
                evaluator = make_evaluator(precision="fp64", order=cfg.order,
                                           eps=cfg.eps)
            else:
                evaluator = make_evaluator(order=cfg.order, eps=cfg.eps,
                                           impl=impl, dtype=cfg.dtype)
        elif cfg.strategy in STRATEGIES:
            if impl == "fp64" or cfg.dtype == "fp64":
                raise ValueError(
                    "fp64 (golden reference) only runs under "
                    "strategy='single'")
            evaluator = make_strategy_evaluator(
                cfg.strategy, devices=_device_list(cfg), order=cfg.order,
                eps=cfg.eps, impl=impl or "xla", dtype=cfg.dtype)
        else:
            raise ValueError(f"unknown strategy {cfg.strategy!r}")

        h.recorder = telemetry.TelemetryRecorder(cfg.meta())
        state = hermite.initialize(state, evaluator)
        jax.block_until_ready(state.pos)
        h.e0 = float(nbody.total_energy(state))
        h.recorder.record_snapshot(0, 0.0, energy=h.e0, de_rel=0.0)
        h.state, h.evaluator = state, evaluator
        h.steps, h.h_prev = 0, None
        return h

    def step(self, h: RunHandle) -> bool:
        if h.finished:
            return True
        cfg, state = h.cfg, h.state
        if not (float(state.time) < cfg.t_end and h.steps < MAX_STEPS):
            h.finished = True
            return True
        if cfg.dt is not None:
            dt = cfg.dt
        else:
            dt = float(hermite.aarseth_dt(state, eta=cfg.eta,
                                          dt_max=cfg.dt_max))
            if h.h_prev is not None:  # rate-limit dt changes (robustness)
                dt = min(max(dt, 0.5 * h.h_prev), 2.0 * h.h_prev)
            h.h_prev = dt
        dt = min(dt, cfg.t_end - float(state.time))
        t0 = time.perf_counter()
        with obs_trace.get_tracer().span("macro-step", step=h.steps + 1,
                                         dt=dt):
            state = hermite.step(state, jnp.asarray(dt, state.dtype),
                                 h.evaluator, order=cfg.order)
            jax.block_until_ready(state.pos)
        h.state = state
        h.steps += 1
        obs_metrics.registry().counter(
            "sim.events", unit="events",
            help="productive member-events (lockstep: member-steps)").inc()
        h.recorder.record_step(h.steps, float(state.time),
                               time.perf_counter() - t0)
        if h.steps % cfg.diag_every == 0:
            e = float(nbody.total_energy(state))
            h.recorder.record_snapshot(h.steps, float(state.time), energy=e,
                                       de_rel=abs((e - h.e0) / h.e0))
        return False

    def collect(self, h: RunHandle) -> RunReport:
        cfg = h.cfg
        e1 = float(nbody.total_energy(h.state))
        return h.recorder.finalize(
            n_bodies=cfg.n, ensemble=1,
            n_devices=cfg.devices if cfg.strategy != "single" else 1,
            per_run_pairs=[float(h.steps) * cfg.n * cfg.n],
            metrics=obs_metrics.registry().snapshot(),
            extra={"e0": h.e0, "e1": e1,
                   "de_rel": abs((e1 - h.e0) / h.e0),
                   "t_final": float(h.state.time)})


# --------------------------------------------------------------------------
# single block run under a distribution strategy (shard-local compaction)
# --------------------------------------------------------------------------
class BlockStrategyRunner(Runner):
    """One run, its force evaluation sharded by ``cfg.strategy``: each shard
    compacts its own local active targets (``compaction="gather"``) and the
    report carries the per-shard launched tiles as ``grid_tiles_per_shard``.
    """

    kind = "block_strategy"

    def matches(self, cfg: SimConfig) -> bool:
        # a single block run under a distribution strategy shards the
        # *domain* (shard-local compaction, per-shard tile telemetry)
        # — batched block runs shard the batch axis instead, where
        # the strategy label only tags the report
        return cfg.mix is None and cfg.resolved_stepper() == "block" \
            and cfg.ensemble == 1 and cfg.strategy != "single"

    def build(self, cfg: SimConfig) -> RunHandle:
        validate_config(cfg)
        if cfg.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {cfg.strategy!r}")
        h = RunHandle(cfg, self.kind)
        impl = ens.resolve_eval_impl(cfg.impl, cfg.kernel)
        if impl == "fp64" or cfg.dtype == "fp64":
            raise ValueError(
                "fp64 (golden reference) only runs under strategy='single'")
        devices = _device_list(cfg)
        state = _build_states(cfg)[0]
        # same tile shape for the bootstrap pass as for the event loop, so a
        # CLI run is bit-for-bit reproducible by ens.evolve_strategy_block
        evaluator = make_strategy_evaluator(
            cfg.strategy, devices=devices, order=cfg.order, eps=cfg.eps,
            impl=impl, dtype=cfg.dtype,
            block_i=cfg.block_i or nbody_force.DEFAULT_BLOCK_I,
            block_j=cfg.block_j or nbody_force.DEFAULT_BLOCK_J)

        h.recorder = telemetry.TelemetryRecorder(cfg.meta())
        state = hermite.initialize(state, evaluator)
        jax.block_until_ready(state.pos)
        h.e0 = float(nbody.total_energy(state))
        h.recorder.record_snapshot(0, 0.0, energy=h.e0, de_rel=0.0)

        n_levels = cfg.n_levels
        if n_levels is None:  # --levels auto, from the initial dt spread
            dt_i = hermite.aarseth_dt_particles(state, eta=cfg.eta,
                                                dt_max=cfg.dt_max)
            n_levels = int(hermite.auto_n_levels(dt_i, dt_max=cfg.dt_max))
            h.recorder.meta["n_levels"] = n_levels
            h.recorder.meta["n_levels_auto"] = [n_levels]
        h.state, h.impl, h.n_levels = state, impl, n_levels
        h.carry = None
        h.done = 0
        h.ev_prev = h.tiles_prev = 0.0
        return h

    def step(self, h: RunHandle) -> bool:
        if h.finished:
            return True
        cfg = h.cfg
        if not h.done * cfg.diag_every < MAX_STEPS:
            h.finished = True
            return True
        tracer = obs_trace.get_tracer()
        reg = obs_metrics.registry()
        t0 = time.perf_counter()
        t0_us = tracer.now_us()
        h.state, h.carry = ens.strategy_run_block(
            h.state, t_end=cfg.t_end, n_events=cfg.diag_every,
            dt_max=cfg.dt_max, n_levels=h.n_levels, carry=h.carry,
            eta=cfg.eta, order=cfg.order, eps=cfg.eps, impl=h.impl,
            strategy=cfg.strategy, compaction=cfg.compaction,
            block_i=cfg.block_i, block_j=cfg.block_j, devices=cfg.devices,
            dtype=cfg.dtype)
        jax.block_until_ready(h.state.pos)
        h.done += 1
        ev_now = float(h.carry.n_events)
        tiles_now = float(np.asarray(h.carry.n_tiles).sum())
        _chunk_spans(tracer, t0_us, tracer.now_us() - t0_us, chunk=h.done,
                     events=int(ev_now - h.ev_prev),
                     tiles=tiles_now - h.tiles_prev)
        reg.counter("sim.events", unit="events").inc(ev_now - h.ev_prev)
        reg.counter("sim.tiles_launched", unit="tiles").inc(
            tiles_now - h.tiles_prev)
        per_shard_now = np.asarray(h.carry.n_tiles, np.float64)
        if per_shard_now.size and per_shard_now.mean() > 0:
            reg.gauge(
                "sim.shard_imbalance", unit="ratio",
                help="max/mean per-shard launched tiles").set(
                float(per_shard_now.max() / per_shard_now.mean()))
        h.ev_prev, h.tiles_prev = ev_now, tiles_now
        e = float(nbody.total_energy(h.state))
        h.recorder.record_step(int(h.carry.n_events), float(h.state.time),
                               time.perf_counter() - t0)
        h.recorder.record_snapshot(
            int(h.carry.n_events), float(h.state.time), energy=e,
            de_rel=abs((e - h.e0) / h.e0),
            **({"metrics": reg.snapshot()}
               if cfg.metrics_interval
               and h.done % cfg.metrics_interval == 0 else {}))
        if float(h.state.time) >= cfg.t_end:
            h.finished = True
        return h.finished

    def collect(self, h: RunHandle) -> RunReport:
        cfg = h.cfg
        e1 = float(nbody.total_energy(h.state))
        per_shard = [float(t) for t in np.asarray(h.carry.n_tiles)]
        return h.recorder.finalize(
            n_bodies=cfg.n, ensemble=1, n_devices=cfg.devices,
            per_run_steps=[int(h.carry.n_events)],
            per_run_pairs=[float(h.carry.n_pairs)],
            per_run_tiles=[sum(per_shard)], per_shard_tiles=per_shard,
            metrics=obs_metrics.registry().snapshot(),
            extra={"e0": h.e0, "e1": e1,
                   "de_rel": abs((e1 - h.e0) / h.e0),
                   "t_final": float(h.state.time)})


# --------------------------------------------------------------------------
# batched ensembles (lockstep; fixed dt or per-run shared-adaptive dt)
# --------------------------------------------------------------------------
class EnsembleRunner(Runner):
    """Homogeneous ensemble: B copies of one scenario, seeds seed..seed+B-1,
    advanced by the shared lockstep loop (mask-aware engine calls, per-run
    diagnostics and n_active-honest telemetry)."""

    kind = "ensemble"

    def matches(self, cfg: SimConfig) -> bool:
        # the block engine lives in the (vmapped) ensemble path; a
        # single block run is just a B=1 batch
        return cfg.mix is None and (cfg.ensemble > 1
                                    or cfg.resolved_stepper() == "block")

    def _batch(self, cfg: SimConfig):
        batched = ens.stack_states(_build_states(cfg))
        n_active = [cfg.n] * cfg.ensemble
        runs_meta = [{"run": i, "scenario": cfg.scenario, "n": cfg.n,
                      "seed": cfg.seed + i} for i in range(cfg.ensemble)]
        return batched, n_active, runs_meta

    def build(self, cfg: SimConfig) -> RunHandle:
        stepper = validate_config(cfg)
        if cfg.strategy not in STRATEGIES and cfg.strategy != "single":
            raise ValueError(f"unknown strategy {cfg.strategy!r}")
        h = RunHandle(cfg, self.kind)
        batched, n_active, runs_meta = self._batch(cfg)
        if stepper == "block" and cfg.sources == "neighbor":
            # sort once at build (row order is carry-aligned for the whole
            # run) so contiguous index blocks are compact spatial cells and
            # the gathered neighbor windows stay tight
            batched = ens.spatial_sort_batched(
                batched, n_active,
                leaf=math.gcd(cfg.block_i or nbody_force.DEFAULT_BLOCK_I,
                              cfg.block_j or nbody_force.DEFAULT_BLOCK_J))
        impl = ens.resolve_eval_impl(cfg.impl, cfg.kernel)
        devices = _device_list(cfg) if cfg.devices > 1 else None
        h.b = ens.batch_size(batched)
        h.n_max = batched.pos.shape[1]
        h.n_active, h.runs_meta = n_active, runs_meta

        h.recorder = telemetry.TelemetryRecorder(cfg.meta())
        reg = obs_metrics.registry()
        reg.gauge("sim.pad_waste", unit="fraction",
                  help="zero-mass padded slot fraction of the batch").set(
            1.0 - float(sum(n_active)) / (h.b * h.n_max))
        na = jnp.asarray(n_active, jnp.int32)
        h.kw = dict(n_active=na, order=cfg.order, eps=cfg.eps, impl=impl,
                    devices=devices, dtype=cfg.dtype)
        if cfg.mesh is not None:
            # validated block-only, so the lockstep entry points (which
            # take no mesh) never see the key
            h.kw["mesh"] = tuple(int(e) for e in cfg.mesh)
            h.kw["devices"] = _device_list(cfg)
        batched = ens.ensemble_initialize(batched, **h.kw)
        jax.block_until_ready(batched.pos)
        h.batched = batched
        h.e0 = np.asarray(ens.batched_total_energy(batched), np.float64)
        h.recorder.record_snapshot(0, 0.0, energy=h.e0.tolist(), de_rel=0.0)
        h.chunks_done = 0

        h.stepper = cfg.resolved_stepper()
        h.per_run_steps = h.per_run_tiles = None
        if h.stepper == "fixed":
            h.n_steps = max(1, int(round(cfg.t_end / cfg.dt)))
            h.done = 0
        elif h.stepper == "adaptive":
            # per-run shared-adaptive dt: each member steps at its own
            # Aarseth criterion; finished members freeze until the whole
            # batch is done
            h.h_prev = h.n_taken = None
            h.done = 0
            h.ev_prev = 0.0
        else:
            # hierarchical block timesteps: each member's active block is
            # evaluated per event; the engine *measures* its pairwise work
            # and the kernel grid tiles it launched (what compaction shrinks)
            n_levels = cfg.n_levels
            if n_levels is None:  # auto: size each member's hierarchy from
                # its initial Aarseth dt distribution, run at the deepest
                per_member = _auto_levels(cfg, batched)
                n_levels = max(per_member)
                h.recorder.meta["n_levels"] = n_levels
                h.recorder.meta["n_levels_auto"] = per_member
            h.n_levels = n_levels
            h.plan = ops.CapacityPlan(
                h.n_max, h.n_max, cfg.block_i or nbody_force.DEFAULT_BLOCK_I,
                cfg.block_j or nbody_force.DEFAULT_BLOCK_J, dtype=cfg.dtype)
            h.mask = np.arange(h.n_max)[None, :] \
                < np.asarray(n_active)[:, None]
            h.carry = None
            h.done = 0
            h.ev_prev = np.zeros(h.b)
            h.tiles_prev = np.zeros(h.b)
            h.pairs_prev = np.zeros(h.b)
            h.bound_total = 0.0
            h.nref_prev = h.nov_prev = 0.0
        return h

    def _snapshot(self, h: RunHandle, done, t_sim, wall) -> None:
        # one wall sample per chunk: lockstep ensembles sync at chunk ends
        cfg = h.cfg
        h.chunks_done += 1
        h.recorder.record_step(done, t_sim, wall)
        e = np.asarray(ens.batched_total_energy(h.batched), np.float64)
        h.recorder.record_snapshot(
            done, t_sim, energy=e.tolist(),
            de_rel=float(np.abs((e - h.e0) / h.e0).max()),
            **({"metrics": obs_metrics.registry().snapshot()}
               if cfg.metrics_interval
               and h.chunks_done % cfg.metrics_interval == 0 else {}))

    def step(self, h: RunHandle) -> bool:
        if h.finished:
            return True
        step_fn = {"fixed": self._step_fixed, "adaptive": self._step_adaptive,
                   "block": self._step_block}[h.stepper]
        return step_fn(h)

    def _step_fixed(self, h: RunHandle) -> bool:
        cfg = h.cfg
        tracer = obs_trace.get_tracer()
        chunk = min(cfg.diag_every, h.n_steps - h.done)
        t0 = time.perf_counter()
        t0_us = tracer.now_us()
        h.batched = ens.ensemble_run(h.batched, n_steps=chunk, dt=cfg.dt,
                                     **h.kw)
        jax.block_until_ready(h.batched.pos)
        h.done += chunk
        _chunk_spans(tracer, t0_us, tracer.now_us() - t0_us,
                     chunk=h.chunks_done + 1, events=chunk * h.b)
        obs_metrics.registry().counter(
            "sim.events", unit="events").inc(chunk * h.b)
        self._snapshot(h, h.done, h.done * cfg.dt, time.perf_counter() - t0)
        h.finished = h.done >= h.n_steps
        return h.finished

    def _step_adaptive(self, h: RunHandle) -> bool:
        cfg = h.cfg
        tracer = obs_trace.get_tracer()
        t0 = time.perf_counter()
        t0_us = tracer.now_us()
        h.batched, h.h_prev, h.n_taken = ens.ensemble_run_adaptive(
            h.batched, t_end=cfg.t_end, n_steps=cfg.diag_every,
            h_prev=h.h_prev, n_taken=h.n_taken, eta=cfg.eta,
            dt_max=cfg.dt_max, **h.kw)
        jax.block_until_ready(h.batched.pos)
        h.done += 1
        ev_now = float(np.asarray(h.n_taken, np.float64).sum())
        _chunk_spans(tracer, t0_us, tracer.now_us() - t0_us,
                     chunk=h.done, events=int(ev_now - h.ev_prev))
        obs_metrics.registry().counter(
            "sim.events", unit="events").inc(ev_now - h.ev_prev)
        h.ev_prev = ev_now
        self._snapshot(h, int(np.max(np.asarray(h.n_taken))),
                       float(np.min(np.asarray(h.batched.time))),
                       time.perf_counter() - t0)
        h.finished = (float(np.min(np.asarray(h.batched.time))) >= cfg.t_end
                      or h.done * cfg.diag_every >= MAX_STEPS)
        return h.finished

    def _step_block(self, h: RunHandle) -> bool:
        cfg = h.cfg
        tracer = obs_trace.get_tracer()
        reg = obs_metrics.registry()
        t0 = time.perf_counter()
        t0_us = tracer.now_us()
        h.batched, h.carry = ens.ensemble_run_block(
            h.batched, t_end=cfg.t_end, n_events=cfg.diag_every,
            dt_max=cfg.dt_max, n_levels=h.n_levels, carry=h.carry,
            eta=cfg.eta, compaction=cfg.compaction,
            bucket_mode=cfg.bucket_mode,
            block_i=cfg.block_i, block_j=cfg.block_j,
            sources=cfg.sources, neighbor_radius=cfg.neighbor_radius,
            refresh_levels=cfg.refresh_levels, **h.kw)
        jax.block_until_ready(h.batched.pos)
        h.done += 1
        ev = np.asarray(h.carry.n_events, np.float64)
        tiles = np.asarray(h.carry.n_tiles, np.float64)
        pairs = np.asarray(h.carry.n_pairs, np.float64)
        ev_d, tiles_d = ev - h.ev_prev, tiles - h.tiles_prev
        pairs_d = pairs - h.pairs_prev
        _chunk_spans(tracer, t0_us, tracer.now_us() - t0_us, chunk=h.done,
                     events=int(ev_d.sum()), tiles=float(tiles_d.sum()))
        reg.counter("sim.events", unit="events").inc(float(ev_d.sum()))
        reg.counter("sim.tiles_launched", unit="tiles").inc(
            float(tiles_d.sum()))
        reg.counter(
            "sim.tiles_dense_baseline", unit="tiles",
            help="what compaction='none' would have enqueued").inc(
            float(ev_d.sum()) * h.plan.dense_tiles)
        # analytic a-priori tile bound: occupancy entry 0 (every real
        # particle) is the largest active set any tick of the block
        # schedule can see, so per member and event the launch can
        # never exceed the tiles of occ[0]'s capacity bucket
        # the full-N tile bound doesn't transfer to the fused mesh, whose
        # launches are sized by P shard-local plans (the engine already
        # schedules from the analytic per-shard bound there)
        if cfg.mesh is None:
            occ0 = np.asarray(jax.vmap(
                lambda lv, m: hermite.block_level_occupancy(
                    lv, n_levels=h.n_levels, mask=m))(
                        h.carry.levels, jnp.asarray(h.mask)))[:, 0]
            for i in range(h.b):
                per_event = (int(h.plan.tiles(h.plan.bucket(int(occ0[i]))))
                             if cfg.compaction == "gather"
                             else h.plan.dense_tiles)
                h.bound_total += ev_d[i] * per_event
            reg.gauge("sim.tiles_occupancy_bound", unit="tiles",
                      help="analytic bound; launched <= bound").set(
                h.bound_total)
        for i in range(h.b):
            if ev_d[i] > 0 and h.n_active[i] > 0:
                reg.histogram(
                    "sim.active_fraction", unit="fraction",
                    help="per-chunk mean active-target fraction"
                ).observe(pairs_d[i]
                          / (ev_d[i] * float(h.n_active[i]) ** 2))
        # the fused mesh engine's capacity switch lives inside the shards
        # (one shared bucket per (batch, domain) shard) — there is no
        # batch-level hit distribution to report
        if cfg.compaction == "gather" and cfg.mesh is None:
            reg.gauge(
                "sim.bucket_hits", unit="hits",
                help="capacity-bucket switch hit counts (full "
                     "schedule, summed over members)").set(
                [float(hits) for hits in
                 np.asarray(h.carry.bucket_hits, np.float64).sum(axis=0)])
        if h.carry.nbr is not None:
            nbr = h.carry.nbr
            nref = float(np.asarray(nbr.n_refresh, np.float64).sum())
            nov = float(np.asarray(nbr.n_overflow, np.float64).sum())
            reg.counter(
                "sim.neighbor_refreshes", unit="refreshes",
                help="Ahmad-Cohen window rebuilds (far-field "
                     "re-anchors, summed over members)").inc(
                nref - h.nref_prev)
            reg.counter(
                "sim.neighbor_overflow", unit="fallbacks",
                help="refreshes whose widest active window fit no "
                     "bucket below the full source extent").inc(
                nov - h.nov_prev)
            h.nref_prev, h.nov_prev = nref, nov
            wc = np.asarray(nbr.win_cnt, np.float64)
            nsb = nbr.win_idx.shape[-1]
            blk_valid = (np.arange(wc.shape[1])[None, :]
                         * h.plan.block_i) \
                < np.asarray(h.n_active)[:, None]
            occ_hist = reg.histogram(
                "sim.neighbor_occupancy", unit="fraction",
                help="per-target-block neighbor window fraction of "
                     "the full source extent (sampled per chunk)")
            for v in (wc[blk_valid] / nsb).tolist():
                occ_hist.observe(v)
        h.ev_prev, h.tiles_prev, h.pairs_prev = ev, tiles, pairs
        self._snapshot(h, int(np.max(np.asarray(h.carry.n_events))),
                       float(np.min(np.asarray(h.batched.time))),
                       time.perf_counter() - t0)
        h.finished = (float(np.min(np.asarray(h.batched.time))) >= cfg.t_end
                      or h.done * cfg.diag_every >= MAX_STEPS)
        return h.finished

    def collect(self, h: RunHandle) -> RunReport:
        cfg = h.cfg
        if h.stepper == "fixed":
            t_final = h.n_steps * cfg.dt
            per_run_pairs = [float(h.n_steps) * a * a for a in h.n_active]
            per_run_steps = per_run_tiles = None
        elif h.stepper == "adaptive":
            per_run_steps = [int(c) for c in np.asarray(h.n_taken)]
            t_final = float(np.min(np.asarray(h.batched.time)))
            per_run_pairs = [float(s) * a * a
                             for s, a in zip(per_run_steps, h.n_active)]
            per_run_tiles = None
        else:
            per_run_steps = [int(c) for c in np.asarray(h.carry.n_events)]
            t_final = float(np.min(np.asarray(h.batched.time)))
            per_run_pairs = [float(p) for p in np.asarray(h.carry.n_pairs)]
            per_run_tiles = [float(t) for t in np.asarray(h.carry.n_tiles)]

        e1 = np.asarray(ens.batched_total_energy(h.batched), np.float64)
        de = np.abs((e1 - h.e0) / h.e0)
        virial = np.asarray(ens.batched_virial_ratio(h.batched), np.float64)
        runs = [{**h.runs_meta[i], "e0": float(h.e0[i]), "e1": float(e1[i]),
                 "de_rel": float(de[i]), "virial_ratio": float(virial[i]),
                 "force_evals": per_run_pairs[i],
                 **({"steps": per_run_steps[i]} if per_run_steps else {}),
                 **({"grid_tiles": per_run_tiles[i]}
                    if per_run_tiles else {})}
                for i in range(h.b)]
        extra = {"e0": h.e0.tolist(), "e1": e1.tolist(),
                 "de_rel": float(de.max()), "t_final": t_final,
                 "runs": runs}
        if h.stepper == "block" and h.carry.nbr is not None:
            nref = np.asarray(h.carry.nbr.n_refresh, np.int64)
            nov = np.asarray(h.carry.nbr.n_overflow, np.int64)
            for i, r in enumerate(runs):
                r["neighbor_refreshes"] = int(nref[i])
                r["neighbor_overflows"] = int(nov[i])
            extra["neighbor_refreshes"] = int(nref.sum())
            extra["neighbor_overflows"] = int(nov.sum())
        return h.recorder.finalize(
            n_bodies=h.n_max, ensemble=h.b, n_devices=max(cfg.devices, 1),
            n_active=h.n_active, per_run_steps=per_run_steps,
            per_run_pairs=per_run_pairs, per_run_tiles=per_run_tiles,
            metrics=obs_metrics.registry().snapshot(),
            extra=extra)


class MixedRunner(EnsembleRunner):
    """Heterogeneous padded ensemble: one rectangular (B, N_max, ...) batch
    of different scenarios/N, zero-mass padding, per-run n_active mask."""

    kind = "mixed"

    def matches(self, cfg: SimConfig) -> bool:
        return cfg.mix is not None

    def _batch(self, cfg: SimConfig):
        specs = scenarios.make_mix(cfg.mix, seed=cfg.seed,
                                   repeat=cfg.ensemble,
                                   params=_mix_params(cfg))
        batched, n_active = scenarios.build_padded(
            specs, n_max=cfg.pad, validate=cfg.validate_ic)
        runs_meta = [{"run": i, "scenario": s.name, "n": s.n, "seed": s.seed}
                     for i, s in enumerate(specs)]
        return batched, [int(a) for a in np.asarray(n_active)], runs_meta


# registration order IS the dispatch priority (mirrors the seed's if/elif)
register_runner(MixedRunner())
register_runner(BlockStrategyRunner())
register_runner(EnsembleRunner())
register_runner(SingleRunner())


# --------------------------------------------------------------------------
# the recomposed one-shot entry
# --------------------------------------------------------------------------
def run(cfg: SimConfig) -> RunReport:
    """Run one configuration end-to-end and return its telemetry report.

    The monolithic convenience over the composable surface: resolve the
    runner, ``build``, drive ``step`` to completion, ``collect``.  Each run
    gets its own :class:`repro.obs.metrics.MetricsRegistry` (scoped as the
    module-current registry so the engine layers' emissions land in it)
    whose snapshot rides in the report under ``metrics``; with ``cfg.trace``
    a live :class:`repro.obs.trace.SpanTracer` is installed and the
    Chrome-trace JSON exported on completion (``trace_path`` in the report).
    """
    validate_config(cfg)
    tracer = obs_trace.SpanTracer() if cfg.trace else obs_trace.NullTracer()
    prev_tracer = obs_trace.set_tracer(tracer)
    try:
        with obs_metrics.use():
            obs_metrics.registry().gauge(
                "sim.dtype", unit="enum",
                help="precision axis of the run's force kernels").set(
                cfg.dtype)
            obs_metrics.registry().gauge(
                "sim.sources", unit="enum",
                help="force-source mode (full all-pairs vs Ahmad-Cohen "
                     "neighbor windows)").set(cfg.sources)
            runner = get_runner(resolve_kind(cfg))
            handle = runner.build(cfg)
            while not runner.step(handle):
                pass
            report = runner.collect(handle)
    finally:
        obs_trace.set_tracer(prev_tracer)
    if cfg.trace:
        report["trace_path"] = tracer.export(cfg.trace)
    if cfg.out:
        telemetry.write_report(report, cfg.out)
        report["report_path"] = cfg.out
    return report
