"""Per-run telemetry: wall-time accounting, modeled energy/EDP, JSON reports.

The energy model lives in ``repro.obs.energy`` (paper Fig. 6 / Table 1
analysis) — the single source of truth this module and ``benchmarks.common``
both import, so the constants in reports and benchmark tables can never
drift apart.
"""

from __future__ import annotations

import dataclasses
import json
import os
import statistics
import time
import warnings
from typing import Any, Dict, List, Optional

from repro.obs import metrics as obs_metrics
from repro.obs.energy import DEFAULT_UTIL, modeled_energy  # noqa: F401
#   (re-exported: callers historically read telemetry.DEFAULT_UTIL)

#: schema version stamped into every RunReport (bump on breaking key changes)
REPORT_SCHEMA_VERSION = 1


class RunReport(dict):
    """Versioned, typed telemetry report of one run.

    A ``dict`` subclass, so every historical consumer (``report["wall_s"]``,
    ``json.dump``, ``report.get(...)``) keeps working unchanged — but new
    code should treat the mapping surface as legacy and use the typed one:
    the ``schema_version`` stamp, :meth:`to_json` / :meth:`from_json` (an
    exact round-trip, validated on load) and the read-only field properties.
    """

    def __init__(self, data: Optional[Dict[str, Any]] = None, **kw):
        super().__init__(data or {}, **kw)
        self.setdefault("schema_version", REPORT_SCHEMA_VERSION)

    # ------------------------------------------------------------- typed view
    @property
    def schema_version(self) -> int:
        return int(self["schema_version"])

    @property
    def wall_s(self) -> float:
        return float(self["wall_s"])

    @property
    def steps(self) -> int:
        return int(self["steps"])

    @property
    def steps_per_s(self) -> float:
        return float(self["steps_per_s"])

    @property
    def interactions_per_s(self) -> float:
        return float(self["interactions_per_s"])

    @property
    def snapshots(self) -> List[Dict[str, Any]]:
        return self["snapshots"]

    @property
    def as_dict(self) -> Dict[str, Any]:
        """Deprecated: a plain-dict copy for legacy consumers.

        ``RunReport`` *is* a mapping — index it directly, or use the typed
        properties.  This escape hatch exists only for callers that type-check
        against ``dict`` exactly; it will be removed once none remain.
        """
        warnings.warn(
            "RunReport.as_dict is deprecated: RunReport is a dict — index "
            "it directly or use the typed properties", DeprecationWarning,
            stacklevel=2)
        return dict(self)

    # ------------------------------------------------------------ round-trip
    def to_json(self) -> str:
        return json.dumps(self, default=float)

    @classmethod
    def from_json(cls, text: str) -> "RunReport":
        data = json.loads(text)
        if not isinstance(data, dict):
            raise ValueError(
                f"RunReport.from_json: expected a JSON object, "
                f"got {type(data).__name__}")
        version = data.get("schema_version")
        if version != REPORT_SCHEMA_VERSION:
            raise ValueError(
                f"RunReport.from_json: schema_version {version!r} does not "
                f"match this reader ({REPORT_SCHEMA_VERSION})")
        return cls(data)


@dataclasses.dataclass
class StepSample:
    step: int
    t_sim: float
    wall_s: float


class TelemetryRecorder:
    """Accumulates per-step wall times + diagnostics snapshots for one run."""

    def __init__(self, meta: Optional[Dict[str, Any]] = None):
        self.meta: Dict[str, Any] = dict(meta or {})
        self.steps: List[StepSample] = []
        self.snapshots: List[Dict[str, Any]] = []
        self._t0 = time.perf_counter()

    # ---------------------------------------------------------------- record
    def record_step(self, step: int, t_sim: float, wall_s: float) -> None:
        self.steps.append(StepSample(step=step, t_sim=t_sim, wall_s=wall_s))

    def record_snapshot(self, step: int, t_sim: float, **values) -> None:
        self.snapshots.append({"step": step, "t_sim": t_sim, **values})

    # -------------------------------------------------------------- finalize
    def finalize(self, *, n_bodies: int, ensemble: int = 1,
                 n_devices: int = 1, util: float = DEFAULT_UTIL,
                 n_active: Optional[List[int]] = None,
                 per_run_steps: Optional[List[int]] = None,
                 per_run_pairs: Optional[List[float]] = None,
                 per_run_tiles: Optional[List[float]] = None,
                 per_shard_tiles: Optional[List[float]] = None,
                 metrics: Optional[Dict[str, Any]] = None,
                 extra: Optional[Dict[str, Any]] = None) -> RunReport:
        """Assemble the versioned :class:`RunReport` for this run.

        For padded ensembles pass ``n_active`` (per-run real particle
        counts): interaction throughput then counts ``n_active**2`` pairs per
        run rather than the padded ``n_bodies**2``, so telemetry and the EDP
        model never credit work done on zero-mass padding rows.
        ``per_run_steps`` (e.g. adaptive-mode productive step counts) further
        replaces the shared lockstep step count per run.

        ``per_run_pairs`` is the strongest form: the *measured* per-run
        pairwise force-evaluation count (per Hermite pass).  The block
        stepper evaluates only its active targets each substep, so its cost
        is not ``steps * n_active**2`` — when counts are given they override
        the step-based estimate entirely, and the report carries them as
        ``force_evals`` / ``force_evals_total``.

        ``per_run_tiles`` reports the kernel grid tiles *launched* per run
        (both Hermite passes) as ``grid_tiles`` / ``grid_tiles_total`` —
        next to ``force_evals`` this shows whether algorithmic savings
        reached the launch schedule: the masked block path shrinks
        ``force_evals`` but launches the full grid every event, the
        compaction path shrinks both.

        ``metrics`` is a ``repro.obs.metrics`` registry snapshot (or a dict
        with the same versioned schema — validated here, so a malformed
        payload fails at finalize time, not when a reader chokes on the
        report); it lands under the report's ``metrics`` key.

        ``per_shard_tiles`` (strategy-distributed block runs) additionally
        breaks the launched tiles down *per device shard* as
        ``grid_tiles_per_shard`` — under shard-local compaction each chip
        enqueues only the buckets its own local active set needed, so the
        vector shows which shards the activity actually touched (a flat
        vector at the dense count means compaction never engaged).
        """
        walls = [s.wall_s for s in self.steps]
        wall_total = sum(walls) if walls else time.perf_counter() - self._t0
        n_steps = self.steps[-1].step if self.steps else 0
        # each Hermite-6 step sweeps all pairs twice (acc/jerk pass + snap)
        if per_run_pairs is not None:
            force_evals = [float(p) for p in per_run_pairs]
            interactions = 2.0 * sum(force_evals)
        elif n_active is not None:
            acts = [float(a) for a in n_active]
            steps_per_run = [float(s) for s in per_run_steps] \
                if per_run_steps is not None else [float(n_steps)] * len(acts)
            if len(steps_per_run) != len(acts):
                raise ValueError(
                    f"per_run_steps (len {len(steps_per_run)}) must match "
                    f"n_active (len {len(acts)})")
            force_evals = [st * a * a for st, a in zip(steps_per_run, acts)]
            interactions = 2.0 * sum(force_evals)
        else:
            force_evals = None
            interactions = 2.0 * n_steps * ensemble * float(n_bodies) ** 2
        energy = modeled_energy(wall_total, n_devices, util)
        if metrics is not None:
            obs_metrics.validate_snapshot(metrics)
        report: Dict[str, Any] = {
            **self.meta,
            "n_bodies": n_bodies,
            "ensemble": ensemble,
            "devices": n_devices,
            **({"n_active": [int(a) for a in n_active]}
               if n_active is not None else {}),
            **({"force_evals": force_evals,
                "force_evals_total": sum(force_evals)}
               if force_evals is not None else {}),
            **({"grid_tiles": [float(t) for t in per_run_tiles],
                "grid_tiles_total": float(sum(per_run_tiles))}
               if per_run_tiles is not None else {}),
            **({"grid_tiles_per_shard": [float(t) for t in per_shard_tiles]}
               if per_shard_tiles is not None else {}),
            "steps": n_steps,
            "wall_s": wall_total,
            "steps_per_s": n_steps / wall_total if wall_total > 0 else 0.0,
            "interactions_per_s":
                interactions / wall_total if wall_total > 0 else 0.0,
            "step_wall_s": {
                "mean": statistics.fmean(walls) if walls else 0.0,
                "median": statistics.median(walls) if walls else 0.0,
                "max": max(walls) if walls else 0.0,
            },
            "modeled": {
                "util": util,
                "energy_J": energy["energy_J"],
                "peak_W": energy["peak_W"],
                "edp_Js": energy["edp_Js"],
            },
            **({"metrics": metrics} if metrics is not None else {}),
            "snapshots": self.snapshots,
        }
        if extra:
            report.update(extra)
        return RunReport(report)


def write_report(report: Dict[str, Any], path: str) -> str:
    """Persist a report dict as pretty-printed JSON; returns the path."""
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    return path


def default_report_path(meta: Dict[str, Any], root: str = ".") -> str:
    """experiments/sim/<scenario>_n<N>[_eB]_<strategy>.json"""
    bits = [str(meta.get("scenario", "run")), f"n{meta.get('n', 0)}"]
    if int(meta.get("ensemble", 1)) > 1:
        bits.append(f"e{meta['ensemble']}")
    bits.append(str(meta.get("strategy", "single")))
    return os.path.join(root, "experiments", "sim", "_".join(bits) + ".json")
