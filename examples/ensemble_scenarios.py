"""Scenario-library + ensemble-engine tour: run a small batched ensemble of
every registered scenario and print per-scenario telemetry.

    PYTHONPATH=src python examples/ensemble_scenarios.py \
        --n 128 --ensemble 4 --t-end 0.125 [--devices 2]

Each scenario runs as one batched call (B lockstep copies with different
seeds, per-run shared-adaptive timestep); the summary compares wall time,
step counts, achieved pair-interaction throughput and worst-case per-run
energy drift — the workload-shape sensitivity the scenario registry exists
to expose.
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--ensemble", type=int, default=4)
    ap.add_argument("--t-end", type=float, default=0.125)
    ap.add_argument("--devices", type=int, default=1)
    args = ap.parse_args()

    if args.devices > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    jax.config.update("jax_enable_x64", True)

    from repro.sim import driver, scenarios

    print(f"{'scenario':16s} {'steps':>6s} {'wall_s':>8s} {'pairs/s':>10s} "
          f"{'max|dE/E|':>10s}")
    for name in scenarios.available():
        spec = scenarios.get_spec(name)
        n = max(args.n, spec.min_n)
        if name == "two_body":
            n = 2
        report = driver.run(driver.SimConfig(
            scenario=name, n=n, ensemble=args.ensemble, t_end=args.t_end,
            devices=args.devices, impl="xla", diag_every=16))
        print(f"{name:16s} {report['steps']:6d} {report['wall_s']:8.2f} "
              f"{report['interactions_per_s']:10.2e} "
              f"{report['de_rel']:10.2e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
