"""Quickstart: a 1024-body Plummer cluster, 6th-order Hermite, mixed
precision (FP64 host / FP32 device kernel) — the paper's pipeline in ~20
lines of public API.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import hermite, nbody                      # noqa: E402
from repro.core.evaluate import make_evaluator             # noqa: E402


def main():
    state = nbody.plummer(512, seed=0)

    # FP32 force evaluation (Pallas kernel on TPU, interpreted on CPU);
    # prediction/correction stay FP64 on the host — the paper's split.
    evaluator = make_evaluator(order=6)

    state = hermite.initialize(state, evaluator)
    e0 = float(nbody.total_energy(state))
    print(f"t=0.000  E={e0:+.6f}")

    for _ in range(4):
        state = hermite.evolve(state, evaluator,
                               t_end=float(state.time) + 0.25, eta=0.02)
        e = float(nbody.total_energy(state))
        print(f"t={float(state.time):.3f}  E={e:+.6f}  "
              f"|dE/E|={abs((e - e0) / e0):.2e}")


if __name__ == "__main__":
    main()
