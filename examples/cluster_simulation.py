"""End-to-end driver: the paper's representative simulation, scaled for the
host — Plummer sphere, 6th-order Hermite, FP32 device evaluation, any of the
paper's three scaling strategies (+ ring), with validation against the FP64
golden reference and the Fig. 4 energy-distribution comparison.

    PYTHONPATH=src python examples/cluster_simulation.py \
        --n 2048 --t-end 0.5 --strategy replicated --devices 4

Multi-device strategies on a CPU host need placeholder devices — handled
automatically (XLA_FLAGS set before jax import).
"""

import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--t-end", type=float, default=0.5)
    ap.add_argument("--dt", type=float, default=1.0 / 256)
    ap.add_argument("--strategy", default="single",
                    choices=("single", "replicated", "two_level",
                             "mesh_sharded", "ring"))
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--validate", action="store_true", default=True)
    args = ap.parse_args()

    if args.devices > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import hermite, nbody
    from repro.core.evaluate import make_evaluator
    from repro.core.strategies import make_strategy_evaluator

    state = nbody.plummer(args.n, seed=0)
    if args.strategy == "single":
        ev = make_evaluator(order=6)
    else:
        ev = make_strategy_evaluator(
            args.strategy, devices=jax.devices()[: args.devices], impl="xla")

    init = hermite.initialize(state, ev)
    e0 = float(nbody.total_energy(init))
    out = hermite.evolve(state, ev, t_end=args.t_end, dt=args.dt)
    e1 = float(nbody.total_energy(out))
    print(f"[sim] N={args.n} strategy={args.strategy} t={float(out.time):.3f}"
          f" |dE/E|={abs((e1 - e0) / e0):.3e}")

    if args.validate:
        golden = make_evaluator(precision="fp64")
        out_g = hermite.evolve(state, golden, t_end=args.t_end, dt=args.dt)
        ed = np.asarray(nbody.particle_energies(out))
        eg = np.asarray(nbody.particle_energies(out_g))
        lo, hi = min(eg.min(), ed.min()), max(eg.max(), ed.max())
        hg, edges = np.histogram(eg, bins=24, range=(lo, hi), density=True)
        hd, _ = np.histogram(ed, bins=24, range=(lo, hi), density=True)
        overlap = float(np.minimum(hg, hd).sum() * (edges[1] - edges[0]))
        print(f"[validate] energy-distribution overlap vs FP64 golden: "
              f"{overlap:.3f} (paper Fig. 4: distributions coincide)")
        # ASCII histogram, accelerated (*) vs golden (.)
        peak = max(hg.max(), hd.max())
        for i in range(24):
            g = int(30 * hg[i] / peak)
            d = int(30 * hd[i] / peak)
            print(f"  {edges[i]:+.3f} " + "#" * min(g, d)
                  + ("*" * (d - g) if d > g else "." * (g - d)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
