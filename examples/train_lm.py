"""Train a ~100M-parameter LM (reduced qwen3 family) for a few hundred steps
with the full substrate: sharding rules, AdamW + warmup-cosine, deterministic
data pipeline, checkpoint/restart, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --preset 100m
    PYTHONPATH=src python examples/train_lm.py --steps 50 --preset 10m  # CI
"""

import argparse
import dataclasses
import sys

from repro.data import SyntheticLM, batch_spec_for
from repro.distributed.shardings import MeshRules
from repro.models import config as C
from repro.models import params as P
from repro.optim import AdamW, warmup_cosine
from repro.train import Trainer, TrainerConfig

PRESETS = {
    # ~104M params: 12L x 768, tied embeddings over the qwen3 vocab subset
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32768, batch=8, seq=256),
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                d_ff=688, vocab_size=8192, batch=4, seq=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = dataclasses.replace(
        C.get("qwen3-0.6b"),
        name=f"qwen3-{args.preset}",
        n_layers=p["n_layers"], d_model=p["d_model"], n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], head_dim=p["d_model"] // p["n_heads"],
        dtype="float32", attn_chunked_above=10 ** 9, remat="none")
    print(f"[train_lm] {cfg.name}: {P.count_params(cfg) / 1e6:.1f}M params")

    rules = MeshRules.single_device()
    data = SyntheticLM(cfg, batch_spec_for(cfg, p["batch"], p["seq"]))
    opt = AdamW(learning_rate=warmup_cosine(
        args.lr, warmup=max(args.steps // 20, 5), total=args.steps))
    trainer = Trainer(cfg, rules, opt, data,
                      TrainerConfig(steps=args.steps,
                                    ckpt_every=max(args.steps // 2, 25),
                                    ckpt_dir=args.ckpt_dir, log_every=10))
    _, _, history = trainer.run()
    print(f"[train_lm] final loss {history[-1]['loss']:.4f} "
          f"(step time {history[-1]['step_time'] * 1e3:.0f} ms, "
          f"stragglers {trainer.monitor.flagged})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
