"""Serve a small LM with batched requests: prefill a batch of prompts, then
lock-step greedy decode — the serving path the decode_32k / long_500k
dry-run shapes characterize at scale.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --prompt-len 32 \
        --gen 32 --arch qwen3-0.6b --scale 0.05
"""

import argparse
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax

    from repro.distributed.shardings import MeshRules
    from repro.launch.train import scaled_config
    from repro.models import config as C
    from repro.models import params as P
    from repro.serve import Engine, ServeConfig

    cfg = scaled_config(C.get(args.arch), args.scale)
    rules = MeshRules.single_device()
    params = P.init_params(cfg, jax.random.PRNGKey(0))
    print(f"[serve_lm] {cfg.name}: {P.count_params(cfg) / 1e6:.1f}M params, "
          f"batch={args.batch}")

    engine = Engine(cfg, rules, params, ServeConfig(
        max_len=args.prompt_len + args.gen,
        temperature=args.temperature))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out, stats = engine.generate({"tokens": prompts}, args.gen)
    print(f"[serve_lm] prefill {stats['prefill_s'] * 1e3:.0f} ms, "
          f"decode {stats['decode_s'] * 1e3:.0f} ms "
          f"({stats['tok_per_s']:.1f} tok/s)")
    for i in range(min(2, args.batch)):
        print(f"  seq {i}: {np.asarray(out[i])[:16]} ...")
    return 0


if __name__ == "__main__":
    sys.exit(main())
